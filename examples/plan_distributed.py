"""Cobra as a distributed-execution planner (beyond-paper integration).

    PYTHONPATH=src python examples/plan_distributed.py

For several (architecture × workload) cells, front the step-program planner
through the same ``CobraSession`` facade used for program rewriting: both
domains return ``PlanReport``s — the chosen alternative, its estimated
cost, and the size of the enumerated plan space — so sharding choices read
exactly like SQL/prefetch choices.
"""

import sys

sys.path.insert(0, "src")

from repro.api import CobraSession
from repro.programs import make_orders_customer_db


def main():
    # the planner facade needs no relational data; a tiny db seeds the session
    session = CobraSession(make_orders_customer_db(10, 10))
    cells = [
        ("stablelm-12b", "train", 4096, 256),
        ("kimi-k2-1t-a32b", "train", 4096, 256),
        ("llama4-scout-17b-a16e", "train", 4096, 256),
        ("qwen2-vl-72b", "decode", 32768, 128),
        ("rwkv6-3b", "decode", 524288, 1),
    ]
    for arch, kind, T, B in cells:
        reports = session.plan_step(arch, T, B, kind, mesh=(1, 16, 16),
                                    top_k=3)
        print(f"\n=== {arch} / {kind} T={T} B={B} on 16x16 ===")
        for i, rep in enumerate(reports):
            c, t = rep.choice, rep.artifact
            flag = " ← chosen" if i == 0 else ""
            feas = "" if t["feasible"] else "  [infeasible: HBM]"
            print(f"  {c.strategy:8s} remat={c.remat:5s} mb={c.microbatch:<3d} "
                  f"moe={c.moe_mode:13s} step≈{rep.est_cost_s*1e3:8.1f}ms "
                  f"(C {t['compute_s']*1e3:7.1f} | M {t['memory_s']*1e3:7.1f} "
                  f"| X {t['collective_s']*1e3:7.1f}) "
                  f"res={t['resident_bytes']/1e9:5.1f}GB{feas}{flag}")


if __name__ == "__main__":
    main()
