"""Cobra as a distributed-execution planner (beyond-paper integration).

    PYTHONPATH=src python examples/plan_distributed.py

For several (architecture × workload) cells, enumerate the plan space
(layout × remat × microbatch × MoE dispatch) through the Region-DAG
machinery, cost each with the three-term TPU roofline model, and print the
least-cost plan — the same Volcano-style choice the paper makes between
P1 and P2, applied to sharding instead of SQL.
"""

import sys

sys.path.insert(0, "src")

from repro.core.planner import enumerate_plans, plan
from repro.models.arch import get_arch


def main():
    cells = [
        ("stablelm-12b", "train", 4096, 256),
        ("kimi-k2-1t-a32b", "train", 4096, 256),
        ("llama4-scout-17b-a16e", "train", 4096, 256),
        ("qwen2-vl-72b", "decode", 32768, 128),
        ("rwkv6-3b", "decode", 524288, 1),
    ]
    for arch, kind, T, B in cells:
        cfg = get_arch(arch)
        out = plan(cfg, T, B, kind, mesh=(1, 16, 16), top_k=3)
        print(f"\n=== {arch} / {kind} T={T} B={B} on 16x16 ===")
        for i, cand in enumerate(out):
            c, t = cand["choice"], cand["terms"]
            flag = " ← chosen" if i == 0 else ""
            feas = "" if t["feasible"] else "  [infeasible: HBM]"
            print(f"  {c.strategy:8s} remat={c.remat:5s} mb={c.microbatch:<3d} "
                  f"moe={c.moe_mode:13s} step≈{cand['cost_s']*1e3:8.1f}ms "
                  f"(C {t['compute_s']*1e3:7.1f} | M {t['memory_s']*1e3:7.1f} "
                  f"| X {t['collective_s']*1e3:7.1f}) "
                  f"res={t['resident_bytes']/1e9:5.1f}GB{feas}{flag}")


if __name__ == "__main__":
    main()
