"""End-to-end training driver: a ~100M-param dense LM for a few hundred
steps on CPU, with checkpointing and auto-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses the h2o-danube family at a ~100M scale (12 layers, d=512).
"""

import argparse
import dataclasses
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.train import TrainConfig, train
from repro.models.arch import get_arch, register_arch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    base = get_arch("h2o-danube-1.8b")
    cfg100m = dataclasses.replace(
        base, name="danube-100m", n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=4, d_ff=1536, vocab_size=8192, head_dim=64, window=256,
        max_seq_len=512)
    register_arch(cfg100m)
    print(f"arch: {cfg100m.name} — {cfg100m.n_params()/1e6:.0f}M params")

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="train_lm_")
    out = train(TrainConfig(
        arch="danube-100m", scale="full", steps=args.steps,
        global_batch=8, seq_len=128, ckpt_dir=ckpt, ckpt_every=50,
        log_every=10))
    first = out["losses"][0][1]
    last = out["losses"][-1][1]
    print(f"\nloss {first:.3f} → {last:.3f} over {args.steps} steps "
          f"({'improved' if last < first else 'no improvement'})")
    print(f"checkpoints in {ckpt} (rerun with --ckpt-dir {ckpt} to resume)")


if __name__ == "__main__":
    main()
