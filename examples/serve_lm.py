"""Batched serving example: continuous-batching greedy decode.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.launch.serve import ServeConfig, Server


def main():
    cfg = ServeConfig(arch="h2o-danube-1.8b", scale="smoke", max_batch=8,
                      max_seq=96, max_new_tokens=24)
    server = Server(cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, server.arch.vocab_size,
                            rng.integers(4, 20)).astype(np.int32)
               for _ in range(6)]
    t0 = time.time()
    outs = server.generate(prompts)
    dt = time.time() - t0
    n_new = sum(len(o) for o in outs)
    print(f"served {len(prompts)} requests, {n_new} new tokens "
          f"in {dt:.2f}s ({n_new/dt:.1f} tok/s, batched greedy)")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i} prompt_len={len(prompts[i])} completion={o[:10]}")


if __name__ == "__main__":
    main()
