"""Serving walkthrough: batched execution, persistent plans, feedback.

    PYTHONPATH=src python examples/serve_programs.py

Three acts:

  1. **Cold start + warm start.** Session A compiles P0 and M0 into a
     shared ``PlanStore`` directory. Session B — a "new process" — opens
     the same store and compiles both programs WITHOUT running the memo
     search (cross-session cache hits).
  2. **Batched serving.** A ``ServingRuntime`` processes a mixed request
     stream; each batch pays one server round trip per query site instead
     of one per request, so simulated throughput scales with batch size.
     Registration compiles under the runtime's ``ExecutionContext``
     (batch_size=16), so SCAN — a while/early-exit program lifted from
     plain Python — gets a DIFFERENT plan than a one-shot compile: the
     batch-amortized prefetch beats the per-iteration aggregate query.
     Each request's ``threshold`` parameter still makes every invocation
     stop after a different number of rounds, even mid-batch.
  3. **Drift + re-optimization.** A bulk load grows ``orders`` 40x without
     ANALYZE. The feedback controller notices observed cardinalities
     leaving the estimated band, re-analyzes only the drifted tables, and
     recompiles P0 — whose winning plan flips from P1 (join) to P2
     (prefetch). M0's plan (sales only) stays hot throughout. Before the
     new plan replaces the running one, the anti-regression guard replays
     the last observed bindings against both.
  4. **Hot promotion to the compiled tier.** A runtime with
     ``compile_hot_plans=24`` serves the same P0 stream: the first batch
     is interpreted (heat below threshold), the pair goes hot mid-stream,
     and every later batch runs the kernel-backed columnar executable —
     same outputs, same simulated clock, less wall time per batch.
  5. **Observability.** ``rt.explain("P0")`` renders the drift-flipped
     plan with its rewrite provenance, estimated-vs-observed counts and
     q-errors, cache/binding status, and any bad-plan signals still
     present; ``rt.triage()`` ranks the whole fleet by traffic-weighted
     estimated win so re-optimization effort follows the requests.
  6. **Sharded cluster + hot-shard triage.** A 4-worker
     ``ClusterRuntime`` partitions ``tasks`` by ``t_role_id`` and routes
     W_E requests by their worklist key. A uniform key stream spreads
     across the fleet; a skewed stream (every key a multiple of 4) pins
     ALL the work on worker 0 — cluster ``triage()`` grows per-shard
     request columns and flags the hot shard with its skew factor.
"""

import sys
import tempfile
import time

sys.path.insert(0, "src")

from repro.api import CobraSession, OptimizerConfig
from repro.core import CostCatalog
from repro.programs import (make_m0, make_orders_customer_db, make_p0,
                            make_sales_db, make_scan, make_wilos_db)
from repro.relational.database import SLOW_REMOTE
from repro.runtime import PlanStore, ServingRuntime


def make_db():
    # all served programs are plain Python functions lifted to Region IR
    # (repro.programs) — one simulated server hosts every table they touch
    db = make_orders_customer_db(100, 5000)
    db.add_table(make_sales_db(800).table("sales"))
    wilos = make_wilos_db(2000)
    db.add_table(wilos.table("tasks"))
    db.add_table(wilos.table("roles"))
    return db


def fresh_session(store):
    return CobraSession(make_db(), CostCatalog(SLOW_REMOTE),
                        config=OptimizerConfig.preset("paper-exp1-3"),
                        plan_store=store)


def main():
    store_dir = tempfile.mkdtemp(prefix="cobra_plans_")
    store = PlanStore(store_dir)

    # ---- act 1: compile once, reuse across sessions -----------------------
    print(f"=== plan store at {store_dir} ===")
    session_a = fresh_session(store)
    session_a.compile(make_p0())
    session_a.compile(make_m0())
    print(f"session A: {session_a.memo_runs} memo run(s), "
          f"{store.puts} plan(s) persisted")

    session_b = fresh_session(store)
    exe_p0 = session_b.compile(make_p0())
    exe_m0 = session_b.compile(make_m0())
    assert exe_p0.from_cache and exe_m0.from_cache
    print(f"session B: {session_b.memo_runs} memo run(s) — both programs "
          f"warm from the store ({store.hits} hit(s))")
    print(f"  P0 plan: {exe_p0.describe()}")

    # ---- act 2: batched serving ------------------------------------------
    rt = ServingRuntime(session_b, batch_size=16, drift_threshold=3.0)
    rt.register(make_p0())
    rt.register(make_m0())
    rt.register(make_scan())

    single = rt.executable("P0").run()
    batch = rt.executable("P0").run_batch([{}] * 16)
    print(f"\n=== batched serving (slow remote network) ===")
    print(f"per-invocation P0: {single.simulated_s:6.2f}s simulated/request, "
          f"{single.n_round_trips} round trip(s) each")
    print(f"batch of 16:       {batch.simulated_s / 16:6.2f}s/request, "
          f"{batch.n_round_trips} round trip(s) total "
          f"({16 / batch.simulated_s:.1f} req/s vs "
          f"{1 / single.simulated_s:.1f} req/s)")

    responses = rt.serve([("P0", {}), ("M0", {})] * 8)
    print(f"served {len(responses)} mixed requests in {rt.batches_run} "
          f"batch(es), {rt.n_round_trips} round trips")

    # the shared SiteCache carries fetches ACROSS batches: replaying the
    # same workload touches the server zero times (one fetch per site per
    # stats epoch, invalidated by analyze()/writes — never stale)
    before = rt.n_round_trips
    rt.serve([("P0", {}), ("M0", {})] * 8)
    print(f"replayed workload: {rt.n_round_trips - before} new round "
          f"trip(s) — {rt.site_cache.describe()}")

    # the serving context changes which plan wins: one-shot SCAN keeps the
    # per-iteration aggregate query, batch-16 SCAN amortizes the prefetch
    one_shot_scan = session_b.compile(make_scan())
    served_scan = rt.executable("SCAN")
    print(f"SCAN one-shot: {one_shot_scan.describe()}")
    print(f"SCAN batch=16: {served_scan.describe()}")
    assert "prefetch" not in repr(one_shot_scan.program.body)
    assert "prefetch" in repr(served_scan.program.body), \
        "the serving context should amortize the in-while prefetch site"

    # SCAN is a while/early-exit program (plain Python `while` + `break`);
    # each request's threshold stops it after a different number of rounds,
    # respected per invocation even inside one shared batch
    scans = rt.serve([("SCAN", {"threshold": th})
                      for th in (100.0, 2e4, 1e9) * 2])
    rounds = sorted({r["state"] for r in scans})
    print(f"SCAN requests stopped after {rounds} round(s) "
          f"(per-invocation early exit inside a shared batch)")

    # ---- act 3: drift-driven re-optimization ------------------------------
    print(f"\n=== bulk load: orders 100 -> 4000 rows, no ANALYZE ===")
    grown = make_orders_customer_db(4000, 500)
    session_b.db.replace_table(grown.table("orders"))
    session_b.db.replace_table(grown.table("customer"))

    rt.serve([("P0", {})] * 8 + [("M0", {})] * 4)
    fb = rt.feedback
    print(f"feedback: {len(fb.events)} drift event(s), "
          f"{fb.refreshes} stats refresh(es), {rt.recompiles} recompile(s)")
    if fb.events:
        print(f"  first event: {fb.events[0].describe()}")
    print(f"  P0 now: {rt.executable('P0').describe()}")
    assert "prefetch" in repr(rt.executable("P0").program.body), \
        "fresh statistics should flip P0's winner to the prefetch plan"
    assert session_b.compile(make_m0()).from_cache, \
        "M0 touches only `sales` — its plan must survive the drift"
    print("  M0 plan stayed hot through the drift (per-table stats versions)")

    t = rt.telemetry()
    print(f"\ntelemetry: {t['requests_served']} requests, "
          f"{t['session_memo_runs']} memo runs total, "
          f"store {t['session_store_hits']} hit(s)/"
          f"{t['session_store_puts']} put(s)")

    # ---- act 4: hot promotion to the compiled tier ------------------------
    # a fresh runtime over the (grown) database: the first 16-request batch
    # stays interpreted (heat 16 < 24), the second crosses the threshold,
    # is lowered ONCE, and every batch from then on runs the kernel-backed
    # columnar executable — bit-identical outputs and simulated clock,
    # smaller wall clock
    print(f"\n=== compiled execution tier (compile_hot_plans=24) ===")
    session_c = fresh_session(store)
    rt_hot = ServingRuntime(session_c, batch_size=16, compile_hot_plans=24)
    rt_hot.register(make_p0())
    # an interpreter-only twin serves the IDENTICAL stream for the
    # bit-identity check (comparing early vs late batches of one stateful
    # stream would conflate tiers with site-cache warmth)
    rt_cold = ServingRuntime(fresh_session(store), batch_size=16)
    walls, tiers, hot_out, cold_out = [], [], [], []
    for _ in range(3):
        before = rt_hot.compiler.compiled_batches
        t0 = time.perf_counter()
        hot_out.extend(rt_hot.serve([("P0", {})] * 16))
        walls.append(time.perf_counter() - t0)
        tiers.append("compiled" if rt_hot.compiler.compiled_batches > before
                     else "interpreter")
    rt_cold.register(make_p0())
    for _ in range(3):
        cold_out.extend(rt_cold.serve([("P0", {})] * 16))
    for i, (wall, tier) in enumerate(zip(walls, tiers)):
        print(f"batch {i + 1}: {tier:>11s} tier, {wall * 1e3:6.1f}ms wall")
    assert tiers[0] == "interpreter" and tiers[-1] == "compiled", \
        "the pair should go hot (and stay hot) mid-stream"
    assert all(a.outputs == b.outputs and a.simulated_s == b.simulated_s
               for a, b in zip(hot_out, cold_out)), \
        "compiled and interpreted serving must be bit-identical"
    ct = rt_hot.compiler.telemetry()
    print(f"compiler: {ct['compiles']} lowering(s) "
          f"({ct['compile_s_total'] * 1e3:.1f}ms), "
          f"{ct['interpreted_batches']} interpreted / "
          f"{ct['compiled_batches']} compiled batch(es), "
          f"backend={ct['backend']}")

    # ---- act 5: observability — EXPLAIN the flipped plan, triage the fleet
    # the drift-era runtime (act 3) has served real traffic: its feedback
    # controller holds observed row/iteration counts, so EXPLAIN can show
    # estimate-vs-observed q-errors per site on the plan the swap guard
    # just accepted
    print(f"\n=== EXPLAIN the drift-flipped P0 plan ===")
    print(rt.explain("P0"))

    from repro.obs import render_triage
    rows = rt.triage()
    print(f"\n=== fleet triage (share x drift x severity) ===")
    print(render_triage(rows))
    print(f"top: {rows[0].describe()}")

    # ---- act 6: sharded cluster, skewed fleet, hot-shard triage -----------
    # tasks is hash-partitioned on t_role_id over 4 shard workers; W_E is
    # affinity-routed by its worklist key, so a request's per-key query
    # lands on the worker whose shard holds that key. Distinct keys make
    # real per-request work (repeats would just hit the SiteCache).
    from repro.cluster import ClusterRuntime
    from repro.programs import make_wilos_e

    print(f"\n=== sharded cluster: 4 workers, skewed vs uniform keys ===")
    makespans = {}
    for label, key in (("uniform", lambda i: i),
                       ("skewed", lambda i: 4 * i)):
        cl = ClusterRuntime(make_wilos_db(2000), n_workers=4,
                            partition_keys={"tasks": "t_role_id"},
                            affinity={"W_E": "worklist"},
                            deadline_s=0.01, max_batch=8)
        cl.register(make_wilos_e())
        cl.serve([("W_E", {"worklist": [key(i)]}) for i in range(48)])
        makespans[label] = cl.last_makespan_s
        served = [w.requests_served for w in cl.workers]
        print(f"{label:>8s}: worker requests {served}, "
              f"router skew {cl.router.skew():.1f}x, "
              f"makespan {cl.last_makespan_s:.2f}s simulated")
    print(f"skew costs {makespans['skewed'] / makespans['uniform']:.1f}x "
          f"the uniform makespan — and triage points at the hot shard:")
    rows = cl.triage()                      # cl is the skewed cluster
    print(render_triage(rows))
    hot = rows[0]
    assert hot.shard_requests[hot.hot_shard] == 48 and hot.skew == 4.0, \
        "every skewed key is 0 mod 4 — shard 0 must own all 48 requests"
    print(f"hot shard {hot.hot_shard} owns "
          f"{hot.shard_requests[hot.hot_shard]}/48 requests "
          f"({hot.skew:.1f}x its fair share)")


if __name__ == "__main__":
    main()
