"""Quickstart: Cobra cost-based rewriting of the Fig. 3 ORM program.

    PYTHONPATH=src python examples/quickstart.py

Builds P0 (the Hibernate N+1 program), optimizes it under two network
environments, and shows Cobra picking the join rewrite (P1) in one regime
and the prefetch rewrite (P2) in the other — then executes everything and
verifies identical results.
"""

import sys

sys.path.insert(0, "src")

from repro.core import CostCatalog, Interpreter, optimize
from repro.core.rules import default_rules
from repro.programs import make_orders_customer_db, make_p0
from repro.relational.database import ClientEnv, FAST_LOCAL, SLOW_REMOTE


def run(prog, db, net):
    env = ClientEnv(db, net)
    out = Interpreter(env, "fast").run(prog)
    return out["result"], env.clock


def main():
    paper_rules = [r for r in default_rules() if r.name != "T3"]
    for n_orders, n_cust, label in [(200, 7300, "few orders, many customers"),
                                    (20000, 1000, "many orders, few customers")]:
        db = make_orders_customer_db(n_orders, n_cust)
        p0 = make_p0()
        print(f"\n=== {label}: orders={n_orders} customers={n_cust} "
              f"(slow remote network) ===")
        r0, t0 = run(p0, db, SLOW_REMOTE)
        print(f"original P0 (N+1 selects):      {t0:8.2f}s simulated")

        res = optimize(p0, db, CostCatalog(SLOW_REMOTE), rules=paper_rules)
        r1, t1 = run(res.program, db, SLOW_REMOTE)
        kind = "P2 (prefetch)" if "prefetch" in repr(res.program.body) \
            else "P1 (SQL join)"
        print(f"Cobra chose {kind:20s}: {t1:8.2f}s "
              f"(est {res.est_cost:.2f}s, optimized in {res.opt_time_s*1e3:.0f}ms)")

        res_full = optimize(p0, db, CostCatalog(SLOW_REMOTE))
        r2, t2 = run(res_full.program, db, SLOW_REMOTE)
        print(f"Cobra, full rule set (T3∘T4j):  {t2:8.2f}s  [beyond-paper]")
        assert r0 == r1 == r2, "all rewrites must be semantics-preserving"
        print(f"results identical across all programs "
              f"({len(r0)} rows) — speedup {t0/t1:.0f}x / {t0/t2:.0f}x")


if __name__ == "__main__":
    main()
