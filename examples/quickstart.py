"""Quickstart: the `CobraSession` API on the Fig. 3 ORM program.

    PYTHONPATH=src python examples/quickstart.py

Walkthrough:

  1. Trace P0 (the Hibernate N+1 program) with ``ProgramBuilder`` — no
     hand-assembled Region IR.
  2. Open a ``CobraSession`` and ``compile()`` the program: the memo search
     runs once and the chosen plan lands in a stats-versioned plan cache.
  3. ``Executable.run()`` executes the rewritten program (execute-many).
  4. Re-compiling the same program is a cache hit; ``db.analyze()`` after a
     data change bumps the stats version and forces a fresh compilation —
     whose winning plan may flip (join ↔ prefetch) with the new stats.

Migration note: the old free function ``repro.core.optimize(program, db,
catalog)`` still works — it is now a thin shim that opens a throwaway
session per call — but it re-runs the full memo search every time. Hold a
``CobraSession`` instead to compile once and execute many.

Serving (see ``examples/serve_programs.py`` for the full walkthrough): for
high-throughput workloads, execute a BATCH of parameter bindings in one
call and persist plans across processes::

    session = CobraSession(db, catalog, plan_store="plans/")  # disk-backed
    exe = session.compile(p0)          # warm from plans/ if a prior session
                                       # compiled the same program
    batch = exe.run_batch([{}] * 64)   # one server round trip per query
                                       # site per batch — not per request
    batch[0].outputs                   # bit-identical to exe.run()

``repro.runtime.ServingRuntime`` wraps this into a request loop that also
watches observed-vs-estimated cardinalities and recompiles a program when
its tables drift (feedback-driven re-optimization).
"""

import sys

sys.path.insert(0, "src")

from repro.api import CobraSession, OptimizerConfig, ProgramBuilder
from repro.core import CostCatalog
from repro.programs import make_orders_customer_db
from repro.relational.database import SLOW_REMOTE


def trace_p0():
    """Fig. 3a, written as straight-line traced code."""
    b = ProgramBuilder("P0")
    b.relate("orders", "o_customer_sk", "customer", "c_customer_sk",
             name="customer")
    result = b.let("result", b.empty_list())
    with b.loop(b.load_all("orders"), var="o") as o:
        cust = b.let("cust", o.customer)          # ORM navigation → N+1
        val = b.let("val", b.call("myFunc", o.o_id, cust.c_birth_year))
        b.add(result, val)
    return b.build(outputs=(result,))


def main():
    p0 = trace_p0()
    for n_orders, n_cust, label in [(200, 7300, "few orders, many customers"),
                                    (20000, 1000, "many orders, few customers")]:
        db = make_orders_customer_db(n_orders, n_cust)
        session = CobraSession(db, CostCatalog(SLOW_REMOTE),
                               config=OptimizerConfig.preset("paper-exp1-3"))
        print(f"\n=== {label}: orders={n_orders} customers={n_cust} "
              f"(slow remote network) ===")

        baseline = session.execute(p0)
        print(f"original P0 (N+1 selects):      {baseline.simulated_s:8.2f}s "
              f"simulated, {baseline.n_queries} queries")

        exe = session.compile(p0)
        opt = exe.run()
        kind = "P2 (prefetch)" if "prefetch" in repr(exe.program.body) \
            else "P1 (SQL join)"
        print(f"Cobra chose {kind:20s}: {opt.simulated_s:8.2f}s "
              f"(est {exe.est_cost_s:.2f}s, optimized in "
              f"{exe.result.opt_time_s*1e3:.0f}ms)")

        # full rule set (beyond-paper T3∘T4j projection-pushed join)
        exe_full = session.compile(p0, config=OptimizerConfig.preset("full"))
        full = exe_full.run()
        print(f"Cobra, full rule set (T3∘T4j):  {full.simulated_s:8.2f}s")

        # compile-once / execute-many: second compile is a cache hit
        again = session.compile(p0)
        assert again.from_cache, "repeated compile must hit the plan cache"
        t = session.telemetry
        print(f"plan cache: {t['cache_hits']} hit(s), "
              f"{t['memo_runs']} memo run(s) for {t['compile_calls']} compiles")

        assert baseline["result"] == opt["result"] == full["result"], \
            "all rewrites must be semantics-preserving"
        print(f"results identical across all programs "
              f"({len(baseline['result'])} rows) — speedup "
              f"{baseline.simulated_s/opt.simulated_s:.0f}x / "
              f"{baseline.simulated_s/full.simulated_s:.0f}x")


if __name__ == "__main__":
    main()
