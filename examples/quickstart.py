"""Quickstart: point Cobra at plain Python code (the Fig. 3 ORM program).

    PYTHONPATH=src python examples/quickstart.py

Walkthrough:

  1. Write P0 (the Hibernate N+1 program) as an **ordinary Python
     function** — real ``for`` loops and attribute navigation, no builder
     calls — and hand it to ``session.trace``: the AST lifter compiles it
     to Region IR and the memo search picks the cheapest rewrite.
  2. ``Executable.run()`` executes the rewritten program (execute-many);
     ``run_baseline()`` runs the original for comparison.
  3. Re-compiling the same program is a plan-cache hit; after a data
     change, ``db.analyze()`` bumps the stats version and forces a fresh
     compilation — whose winning plan may flip (join ↔ prefetch).
  4. ``while`` + ``break`` (the paper's Sec. V limitations) lift too: the
     SCAN program keeps its guarded loop imperative while the aggregation
     inside it still moves into SQL.

Escape hatch: a traced function whose first parameter is named ``b`` gets
a ``ProgramBuilder`` instead (the lifter's own lowering target) — see
``repro.api.builder`` for that vocabulary.

Serving (see ``examples/serve_programs.py`` for the full walkthrough): for
high-throughput workloads, execute a BATCH of parameter bindings in one
call and persist plans across processes::

    session = CobraSession(db, catalog, plan_store="plans/")  # disk-backed
    exe = session.compile(p0)          # warm from plans/ if a prior session
                                       # compiled the same program
    batch = exe.run_batch([{}] * 64)   # one server round trip per query
                                       # site per batch — not per request
    batch[0].outputs                   # bit-identical to exe.run()
"""

import sys

sys.path.insert(0, "src")

from repro.api import CobraSession, OptimizerConfig, load_all, q, col, param
from repro.core import CostCatalog
from repro.core.regions import get_function
from repro.programs import make_orders_customer_db, make_wilos_db
from repro.relational.database import SLOW_REMOTE

myFunc = get_function("myFunc")


def p0():
    """Fig. 3a as the application would actually write it."""
    result = []
    for o in load_all("orders"):
        cust = o.customer                     # ORM navigation → N+1
        val = myFunc(o.o_id, cust.c_birth_year)
        result.append(val)
    return result


def scan(threshold=100.0, max_state=5):
    """While + early exit: per-state triage until the threshold is hit."""
    state = 0
    total = 0.0
    while state < max_state:
        s = 0.0
        for t in q("tasks").where(col("t_state").eq(param("k"))).bind(k=state):
            s = s + t.t_hours
        total = total + s
        state = state + 1
        if total > threshold:
            break
    return total, state


def main():
    for n_orders, n_cust, label in [(200, 7300, "few orders, many customers"),
                                    (20000, 1000, "many orders, few customers")]:
        db = make_orders_customer_db(n_orders, n_cust)
        session = CobraSession(db, CostCatalog(SLOW_REMOTE),
                               config=OptimizerConfig.preset("paper-exp1-3"))
        print(f"\n=== {label}: orders={n_orders} customers={n_cust} "
              f"(slow remote network) ===")

        exe = session.trace(p0, name="P0", relations=[
            ("orders", "o_customer_sk", "customer", "c_customer_sk",
             "customer")])
        baseline = exe.run_baseline()
        print(f"original P0 (N+1 selects):      {baseline.simulated_s:8.2f}s "
              f"simulated, {baseline.n_queries} queries")

        opt = exe.run()
        kind = "P2 (prefetch)" if "prefetch" in repr(exe.program.body) \
            else "P1 (SQL join)"
        print(f"Cobra chose {kind:20s}: {opt.simulated_s:8.2f}s "
              f"(est {exe.est_cost_s:.2f}s, optimized in "
              f"{exe.result.opt_time_s*1e3:.0f}ms)")

        # full rule set (beyond-paper T3∘T4j projection-pushed join)
        exe_full = session.compile(exe.source,
                                   config=OptimizerConfig.preset("full"))
        full = exe_full.run()
        print(f"Cobra, full rule set (T3∘T4j):  {full.simulated_s:8.2f}s")

        # compile-once / execute-many: second compile is a cache hit
        again = session.compile(exe.source)
        assert again.from_cache, "repeated compile must hit the plan cache"
        t = session.telemetry
        print(f"plan cache: {t['cache_hits']} hit(s), "
              f"{t['memo_runs']} memo run(s) for {t['compile_calls']} compiles")

        assert baseline["result"] == opt["result"] == full["result"], \
            "all rewrites must be semantics-preserving"
        print(f"results identical across all programs "
              f"({len(baseline['result'])} rows) — speedup "
              f"{baseline.simulated_s/opt.simulated_s:.0f}x / "
              f"{baseline.simulated_s/full.simulated_s:.0f}x")

    # ---- while + early exit (beyond the paper's builder coverage) ---------
    print("\n=== while + break: per-state SCAN over tasks ===")
    session = CobraSession(make_wilos_db(3000), CostCatalog(SLOW_REMOTE))
    exe = session.trace(scan, name="SCAN")
    base = exe.run_baseline(threshold=20000.0)
    opt = exe.run(threshold=20000.0)
    assert "scalarQuery" in repr(exe.program.body), \
        "the aggregation inside the while body should move into SQL"
    print(f"original (row-at-a-time σ loops): {base.simulated_s:6.2f}s, "
          f"stopped after {base['state']} state(s)")
    print(f"rewritten (correlated SELECT SUM): {opt.simulated_s:6.2f}s — "
          f"{exe.report.describe()}")
    assert base["state"] == opt["state"]


if __name__ == "__main__":
    main()
