PYTHON ?= python
PYTHONPATH := src

.PHONY: test test-fast lint bench-smoke bench bench-batch bench-serving \
	bench-compiled bench-obs bench-cluster bench-stats bench-compile \
	examples

# tier-1: the full suite (slow markers included)
test:
	PYTHONPATH=$(PYTHONPATH) $(PYTHON) -m pytest -x -q

# sub-60s inner loop: everything not marked slow
test-fast:
	PYTHONPATH=$(PYTHONPATH) $(PYTHON) -m pytest -x -q -m "not slow"

# static checks (pyflakes: undefined names, unused imports, shadowing)
lint:
	$(PYTHON) -m pyflakes src/repro tests benchmarks examples

# tiny-configuration pass over the benchmark drivers — catches API drift
# (the drivers import and exercise the CobraSession/compile/run surface)
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) $(PYTHON) -m benchmarks.run --smoke \
		exp_crossover exp_wilos exp_opt_time bench_runtime bench_planner

# full benchmark harness (all modules, paper-scale configurations)
bench:
	PYTHONPATH=$(PYTHONPATH) $(PYTHON) -m benchmarks.run

# batch-size sweep {1, 8, 64}: serving throughput + the ExecutionContext
# plan-flip point (which batch size makes the memo search switch winners);
# trajectory lands in BENCH_runtime.json
bench-batch:
	PYTHONPATH=$(PYTHONPATH) $(PYTHON) -m benchmarks.run bench_runtime

# serving-level SiteCache metrics: cross-batch hit rate, observed
# distinct-binding fractions, and mutating-workload (W_A) throughput under
# write-set-aware sharing — the bench_runtime driver emits them alongside
# the batch sweep, so this is an alias of bench-batch; the serving section
# lands in BENCH_runtime.json (uploaded as the existing CI artifact)
bench-serving: bench-batch

# compiled execution tier: interpreter-vs-compiled wall throughput on the
# P0-style loop-heavy workload at batch 64 + one-time lowering latency;
# the `compiled` section lands in BENCH_runtime.json (the full bench-batch
# run emits it too — this target runs ONLY that section)
bench-compiled:
	PYTHONPATH=$(PYTHONPATH) REPRO_BENCH_ONLY=compiled \
		$(PYTHON) -m benchmarks.run bench_runtime

# observability overhead: no-op tracer vs recording tracer on the P0
# batch-64 serving loop (bit-identical outputs/simulated clock either
# way); the `obs` section lands in BENCH_runtime.json and the traced
# run's span tree in BENCH_trace_sample.jsonl (uploaded as a CI artifact)
bench-obs:
	PYTHONPATH=$(PYTHONPATH) REPRO_BENCH_ONLY=obs \
		$(PYTHON) -m benchmarks.run bench_runtime

# sharded serving cluster: simulated W_E/SCAN throughput at 1 vs 2 vs 4
# shard workers, deadline-driven batch formation (burst reaches the
# batch-64 SCAN plan flip with no fixed-size batching; sparse stays on
# the per-iteration plan), and skewed-vs-uniform affinity routing with
# the triage hot-shard flag; the `cluster` section lands in
# BENCH_runtime.json (the full bench-batch run emits it too)
bench-cluster:
	PYTHONPATH=$(PYTHONPATH) REPRO_BENCH_ONLY=cluster \
		$(PYTHON) -m benchmarks.run bench_runtime

# histogram statistics subsystem: the histogram-vs-scalar selectivity
# plan flip (bit-identical outputs either way), per-site q-error before
# and after the feedback controller's targeted re-analyze, and ANALYZE
# overhead (histograms vs scalar cardinalities) at three table sizes;
# the `stats` section lands in BENCH_runtime.json (the full bench-batch
# run and the bench-smoke CI pass emit it too)
bench-stats:
	PYTHONPATH=$(PYTHONPATH) REPRO_BENCH_ONLY=stats \
		$(PYTHON) -m benchmarks.run bench_runtime

# optimizer throughput: delta-driven vs exhaustive memo saturation on the
# synthetic 10x-scale program (>=5x cold-compile saturation speedup with
# the identical winning plan and bit-identical batch outputs — the bench
# RAISES on plan divergence), the node-budget greedy fallback, and
# cross-program MemoPool hits on a serving-fleet cold start; the
# `compile` section lands in BENCH_runtime.json (the full bench-batch run
# and the bench-smoke CI pass emit it too)
bench-compile:
	PYTHONPATH=$(PYTHONPATH) REPRO_BENCH_ONLY=compile \
		$(PYTHON) -m benchmarks.run bench_runtime

examples:
	$(PYTHON) examples/quickstart.py
	$(PYTHON) examples/serve_programs.py
	$(PYTHON) examples/plan_distributed.py
