"""Aggregate the dry-run artifacts into the §Roofline table (CSV + summary).

Reads reports/dryrun/*.json produced by ``python -m repro.launch.dryrun``.
"""

from __future__ import annotations

import glob
import json
import os


def load_cells(out_dir: str = "reports/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def main(emit):
    cells = load_cells()
    if not cells:
        emit("roofline/no_dryrun_artifacts", 0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    ok = skipped = err = 0
    for c in cells:
        tag = f"roofline/{c['arch']}/{c['shape']}/{c.get('mesh')}"
        if c.get("status") == "skipped":
            skipped += 1
            emit(tag, -1, "skipped:" + c.get("reason", "")[:40])
            continue
        if c.get("status") != "ok":
            err += 1
            emit(tag, -2, "error")
            continue
        ok += 1
        rf = c.get("roofline", {})
        if rf:
            emit(tag + "/compute_s", rf["compute_s"] * 1e6, "")
            emit(tag + "/memory_s", rf["memory_s"] * 1e6, "")
            emit(tag + "/collective_s", rf["collective_s"] * 1e6, "")
            emit(tag + "/dominant", rf["dominant"],
                 f"frac={rf['roofline_fraction']:.4f};"
                 f"useful={rf['useful_flops_ratio']:.3f}")
        mem = c.get("full_compile", {}).get("memory", {})
        if mem.get("total_hbm_bytes"):
            emit(tag + "/hbm_gb", mem["total_hbm_bytes"] / 1e9 / 1,
                 f"fits_16gb={mem['total_hbm_bytes']/c['n_devices'] < 16e9}"
                 if c.get("n_devices") else "")
    emit("roofline/cells_ok", ok, f"skipped={skipped};errors={err}")
