"""Kernel micro-benchmarks: reference-path wall time on CPU (structural),
plus derived kernel roofline occupancy estimates for the TPU target.

interpret-mode Pallas timing is Python-loop bound and NOT a TPU proxy, so
the derived column reports the analytic VMEM/MXU roofline instead:
bytes touched per tile vs. FLOPs per tile at the kernel's block shape.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.analysis.roofline import HW


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(emit):
    key = jax.random.PRNGKey(0)
    # flash attention tile analysis (Bq=Bk=128, hd=128)
    Bq = Bk = 128
    hd = 128
    tile_flops = 2 * Bq * Bk * hd * 2           # qk + pv
    tile_bytes = (Bq * hd + 2 * Bk * hd) * 2 + Bq * Bk * 4
    intensity = tile_flops / tile_bytes
    emit("kernel/flash_attention/tile_intensity_flops_per_byte", intensity,
         f"mxu_bound={intensity > HW['peak_flops']/HW['hbm_bw']:.0f}")

    q = jax.random.normal(key, (1, 8, 512, 64), jnp.float32)
    k = jax.random.normal(key, (1, 8, 512, 64), jnp.float32)
    v = jax.random.normal(key, (1, 8, 512, 64), jnp.float32)
    us = _time(jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c)), q, k, v)
    emit("kernel/flash_attention/ref_512seq", us, "cpu-jnp reference")

    # rwkv6 chunked scan
    r = jax.random.normal(key, (1, 8, 512, 64))
    w = -jnp.exp(jax.random.normal(key, (1, 8, 512, 64)))
    u = jax.random.normal(key, (8, 64))
    us = _time(jax.jit(lambda a, b, c, d, e: ref.rwkv6_scan_ref(a, b, c, d, e)[0]),
               r, r, r, w, u)
    emit("kernel/rwkv6_scan/ref_512seq", us, "cpu-jnp reference")
    chunk_flops = 64 * 64 * 64 * 2 * 3
    chunk_bytes = (4 * 64 * 64) * 4 + 64 * 64 * 4
    emit("kernel/rwkv6_scan/chunk_intensity", chunk_flops / chunk_bytes, "")

    # segment reduce (γ)
    vals = jax.random.normal(key, (100000,))
    segs = jax.random.randint(key, (100000,), 0, 512)
    us = _time(jax.jit(lambda a, b: ref.segment_reduce_ref(a, b, 512)), vals, segs)
    emit("kernel/segment_reduce/ref_100k_rows", us, "cpu-jnp reference")
    onehot_flops = 2 * 256 * 512
    onehot_bytes = 256 * 4 + 512 * 4
    emit("kernel/segment_reduce/block_intensity", onehot_flops / onehot_bytes,
         "one-hot-matmul MXU form")

    # join probe
    build = jnp.arange(10000, dtype=jnp.int32)
    probe = jax.random.randint(key, (200000,), 0, 10000, dtype=jnp.int32)
    us = _time(jax.jit(lambda a, b: ref.join_probe_ref(a, b)), probe, build)
    emit("kernel/join_probe/ref_200k_probes", us, "cpu-jnp reference")
