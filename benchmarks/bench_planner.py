"""Planner validation: Cobra's analytic plan costs vs. compiled dry-run.

For each dry-run cell, compare the planner's predicted compute/collective
terms for the SAME plan the dry-run used (fsdp_tp) against the
cost_analysis-derived terms, and report the plan Cobra would pick instead
(selected through the ``CobraSession.plan_step`` facade, exercising the
shared ``PlanReport`` vocabulary). With no dry-run artifacts on disk (and
always in ``REPRO_BENCH_SMOKE=1`` mode), a small fixed cell grid keeps the
planner API exercised so drift still shows up.
"""

from __future__ import annotations

import os

from repro.api import CobraSession
from repro.configs import SHAPES
from repro.core.planner import PlanChoice, TPUCostModel, MeshShape
from repro.models.arch import get_arch
from repro.programs import make_orders_customer_db
from .bench_roofline import load_cells


def _session() -> CobraSession:
    return CobraSession(make_orders_customer_db(10, 10))


def _smoke_cells(session, emit):
    """No measured artifacts: still drive plan_step over a tiny grid."""
    for arch, kind, T, B in [("stablelm-12b", "train", 4096, 256),
                             ("rwkv6-3b", "decode", 4096, 8)]:
        rep = session.plan_step(arch, T, B, kind, mesh=(1, 16, 16))
        ch = rep.choice
        emit(f"planner/smoke/{arch}/{kind}",
             f"{ch.strategy}/r={ch.remat}/mb={ch.microbatch}/{ch.moe_mode}",
             f"est={rep.est_cost_s:.3e};alts={rep.alternatives}")


def main(emit):
    session = _session()
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    cells = [] if smoke else [c for c in load_cells()
                              if c.get("status") == "ok" and c.get("roofline")]
    if not cells:
        _smoke_cells(session, emit)
        return
    for c in cells[:80]:
        cfg = get_arch(c["arch"])
        spec = SHAPES[c["shape"]]
        mesh = MeshShape(2, 16, 16) if c["mesh"] == "2x16x16" else \
            MeshShape(1, 16, 16)
        cm = TPUCostModel(cfg, spec["seq_len"], spec["global_batch"],
                          c["kind"], mesh)
        used = PlanChoice("fsdp_tp",
                          c["policy"]["remat"], c["policy"]["microbatch"],
                          c["policy"]["seq_shard"],
                          "ep_all_to_all" if cfg.moe else "none")
        pred = cm.terms(used)
        meas = c["roofline"]
        tag = f"planner/{c['arch']}/{c['shape']}/{c['mesh']}"
        for term in ("compute_s", "collective_s"):
            p, m = pred[term], meas[term]
            ratio = p / m if m > 0 else float("inf")
            emit(f"{tag}/{term}_pred_over_meas", ratio,
                 f"pred={p:.3e};meas={m:.3e}")
        rep = session.plan_step(cfg, spec["seq_len"], spec["global_batch"],
                                c["kind"],
                                mesh=(mesh.pod, mesh.data, mesh.model))
        ch = rep.choice
        gain = pred["step_s"] / rep.est_cost_s if rep.est_cost_s > 0 else 1.0
        emit(f"{tag}/cobra_plan",
             f"{ch.strategy}/r={ch.remat}/mb={ch.microbatch}/{ch.moe_mode}",
             f"pred_speedup_vs_default={gain:.2f}x")
