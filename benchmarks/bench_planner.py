"""Planner validation: Cobra's analytic plan costs vs. compiled dry-run.

For each dry-run cell, compare the planner's predicted compute/collective
terms for the SAME plan the dry-run used (fsdp_tp) against the
cost_analysis-derived terms, and report the plan Cobra would pick instead.
"""

from __future__ import annotations

import json

from repro.configs import SHAPES
from repro.core.planner import PlanChoice, TPUCostModel, MeshShape, plan
from repro.models.arch import get_arch
from .bench_roofline import load_cells


def main(emit):
    cells = [c for c in load_cells() if c.get("status") == "ok"
             and c.get("roofline")]
    for c in cells[:80]:
        cfg = get_arch(c["arch"])
        spec = SHAPES[c["shape"]]
        mesh = MeshShape(2, 16, 16) if c["mesh"] == "2x16x16" else \
            MeshShape(1, 16, 16)
        cm = TPUCostModel(cfg, spec["seq_len"], spec["global_batch"],
                          c["kind"], mesh)
        used = PlanChoice("fsdp_tp",
                          c["policy"]["remat"], c["policy"]["microbatch"],
                          c["policy"]["seq_shard"],
                          "ep_all_to_all" if cfg.moe else "none")
        pred = cm.terms(used)
        meas = c["roofline"]
        tag = f"planner/{c['arch']}/{c['shape']}/{c['mesh']}"
        for term in ("compute_s", "collective_s"):
            p, m = pred[term], meas[term]
            ratio = p / m if m > 0 else float("inf")
            emit(f"{tag}/{term}_pred_over_meas", ratio,
                 f"pred={p:.3e};meas={m:.3e}")
        picked = plan(cfg, spec["seq_len"], spec["global_batch"], c["kind"],
                      mesh=(mesh.pod, mesh.data, mesh.model))
        ch = picked["choice"]
        gain = pred["step_s"] / picked["cost_s"] if picked["cost_s"] > 0 else 1.0
        emit(f"{tag}/cobra_plan",
             f"{ch.strategy}/r={ch.remat}/mb={ch.microbatch}/{ch.moe_mode}",
             f"pred_speedup_vs_default={gain:.2f}x")
