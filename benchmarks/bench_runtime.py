"""Serving-runtime benchmark: batch size vs throughput crossover.

For batch sizes 1/8/64, measures simulated serving throughput
(requests per simulated second) of ``Executable.run_batch`` against the
per-invocation baseline, on two workloads:

  * ``P0`` (orders/customer, slow remote network) — round-trip dominated;
    batching amortizes each query site's C_NRT across the batch, so
    throughput climbs steeply with batch size;
  * ``W_E`` (worklist-parameterized σ queries, fast local network) —
    parameter-diverse; distinct bindings still fetch, only repeats amortize.

Also sweeps ``ExecutionContext(batch_size=...)`` over {1, 8, 64} (the
``make bench-batch`` target) and records the plan each context compiles —
the batch size where the winner flips from the per-iteration query to the
amortized prefetch is the ``plan_flip_at`` point in the trajectory.

Also reports the plan-store warm-start: wall-clock of a cold ``compile()``
(memo search) vs a second session hitting the shared store directory.

The ``make bench-serving`` section exercises the serving-level shared
SiteCache: cross-batch hit rate on a repeated identical workload, observed
distinct-binding fractions per parameterized-site group, and mutating-
workload (W_A) throughput with write-set-aware sharing vs fully isolated
sequential execution.

The ``compiled`` section (``make bench-compiled``, or rides along with the
full run) records interpreter-vs-compiled-tier wall throughput on the
P0-style loop-heavy workload at batch 64 plus the one-time lowering
latency; ``REPRO_BENCH_ONLY=compiled`` runs just that section.

The ``obs`` section (``make bench-obs``; ``REPRO_BENCH_ONLY=obs`` runs
just it) measures the observability layer's wall overhead on the P0
batch-64 serving loop: the default no-op tracer vs a recording
:class:`~repro.obs.trace.Tracer`, asserting bit-identical outputs and
simulated clock either way, and exports a sample span tree to
``BENCH_trace_sample.jsonl`` (uploaded as a CI artifact).

The ``cluster`` section (``make bench-cluster``; ``REPRO_BENCH_ONLY=cluster``
runs just it) measures the sharded serving tier: simulated W_E and SCAN
throughput (requests per simulated makespan second) at 1 vs 2 vs 4 shard
workers, the deadline-driven batch former's formed sizes under burst vs
sparse arrivals — including the formation-driven SCAN plan flip, where a
burst forming size-64 batches republishes the worker context and the
serving plan flips from the per-iteration query to the batch-64 prefetch
WITHOUT any fixed-size batch config — and skewed vs uniform affinity-key
routing (hot-shard makespan + triage skew flag).

The ``compile`` section (``make bench-compile``; ``REPRO_BENCH_ONLY=compile``
runs just it) measures optimizer throughput: delta-driven vs exhaustive
memo saturation on the synthetic 10×-scale program (identical winning
plan + bit-identical batch outputs enforced — the bench raises on
divergence), the node-budget greedy fallback (``budget_exhausted`` in
``explain()``, plan still valid), and cross-program MemoPool hits on a
serving-fleet cold start.

The ``stats`` section (``make bench-stats``; ``REPRO_BENCH_ONLY=stats``
runs just it) exercises the histogram statistics subsystem: the
histogram-vs-scalar selectivity plan flip on the skewed probe workload
(with bit-identical outputs across the flip), per-site q-error before and
after the feedback controller's targeted re-analyze, and the ANALYZE wall
overhead of full histograms vs scalar cardinalities at three table sizes.

``main(emit)`` returns the trajectory dict; ``benchmarks/run.py`` writes it
to ``BENCH_runtime.json`` (uploaded as a CI workflow artifact).
"""

from __future__ import annotations

import os
import tempfile
import time

from repro.api import CobraSession, ExecutionContext, OptimizerConfig
from repro.core import CostCatalog
from repro.programs import (make_orders_customer_db, make_p0, make_scan,
                            make_wilos_a, make_wilos_db, make_wilos_e)
from repro.relational.database import FAST_LOCAL, SLOW_REMOTE
from repro.runtime import ServingRuntime, SiteCache

BATCH_SIZES = (1, 8, 64)


def _plan_kind(exe) -> str:
    body = repr(exe.program.body)
    return "prefetch" if "prefetch" in body else \
        "join" if "JOIN" in body else "query"


def _paper_session(db, network):
    return CobraSession(db, CostCatalog(network),
                        config=OptimizerConfig.preset("paper-exp1-3"))


def _throughput(exe, param_sets):
    batch = exe.run_batch(param_sets)
    return len(param_sets) / batch.simulated_s, batch


def _bench_compiled(emit, smoke):
    """Interpreter-vs-compiled tier throughput (``make bench-compiled``).

    The P0-style loop-heavy workload at batch 64: the same executable, the
    same parameter sets, served by (a) the row-at-a-time exact interpreter,
    (b) the vectorized fast interpreter, (c) the compiled tier (kernel-
    backed columnar loops). All three are bit-identical; the wall clock is
    what differs. Also records the one-time lowering latency."""
    bs = 16 if smoke else 64
    n_orders, n_cust = (300, 600) if smoke else (4000, 8000)
    session = _paper_session(make_orders_customer_db(n_orders, n_cust),
                             SLOW_REMOTE)
    exe = session.compile(make_p0())
    params = [{}] * bs

    t0 = time.perf_counter()
    lowered = exe.lower()
    lower_us = (time.perf_counter() - t0) * 1e6
    # warm every path once (imports, jit, plan analysis caches)
    exe.run_batch(params, tier="compiled")
    exe.run_batch(params, tier="interpreter")

    t0 = time.perf_counter()
    exact = exe.run_batch(params, mode="exact", tier="interpreter")
    exact_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    fast = exe.run_batch(params, tier="interpreter")
    fast_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = exe.run_batch(params, tier="compiled")
    comp_wall = time.perf_counter() - t0

    # outputs agree across all three; the CLOCK identity the tier promises
    # is vs the production (fast) interpreter — exact mode sums per-row
    # charges in a different order, so its clock carries a float tail
    identical = (exact.outputs == fast.outputs == compiled.outputs
                 and fast.simulated_s == compiled.simulated_s)
    exact_rps = bs / exact_wall
    fast_rps = bs / fast_wall
    comp_rps = bs / comp_wall
    emit("bench_runtime/compiled/P0_interpreter_exact", exact_wall * 1e6,
         f"wall_rps={exact_rps:.1f}")
    emit("bench_runtime/compiled/P0_interpreter_fast", fast_wall * 1e6,
         f"wall_rps={fast_rps:.1f}")
    emit("bench_runtime/compiled/P0_compiled", comp_wall * 1e6,
         f"wall_rps={comp_rps:.1f};backend={lowered.backend};"
         f"speedup_vs_exact={exact_rps and comp_rps / exact_rps:.1f}x;"
         f"identical={identical}")
    emit("bench_runtime/compiled/P0_lower_latency", lower_us,
         f"columnar_loops={lowered.n_columnar}")
    return {
        "workload": "P0",
        "batch_size": bs,
        "backend": lowered.backend,
        "columnar_loops": lowered.n_columnar,
        "lower_latency_us": lower_us,
        "interpreter_exact_rps": exact_rps,
        "interpreter_fast_rps": fast_rps,
        "compiled_rps": comp_rps,
        "speedup_vs_exact": comp_rps / exact_rps if exact_rps else None,
        "speedup_vs_fast": comp_rps / fast_rps if fast_rps else None,
        "bit_identical": identical,
    }


def _bench_compile(emit, smoke):
    """Delta-driven vs exhaustive memo saturation (``make bench-compile``).

    Cold-compiles the synthetic 10×-scale program (``make_synthetic`` — a
    handful of rewritable query loops buried in thousands of straight-line
    skeleton statements, the shape of real ORM business logic) under both
    schedulers and compares the ``saturate`` phase wall (best-of-N): the
    exhaustive loop rescans every memo node every round, the applicability
    index visits only nodes some rule can match. The two arms must agree
    on the winning plan key and estimated cost — the bench RAISES on
    divergence — and their compiled executables must produce bit-identical
    batch outputs. Also exercises (a) the compile budget: a node budget
    far below the program's memo size trips the greedy best-first
    fallback, which still yields a valid runnable plan with
    ``budget_exhausted`` surfaced in ``explain()``; (b) the session-scoped
    cross-program MemoPool on a serving-fleet cold start: one worker
    registering the fleet's program set replays pooled loop groups, so
    ``memo_pool_hits`` > 0 in the runtime's ``metrics_snapshot()``."""
    import dataclasses

    from repro.api.session import Executable
    from repro.core.search import run_search
    from repro.programs import make_scan, make_synthetic, make_wilos_e

    scale = 3 if smoke else 10
    stmts = 120 if smoke else 700
    n_tasks = 300 if smoke else 2000
    reps = 2 if smoke else 3
    bs = 2 if smoke else 4

    db = make_wilos_db(n_tasks, ratio=10)
    cat = CostCatalog(SLOW_REMOTE)
    t0 = time.perf_counter()
    prog = make_synthetic(scale, stmts)
    lift_us = (time.perf_counter() - t0) * 1e6

    arms = {}
    for tag, kw in (("delta", {}), ("exhaustive", {"exhaustive": True})):
        best_sat = best_total = float("inf")
        res = None
        for _ in range(reps):
            t0 = time.perf_counter()
            r = run_search(prog, db, cat, **kw)
            total = time.perf_counter() - t0
            best_total = min(best_total, total)
            best_sat = min(best_sat, r.phase_times["saturate"])
            res = r
        arms[tag] = {"result": res, "saturate_s": best_sat,
                     "total_s": best_total}
        emit(f"bench_runtime/compile/{tag}", best_total * 1e6,
             f"saturate_us={best_sat * 1e6:.0f};"
             f"nodes={res.memo_stats['and_nodes']};"
             f"alts={res.alternatives};"
             f"rounds={res.memo_stats['rounds']}")

    d, x = arms["delta"], arms["exhaustive"]
    # winning plans MUST agree — a scheduling order must never change the
    # saturated memo, so divergence here is a correctness bug, not noise
    if (d["result"].program.key() != x["result"].program.key()
            or d["result"].est_cost != x["result"].est_cost):
        raise RuntimeError(
            "delta and exhaustive saturation diverged: "
            f"delta={d['result'].program!r} (est {d['result'].est_cost}) "
            f"exhaustive={x['result'].program!r} "
            f"(est {x['result'].est_cost})")
    sat_speedup = x["saturate_s"] / max(d["saturate_s"], 1e-12)
    total_speedup = x["total_s"] / max(d["total_s"], 1e-12)

    # bit-identical execution of the two arms' winning plans
    session = CobraSession(db, cat)
    exe_d = Executable(session, prog, d["result"], from_cache=False)
    exe_x = Executable(session, prog, x["result"], from_cache=False)
    bd = exe_d.run_batch([{}] * bs)
    bx = exe_x.run_batch([{}] * bs)
    identical = (bd.outputs == bx.outputs
                 and bd.simulated_s == bx.simulated_s)
    emit("bench_runtime/compile/saturate_speedup", 0,
         f"speedup={sat_speedup:.2f}x;total={total_speedup:.2f}x;"
         f"identical_plan=True;identical_outputs={identical}")
    if not smoke and sat_speedup < 5.0:
        raise RuntimeError(
            f"delta saturation speedup {sat_speedup:.2f}x < 5x on the "
            f"10x-scale program ({x['saturate_s'] * 1e3:.1f}ms exhaustive "
            f"vs {d['saturate_s'] * 1e3:.1f}ms delta)")

    # --------------------------- compile budget -> greedy best-first
    # the greedy plan is a DIFFERENT (costlier) plan, so its float
    # accumulations may differ from the full plan's in the low bits (the
    # same reason plan swaps go through the bit guard) — validity here is
    # "runs, same shape, numerically equal", not bit equality
    def _approx(a, b, rel=1e-4):
        if isinstance(a, dict) and isinstance(b, dict):
            return (a.keys() == b.keys()
                    and all(_approx(a[k], b[k], rel) for k in a))
        if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
            return (len(a) == len(b)
                    and all(_approx(x, y, rel) for x, y in zip(a, b)))
        if isinstance(a, float) or isinstance(b, float):
            return abs(a - b) <= rel * max(1.0, abs(a), abs(b))
        return a == b

    budget_cfg = OptimizerConfig(node_budget=500)
    sess_b = CobraSession(db, cat, config=budget_cfg)
    t0 = time.perf_counter()
    exe_b = sess_b.compile(prog)
    budget_us = (time.perf_counter() - t0) * 1e6
    bb = exe_b.run_batch([{}] * bs)
    budget_valid = _approx(bb.outputs, bd.outputs)
    budget_ok = (exe_b.report.budget_exhausted
                 and "EXHAUSTED" in exe_b.explain()
                 and budget_valid)
    emit("bench_runtime/compile/budget_greedy", budget_us,
         f"budget_exhausted={exe_b.report.budget_exhausted};"
         f"est={exe_b.est_cost_s:.4g}s_vs_full={exe_d.est_cost_s:.4g}s;"
         f"valid_outputs={budget_valid}")

    # ----------------- memo-pool cross-program hits on a fleet cold start
    # one serving worker registering the fleet's program set: the two
    # synthetic variants share loop subtrees, so the second compile
    # replays pooled groups instead of re-deriving them
    fleet_session = _paper_session(make_wilos_db(n_tasks, ratio=10),
                                   SLOW_REMOTE)
    rt = ServingRuntime(fleet_session, batch_size=8, drift_threshold=1e9)
    rt.register(make_wilos_e())
    rt.register(make_scan())
    rt.register(make_synthetic(2, 25))
    rt.register(dataclasses.replace(make_synthetic(3, 25), name="SYN_B"))
    snap = rt.metrics_snapshot()
    pool_hits = int(snap.get("session_memo_pool_hits", 0))
    pool = fleet_session.telemetry
    emit("bench_runtime/compile/memo_pool_fleet", 0,
         f"hits={pool_hits};misses={pool['memo_pool_misses']};"
         f"entries={pool['memo_pool_entries']}")
    if pool_hits <= 0:
        raise RuntimeError("memo pool saw no cross-program hits on the "
                           "serving fleet cold start")

    return {
        "program": {"scale": scale, "stmts_per_loop": stmts,
                    "lift_us": lift_us,
                    "memo_nodes": d["result"].memo_stats["and_nodes"],
                    "alternatives": d["result"].alternatives},
        "delta": {"saturate_us": d["saturate_s"] * 1e6,
                  "total_us": d["total_s"] * 1e6,
                  "phase_rounds": d["result"].memo_stats.get(
                      "phase_rounds", {})},
        "exhaustive": {"saturate_us": x["saturate_s"] * 1e6,
                       "total_us": x["total_s"] * 1e6},
        "saturate_speedup": sat_speedup,
        "total_speedup": total_speedup,
        "identical_plan": True,
        "bit_identical_outputs": identical,
        "budget": {"budget_exhausted": exe_b.report.budget_exhausted,
                   "explained": budget_ok,
                   "valid_outputs": budget_valid,
                   "est_cost_s": exe_b.est_cost_s,
                   "full_est_cost_s": exe_d.est_cost_s},
        "memo_pool": {"hits": pool_hits,
                      "misses": pool["memo_pool_misses"],
                      "entries": pool["memo_pool_entries"]},
    }


def _bench_obs(emit, smoke):
    """Tracing + metrics wall overhead on the serving loop (``make
    bench-obs``).

    The same P0 batch stream served twice from identical cold starts:
    once with the default no-op tracer (the production configuration —
    one ``tracer.enabled`` branch per instrumentation point) and once
    with a recording :class:`~repro.obs.trace.Tracer`. Outputs and the
    simulated clock must be bit-identical; the wall-clock delta is the
    cost of observing. The traced run's span tree is exported to
    ``BENCH_trace_sample.jsonl``."""
    from repro.obs.trace import Tracer
    bs = 16 if smoke else 64
    n_rounds = 2 if smoke else 8
    n_trials = 2 if smoke else 7
    n_orders, n_cust = (300, 600) if smoke else (4000, 8000)

    def serve_stream(tracer):
        session = _paper_session(make_orders_customer_db(n_orders, n_cust),
                                 SLOW_REMOTE)
        if tracer is not None:
            session.tracer = tracer
        rt = ServingRuntime(session, batch_size=bs, drift_threshold=1e9)
        rt.register(make_p0())
        rt.serve([("P0", {})] * bs)  # warm plan, site cache, code paths
        outs = []
        t0 = time.perf_counter()
        for _ in range(n_rounds):
            outs.extend(rt.serve([("P0", {})] * bs))
        wall = time.perf_counter() - t0
        return wall, [r.outputs for r in outs], rt.simulated_s, rt

    # interleave trials, ALTERNATING which config runs first (CPU boost
    # decay systematically favors whichever config runs first in a pair),
    # and keep the best wall per config — the overhead fraction is a ratio
    # of small numbers, so scheduler noise dominates a single measurement
    noop_wall = traced_wall = float("inf")
    tracer = None
    for trial in range(n_trials):
        order = ("noop", "traced") if trial % 2 == 0 else ("traced", "noop")
        for which in order:
            if which == "noop":
                w, noop_out, noop_sim, _rt = serve_stream(None)
                noop_wall = min(noop_wall, w)
            else:
                tracer = Tracer()
                w, traced_out, traced_sim, rt_traced = serve_stream(tracer)
                traced_wall = min(traced_wall, w)

    identical = noop_out == traced_out and noop_sim == traced_sim
    overhead = traced_wall / noop_wall - 1.0
    n_spans = tracer.export_jsonl("BENCH_trace_sample.jsonl")
    snap = rt_traced.metrics_snapshot()

    emit("bench_runtime/obs/P0_noop_tracer", noop_wall * 1e6,
         f"wall_rps={bs * n_rounds / noop_wall:.1f}")
    emit("bench_runtime/obs/P0_traced", traced_wall * 1e6,
         f"wall_rps={bs * n_rounds / traced_wall:.1f};"
         f"overhead={overhead * 100:+.1f}%;identical={identical}")
    emit("bench_runtime/obs/trace_export", 0,
         f"spans={n_spans};file=BENCH_trace_sample.jsonl")
    return {
        "workload": "P0",
        "batch_size": bs,
        "rounds": n_rounds,
        "noop_wall_us": noop_wall * 1e6,
        "traced_wall_us": traced_wall * 1e6,
        "traced_overhead_frac": overhead,
        "bit_identical": identical,
        "spans_exported": n_spans,
        "trace_file": "BENCH_trace_sample.jsonl",
        "metrics_keys": len(snap),
    }


def _bench_cluster(emit, smoke):
    """Sharded serving cluster (``make bench-cluster``): worker scaling,
    deadline-driven batch formation, and skewed-vs-uniform routing."""
    from repro.cluster import ClusterRuntime, uniform_arrivals
    from repro.obs.triage import render_triage

    n_tasks = 300 if smoke else 2000
    n_req = 24 if smoke else 128
    out = {}

    def build(n_workers, paper=False, **kw):
        kw.setdefault("partition_keys", {"tasks": "t_role_id"})
        kw.setdefault("affinity", {"W_E": "worklist"})
        kw.setdefault("deadline_s", 0.01)
        kw.setdefault("max_batch", 8)
        if paper:
            # the SLOW_REMOTE paper catalog: round-trip-dominated costs,
            # where the SCAN batch-64 plan flip lives
            kw.setdefault("catalog", CostCatalog(SLOW_REMOTE))
            kw.setdefault("config", OptimizerConfig.preset("paper-exp1-3"))
        return ClusterRuntime(make_wilos_db(n_tasks, ratio=10),
                              n_workers=n_workers, **kw)

    # ----------------------------------- worker scaling on W_E and SCAN
    # throughput = requests per simulated MAKESPAN second. Every request
    # carries a DISTINCT worklist key (repeating keys would be absorbed by
    # the per-worker SiteCache and measure warm-up, not serving): each
    # per-key query is pruned to the key's shard, the affinity router
    # sends it to that shard's worker, so the slowest worker's clock (the
    # makespan) shrinks with the fleet
    scaling = {"W_E": {}, "SCAN": {}}
    for nw in (1, 2, 4):
        cl = build(nw)
        cl.register(make_wilos_e())
        cl.register(make_scan())
        we = [("W_E", {"worklist": [i]}) for i in range(n_req)]
        t0 = time.perf_counter()
        cl.serve(we)
        wall_us = (time.perf_counter() - t0) * 1e6
        we_rps = n_req / cl.last_makespan_s
        scaling["W_E"][str(nw)] = {
            "throughput_rps": we_rps, "makespan_s": cl.last_makespan_s,
            "worker_requests": [w.requests_served for w in cl.workers]}
        emit(f"bench_runtime/cluster/W_E/workers{nw}", wall_us,
             f"rps={we_rps:.3f};makespan={cl.last_makespan_s:.3f}s")
        # SCAN spread across workers by a varying (inert) threshold binding
        sc = [("SCAN", {"threshold": 1e9 + i}) for i in range(n_req // 2)]
        t0 = time.perf_counter()
        cl.serve(sc)
        wall_us = (time.perf_counter() - t0) * 1e6
        sc_rps = (n_req // 2) / cl.last_makespan_s
        scaling["SCAN"][str(nw)] = {
            "throughput_rps": sc_rps, "makespan_s": cl.last_makespan_s}
        emit(f"bench_runtime/cluster/SCAN/workers{nw}", wall_us,
             f"rps={sc_rps:.3f};makespan={cl.last_makespan_s:.3f}s")
    speedup = (scaling["W_E"]["4"]["throughput_rps"]
               / scaling["W_E"]["1"]["throughput_rps"])
    scaling["W_E"]["speedup_4_vs_1"] = speedup
    emit("bench_runtime/cluster/W_E/speedup_4_vs_1", 0,
         f"speedup={speedup:.2f}x")
    out["scaling"] = scaling

    # ---------------------- deadline-driven formation: the batch-64 flip
    # workers START costed for batch 1 (initial_batch_size=1 — the SCAN
    # plan is the per-iteration query). A burst arrives, the former flushes
    # size-64 batches, the worker republishes its observed formed size into
    # the serving context and recompiles: the serving plan flips to the
    # batch-64 prefetch because the former MADE batches of 64, with no
    # fixed-size batch config anywhere. (bit_guard_swaps off: the flip's
    # plan pair differs in float low bits, which the default guard vetoes;
    # feedback off: observed-iteration stats would re-cost the per-key
    # query below the prefetch and legitimately swap back — this section
    # isolates the formation->context->recompile mechanism.)
    cl = build(1, paper=True, max_batch=64, initial_batch_size=1,
               bit_guard_swaps=False, feedback=False)
    cl.register(make_scan())
    plan_before = _plan_kind(cl.workers[0].executable("SCAN"))
    burst = [("SCAN", {}) for _ in range(128 if not smoke else 64)]
    t0 = time.perf_counter()
    cl.serve(burst)                       # all arrivals at t=0: full flushes
    wall_us = (time.perf_counter() - t0) * 1e6
    plan_after = _plan_kind(cl.workers[0].executable("SCAN"))
    w = cl.workers[0]
    formation = {
        "plan_before": plan_before, "plan_after": plan_after,
        "published_batch_size": w._base_context.batch_size,
        "batch_publishes": w.batch_publishes,
        "flushes_full": cl.former.flushes_full,
        "flushes_deadline": cl.former.flushes_deadline,
        "formed_sizes": sorted(set(w._formed_sizes)),
    }
    emit("bench_runtime/cluster/formation/burst_flip", wall_us,
         f"plan={plan_before}->{plan_after};"
         f"published_batch={w._base_context.batch_size}")
    # sparse contrast: one request per deadline window keeps batches at 1,
    # so the per-iteration query plan never flips
    cl2 = build(1, paper=True, max_batch=64, initial_batch_size=1,
                bit_guard_swaps=False, feedback=False)
    cl2.register(make_scan())
    sparse = [("SCAN", {}) for _ in range(8)]
    cl2.serve(sparse, arrivals=uniform_arrivals(8, rps=10.0))
    formation["sparse_plan"] = _plan_kind(cl2.workers[0].executable("SCAN"))
    formation["sparse_flushes_deadline"] = cl2.former.flushes_deadline
    emit("bench_runtime/cluster/formation/sparse", 0,
         f"plan={formation['sparse_plan']};"
         f"deadline_flushes={cl2.former.flushes_deadline}")
    out["formation"] = formation

    # --------------------------- skewed vs uniform affinity-key routing
    # uniform: distinct keys land round-robin across the 4 workers; skewed:
    # every key is a multiple of 4, so affinity routing pins ALL of the
    # fleet's work on worker 0 and triage flags its shard
    n_roles = n_tasks // 10
    skew = {}
    for label, key in (("uniform", lambda i: i),
                       ("skewed", lambda i: 4 * (i % (n_roles // 4)))):
        cl = build(4)
        cl.register(make_wilos_e())
        reqs = [("W_E", {"worklist": [key(i)]}) for i in range(n_req)]
        cl.serve(reqs)
        rows = cl.triage()
        top = rows[0]
        skew[label] = {
            "makespan_s": cl.last_makespan_s,
            "router_skew": cl.router.skew(),
            "triage_hot_shard": top.hot_shard,
            "triage_skew": top.skew,
            "worker_requests": [w.requests_served for w in cl.workers]}
        emit(f"bench_runtime/cluster/routing/{label}", 0,
             f"makespan={cl.last_makespan_s:.3f}s;"
             f"router_skew={cl.router.skew():.2f};"
             f"hot_shard={top.hot_shard}")
        if label == "skewed" and not smoke:
            print(render_triage(rows))
    skew["makespan_ratio"] = (skew["skewed"]["makespan_s"]
                              / max(skew["uniform"]["makespan_s"], 1e-12))
    out["routing"] = skew
    return out


def _bench_stats(emit, smoke):
    """Histogram statistics subsystem (``make bench-stats``): the
    selectivity-driven plan flip, per-site q-error before/after the
    feedback controller's targeted re-analyze, and ANALYZE wall overhead
    (scalar cardinalities vs full histograms) at three table sizes."""
    from repro.core import LoopRegion, loop_site_key
    from repro.core.context import StatsProfile
    from repro.programs import make_skew_db, make_skew_probe
    from repro.relational.algebra import Cmp, Col, Param, Scan, Select
    from repro.runtime.feedback import FeedbackController
    from repro.stats import StatsConfig

    n_rows = 4000 if smoke else 20000
    out = {}

    # ------------------------------ histogram-vs-scalar selectivity flip
    # the skewed `events` probe: the scalar 1/NDV rule prices a per-key
    # fetch at N/NDV rows, so correlated per-key queries win; the
    # histogram's param_eq_fraction knows the binding is drawn from the
    # skewed data itself (the hot key dominates), so the prefetch wins —
    # and the integral payload keeps the outputs bit-identical either way
    prog = make_skew_probe()

    def probe_loop_site(region):
        if isinstance(region, LoopRegion):
            return loop_site_key(region.var, region.source)
        for c in region.children():
            s = probe_loop_site(c)
            if s is not None:
                return s
    ctx = ExecutionContext(
        batch_size=1,
        stats=StatsProfile.of({probe_loop_site(prog.body): 4.0}))
    flip = {}
    for arm, cfg in (("hist", None),
                     ("scalar", StatsConfig(histograms=False))):
        db = make_skew_db(n=n_rows, stats_config=cfg)
        sess = _paper_session(db, SLOW_REMOTE)
        t0 = time.perf_counter()
        exe = sess.compile(prog, context=ctx)
        wall_us = (time.perf_counter() - t0) * 1e6
        run = exe.run(worklist=[0, 3, 7, 11])
        flip[arm] = {"plan": _plan_kind(exe), "est_cost_s": exe.est_cost_s,
                     "rows": len(run.outputs["result"]),
                     "outputs": run.outputs}
        emit(f"bench_runtime/stats/flip/{arm}", wall_us,
             f"plan={flip[arm]['plan']};est={exe.est_cost_s:.4g}s")
    identical = flip["hist"].pop("outputs") == flip["scalar"].pop("outputs")
    emit("bench_runtime/stats/flip/identical", 0,
         f"plans={flip['scalar']['plan']}->{flip['hist']['plan']};"
         f"outputs_identical={identical}")
    out["plan_flip"] = {"scalar": flip["scalar"], "hist": flip["hist"],
                        "flipped": flip["scalar"]["plan"]
                        != flip["hist"]["plan"],
                        "outputs_identical": identical}

    # ------------------- q-error feedback: stale stats -> re-analyze
    # uniform data analyzed, then silently replaced by the skewed build (a
    # bulk load nobody ran ANALYZE after): the hot-key estimate is ~NDV×
    # off until the controller's targeted per-column re-analyze lands
    db = make_skew_db(n=n_rows, hot=0.0, seed=7)
    db.replace_table(make_skew_db(n=n_rows, hot=0.9, seed=7)
                     .table("events"))
    sess = _paper_session(db, SLOW_REMOTE)
    fb = FeedbackController(sess)
    q = Select(Cmp("==", Col("e_key"), Param("kid")), Scan("events"))

    def observe():
        result, _, _ = sess.db.run(q, {"kid": 0})
        fb.observe([(q, result.nrows, 0.0)])
        return fb.qerrors.site(q.sql()).last

    before = observe()
    hb0 = db.histogram_builds
    t0 = time.perf_counter()
    fb.refresh(["events"])
    refresh_us = (time.perf_counter() - t0) * 1e6
    after = observe()
    out["qerror"] = {
        "before": before, "after": after,
        "histogram_builds": db.histogram_builds - hb0,
        "analyzes_fired": fb.analyzes_fired,
        "refresh_us": refresh_us,
    }
    emit("bench_runtime/stats/qerror/reanalyze", refresh_us,
         f"qerror_before={before:.1f};qerror_after={after:.2f};"
         f"hist_builds={db.histogram_builds - hb0}")

    # --------------------------- ANALYZE overhead at three table sizes
    # what the richer statistics cost to maintain: wall clock of a full
    # ANALYZE with histograms+sketches vs the scalar-only baseline,
    # best-of-3 per configuration
    sizes = (500, 2000, 8000) if smoke else (2000, 20000, 100000)
    overhead = {}
    for n in sizes:
        walls = {}
        for arm, cfg in (("scalar", StatsConfig(histograms=False)),
                         ("hist", None)):
            db = make_skew_db(n=n, stats_config=cfg)
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                db.analyze("events")
                best = min(best, time.perf_counter() - t0)
            walls[arm] = best * 1e6
        overhead[str(n)] = {
            "scalar_us": walls["scalar"], "hist_us": walls["hist"],
            "overhead_x": walls["hist"] / max(walls["scalar"], 1e-3)}
        emit(f"bench_runtime/stats/analyze/rows{n}", walls["hist"],
             f"scalar_us={walls['scalar']:.0f};"
             f"overhead={overhead[str(n)]['overhead_x']:.1f}x")
    out["analyze_overhead"] = overhead
    return out


def main(emit):
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    only = os.environ.get("REPRO_BENCH_ONLY")
    n_orders, n_cust = (300, 600) if smoke else (4000, 8000)
    n_tasks = 300 if smoke else 4000

    traj = {"batch_sizes": list(BATCH_SIZES), "workloads": {}}

    # ------------------------------------------------ sharded serving tier
    if only in (None, "cluster"):
        traj["cluster"] = _bench_cluster(emit, smoke)
        if only == "cluster":
            return traj

    # --------------------------------------- histogram statistics subsystem
    if only in (None, "stats"):
        traj["stats"] = _bench_stats(emit, smoke)
        if only == "stats":
            return traj

    # ------------------------- delta vs exhaustive saturation, budget, pool
    if only in (None, "compile"):
        traj["compile"] = _bench_compile(emit, smoke)
        if only == "compile":
            return traj

    # ------------------------------------------ compiled tier vs interpreter
    if only != "obs":
        traj["compiled"] = _bench_compiled(emit, smoke)
        if only == "compiled":
            return traj

    # ----------------------------------- observability overhead + trace dump
    traj["obs"] = _bench_obs(emit, smoke)
    if only == "obs":
        return traj

    # ---------------------------------------------------------- P0 serving
    session = _paper_session(make_orders_customer_db(n_orders, n_cust),
                             SLOW_REMOTE)
    exe = session.compile(make_p0())
    base = exe.run()
    unbatched_rps = 1.0 / base.simulated_s
    curve = []
    for bs in BATCH_SIZES:
        t0 = time.perf_counter()
        rps, batch = _throughput(exe, [{}] * bs)
        wall_us = (time.perf_counter() - t0) * 1e6
        curve.append(rps)
        emit(f"bench_runtime/P0/batch{bs}", wall_us,
             f"rps={rps:.3f};round_trips={batch.n_round_trips};"
             f"speedup_vs_unbatched={rps / unbatched_rps:.1f}x")
    traj["workloads"]["P0"] = {"throughput_rps": curve,
                               "unbatched_rps": unbatched_rps,
                               "round_trips_per_site": 1}

    # --------------------------------------------------- W_E (parameterized)
    session_e = _paper_session(make_wilos_db(n_tasks, ratio=10), FAST_LOCAL)
    exe_e = session_e.compile(make_wilos_e())
    base_e = exe_e.run(worklist=[1])
    unbatched_e = 1.0 / base_e.simulated_s
    curve_e = []
    for bs in BATCH_SIZES:
        params = [{"worklist": [i % 5]} for i in range(bs)]
        t0 = time.perf_counter()
        rps, batch = _throughput(exe_e, params)
        wall_us = (time.perf_counter() - t0) * 1e6
        curve_e.append(rps)
        emit(f"bench_runtime/W_E/batch{bs}", wall_us,
             f"rps={rps:.3f};site_hits={batch.site_hits}")
    traj["workloads"]["W_E"] = {"throughput_rps": curve_e,
                                "unbatched_rps": unbatched_e}

    # --------------------------------------- context sweep: plan flip point
    # the same SCAN program compiled for batch sizes 1/8/64: C_NRT of the
    # binding-free prefetch site inside the while body amortizes across the
    # batch, so the winner flips from the per-iteration aggregate query to
    # prefetch + local aggregation at some batch size
    session_c = _paper_session(make_wilos_db(n_tasks, ratio=10), SLOW_REMOTE)
    scan = make_scan()
    plans, flip_at = {}, None
    for bs in BATCH_SIZES:
        t0 = time.perf_counter()
        exe_c = session_c.compile(scan, context=ExecutionContext(batch_size=bs))
        wall_us = (time.perf_counter() - t0) * 1e6
        kind = _plan_kind(exe_c)
        plans[str(bs)] = {"plan": kind, "est_cost_s": exe_c.est_cost_s}
        if flip_at is None and kind != plans[str(BATCH_SIZES[0])]["plan"]:
            flip_at = bs
        emit(f"bench_runtime/SCAN/context_batch{bs}", wall_us,
             f"plan={kind};est={exe_c.est_cost_s:.4g}s")
    emit("bench_runtime/SCAN/plan_flip_at", 0, f"batch_size={flip_at}")
    traj["context_plans"] = {"SCAN": plans, "plan_flip_at": flip_at}

    # -------------------------------------------- serving: shared SiteCache
    # cross-batch hit rate: the same workload served twice through one
    # runtime; the second pass is served from the first pass's fetches
    session_s = _paper_session(make_wilos_db(n_tasks, ratio=10), SLOW_REMOTE)
    rt = ServingRuntime(session_s, batch_size=8, drift_threshold=1e9)
    rt.register(make_wilos_e())
    workload = [("W_E", {"worklist": [i % 4]}) for i in range(16)]
    t0 = time.perf_counter()
    rt.serve(workload)
    first_rts = rt.n_round_trips
    first_sim = rt.simulated_s
    rt.serve(workload)
    wall_us = (time.perf_counter() - t0) * 1e6
    cstats = rt.site_cache.stats()
    second_rts = rt.n_round_trips - first_rts
    second_sim = rt.simulated_s - first_sim
    lookups = cstats["hits"] + cstats["misses"]
    # cross-batch rate counts ONLY hits served by an earlier batch's fetch
    # (in-batch repeats would overstate the cross-batch sharing)
    cross_rate = cstats["shared_hits"] / lookups if lookups else 0.0
    fb = rt.feedback.telemetry()
    fractions = {site: s["published"]
                 for site, s in fb["binding_sites"].items()}
    emit("bench_runtime/serving/cross_batch", wall_us,
         f"cross_batch_hit_rate={cross_rate:.3f};"
         f"overall_hit_rate={cstats['hit_rate']:.3f};"
         f"shared_hits={cstats['shared_hits']};"
         f"second_pass_round_trips={second_rts}")
    traj["serving"] = {
        "cross_batch_hit_rate": cross_rate,
        "overall_hit_rate": cstats["hit_rate"],
        "shared_hits": cstats["shared_hits"],
        "first_pass_round_trips": first_rts,
        "second_pass_round_trips": second_rts,
        "first_pass_simulated_s": first_sim,
        "second_pass_simulated_s": second_sim,
        "binding_fractions": fractions,
        "context_recompiles": rt.context_recompiles,
    }

    # mutating workload (W_A: updates roles, reads tasks): write-set-aware
    # site sharing vs fully isolated per-invocation execution
    n_mut = 4 if smoke else 8
    sess_shared = _paper_session(make_wilos_db(n_tasks // 2, ratio=10),
                                 SLOW_REMOTE)
    exe_shared = sess_shared.compile(make_wilos_a())
    t0 = time.perf_counter()
    shared_batch = exe_shared.run_batch([{}] * n_mut,
                                        site_cache=SiteCache())
    wall_us = (time.perf_counter() - t0) * 1e6
    shared_rps = n_mut / shared_batch.simulated_s
    sess_iso = _paper_session(make_wilos_db(n_tasks // 2, ratio=10),
                              SLOW_REMOTE)
    exe_iso = sess_iso.compile(make_wilos_a())
    iso_s = sum(exe_iso.run().simulated_s for _ in range(n_mut))
    iso_rps = n_mut / iso_s
    emit("bench_runtime/serving/mutating_WA", wall_us,
         f"rps={shared_rps:.3f};isolated_rps={iso_rps:.3f};"
         f"site_hits={shared_batch.site_hits}")
    traj["serving"]["mutating"] = {
        "workload": "W_A",
        "throughput_rps": shared_rps,
        "isolated_rps": iso_rps,
        "site_hits": shared_batch.site_hits,
        "speedup": shared_rps / iso_rps if iso_rps else None,
    }

    # ------------------------------------------------- plan-store warm start
    with tempfile.TemporaryDirectory() as store_dir:
        t0 = time.perf_counter()
        cold = CobraSession(make_orders_customer_db(n_orders, n_cust),
                            CostCatalog(SLOW_REMOTE),
                            config=OptimizerConfig.preset("paper-exp1-3"),
                            plan_store=store_dir)
        cold.compile(make_p0())
        cold_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        warm = CobraSession(make_orders_customer_db(n_orders, n_cust),
                            CostCatalog(SLOW_REMOTE),
                            config=OptimizerConfig.preset("paper-exp1-3"),
                            plan_store=store_dir)
        hit = warm.compile(make_p0())
        warm_us = (time.perf_counter() - t0) * 1e6
    emit("bench_runtime/store/cold_compile", cold_us, "memo_search=1")
    emit("bench_runtime/store/warm_compile", warm_us,
         f"from_store={hit.from_cache};"
         f"speedup={cold_us / max(warm_us, 1e-3):.0f}x")
    traj["store"] = {"cold_compile_us": cold_us, "warm_compile_us": warm_us}
    return traj


if __name__ == "__main__":
    import json
    import sys

    sys.path.insert(0, "src")
    out = main(lambda n, v, d="": print(f"{n},{v},{d}"))
    print(json.dumps(out, indent=1))
