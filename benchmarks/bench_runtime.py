"""Serving-runtime benchmark: batch size vs throughput crossover.

For batch sizes 1/8/64, measures simulated serving throughput
(requests per simulated second) of ``Executable.run_batch`` against the
per-invocation baseline, on two workloads:

  * ``P0`` (orders/customer, slow remote network) — round-trip dominated;
    batching amortizes each query site's C_NRT across the batch, so
    throughput climbs steeply with batch size;
  * ``W_E`` (worklist-parameterized σ queries, fast local network) —
    parameter-diverse; distinct bindings still fetch, only repeats amortize.

Also sweeps ``ExecutionContext(batch_size=...)`` over {1, 8, 64} (the
``make bench-batch`` target) and records the plan each context compiles —
the batch size where the winner flips from the per-iteration query to the
amortized prefetch is the ``plan_flip_at`` point in the trajectory.

Also reports the plan-store warm-start: wall-clock of a cold ``compile()``
(memo search) vs a second session hitting the shared store directory.

``main(emit)`` returns the trajectory dict; ``benchmarks/run.py`` writes it
to ``BENCH_runtime.json`` (uploaded as a CI workflow artifact).
"""

from __future__ import annotations

import os
import tempfile
import time

from repro.api import CobraSession, ExecutionContext, OptimizerConfig
from repro.core import CostCatalog
from repro.programs import (make_orders_customer_db, make_p0, make_scan,
                            make_wilos_db, make_wilos_e)
from repro.relational.database import FAST_LOCAL, SLOW_REMOTE

BATCH_SIZES = (1, 8, 64)


def _plan_kind(exe) -> str:
    body = repr(exe.program.body)
    return "prefetch" if "prefetch" in body else \
        "join" if "JOIN" in body else "query"


def _paper_session(db, network):
    return CobraSession(db, CostCatalog(network),
                        config=OptimizerConfig.preset("paper-exp1-3"))


def _throughput(exe, param_sets):
    batch = exe.run_batch(param_sets)
    return len(param_sets) / batch.simulated_s, batch


def main(emit):
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    n_orders, n_cust = (300, 600) if smoke else (4000, 8000)
    n_tasks = 300 if smoke else 4000

    traj = {"batch_sizes": list(BATCH_SIZES), "workloads": {}}

    # ---------------------------------------------------------- P0 serving
    session = _paper_session(make_orders_customer_db(n_orders, n_cust),
                             SLOW_REMOTE)
    exe = session.compile(make_p0())
    base = exe.run()
    unbatched_rps = 1.0 / base.simulated_s
    curve = []
    for bs in BATCH_SIZES:
        t0 = time.perf_counter()
        rps, batch = _throughput(exe, [{}] * bs)
        wall_us = (time.perf_counter() - t0) * 1e6
        curve.append(rps)
        emit(f"bench_runtime/P0/batch{bs}", wall_us,
             f"rps={rps:.3f};round_trips={batch.n_round_trips};"
             f"speedup_vs_unbatched={rps / unbatched_rps:.1f}x")
    traj["workloads"]["P0"] = {"throughput_rps": curve,
                               "unbatched_rps": unbatched_rps,
                               "round_trips_per_site": 1}

    # --------------------------------------------------- W_E (parameterized)
    session_e = _paper_session(make_wilos_db(n_tasks, ratio=10), FAST_LOCAL)
    exe_e = session_e.compile(make_wilos_e())
    base_e = exe_e.run(worklist=[1])
    unbatched_e = 1.0 / base_e.simulated_s
    curve_e = []
    for bs in BATCH_SIZES:
        params = [{"worklist": [i % 5]} for i in range(bs)]
        t0 = time.perf_counter()
        rps, batch = _throughput(exe_e, params)
        wall_us = (time.perf_counter() - t0) * 1e6
        curve_e.append(rps)
        emit(f"bench_runtime/W_E/batch{bs}", wall_us,
             f"rps={rps:.3f};site_hits={batch.site_hits}")
    traj["workloads"]["W_E"] = {"throughput_rps": curve_e,
                                "unbatched_rps": unbatched_e}

    # --------------------------------------- context sweep: plan flip point
    # the same SCAN program compiled for batch sizes 1/8/64: C_NRT of the
    # binding-free prefetch site inside the while body amortizes across the
    # batch, so the winner flips from the per-iteration aggregate query to
    # prefetch + local aggregation at some batch size
    session_c = _paper_session(make_wilos_db(n_tasks, ratio=10), SLOW_REMOTE)
    scan = make_scan()
    plans, flip_at = {}, None
    for bs in BATCH_SIZES:
        t0 = time.perf_counter()
        exe_c = session_c.compile(scan, context=ExecutionContext(batch_size=bs))
        wall_us = (time.perf_counter() - t0) * 1e6
        kind = _plan_kind(exe_c)
        plans[str(bs)] = {"plan": kind, "est_cost_s": exe_c.est_cost_s}
        if flip_at is None and kind != plans[str(BATCH_SIZES[0])]["plan"]:
            flip_at = bs
        emit(f"bench_runtime/SCAN/context_batch{bs}", wall_us,
             f"plan={kind};est={exe_c.est_cost_s:.4g}s")
    emit("bench_runtime/SCAN/plan_flip_at", 0, f"batch_size={flip_at}")
    traj["context_plans"] = {"SCAN": plans, "plan_flip_at": flip_at}

    # ------------------------------------------------- plan-store warm start
    with tempfile.TemporaryDirectory() as store_dir:
        t0 = time.perf_counter()
        cold = CobraSession(make_orders_customer_db(n_orders, n_cust),
                            CostCatalog(SLOW_REMOTE),
                            config=OptimizerConfig.preset("paper-exp1-3"),
                            plan_store=store_dir)
        cold.compile(make_p0())
        cold_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        warm = CobraSession(make_orders_customer_db(n_orders, n_cust),
                            CostCatalog(SLOW_REMOTE),
                            config=OptimizerConfig.preset("paper-exp1-3"),
                            plan_store=store_dir)
        hit = warm.compile(make_p0())
        warm_us = (time.perf_counter() - t0) * 1e6
    emit("bench_runtime/store/cold_compile", cold_us, "memo_search=1")
    emit("bench_runtime/store/warm_compile", warm_us,
         f"from_store={hit.from_cache};"
         f"speedup={cold_us / max(warm_us, 1e-3):.0f}x")
    traj["store"] = {"cold_compile_us": cold_us, "warm_compile_us": warm_us}
    return traj


if __name__ == "__main__":
    import json
    import sys

    sys.path.insert(0, "src")
    out = main(lambda n, v, d="": print(f"{n},{v},{d}"))
    print(json.dumps(out, indent=1))
