"""Experiment 4 (Fig. 14/15): the six Wilos patterns A–F.

Bars per pattern: Original, Heuristic ([4]: maximal SQL push, no prefetch),
Cobra(AF=1), Cobra(AF=50). Setup mirrors the paper: fast local network,
many-to-one ratio 10:1, ~20% selectivity; relation size scaled 1M → 20k for
CPU wall-time (times are simulated; ratios are scale-stable).

Each bar family shares a ``CobraSession``; the heuristic and the two AF
settings are per-compile config/catalog overrides rather than separate
entry points. ``REPRO_BENCH_SMOKE=1`` shrinks the relation size.
"""

from __future__ import annotations

import os

from repro.api import CobraSession, OptimizerConfig
from repro.core import CostCatalog
from repro.programs import WILOS_PROGRAMS, make_wilos_db
from repro.relational.database import FAST_LOCAL

N_BIG = 4000


def _n_big() -> int:
    return 400 if os.environ.get("REPRO_BENCH_SMOKE") == "1" else N_BIG


def wilos_rows():
    rows = []
    for pid, maker in WILOS_PROGRAMS.items():
        params = {"worklist": [1, 3, 5, 7, 9, 11]} if pid == "E" else {}
        prog = maker()

        def fresh():
            return CobraSession(make_wilos_db(_n_big(), ratio=10),
                                CostCatalog(FAST_LOCAL))

        t_orig = fresh().execute(prog, **params).simulated_s
        exe_h = fresh().compile(prog,
                                config=OptimizerConfig.preset("heuristic"))
        t_heur = exe_h.run(**params).simulated_s
        out = {"pattern": pid, "original_s": t_orig, "heuristic_s": t_heur}
        for af in (1.0, 50.0):
            exe_c = fresh().compile(prog, catalog=CostCatalog(FAST_LOCAL, af=af))
            out[f"cobra_af{int(af)}_s"] = exe_c.run(**params).simulated_s
        out["cobra_never_worse"] = (
            out["cobra_af50_s"] <= min(t_orig, t_heur) * 1.05
            or out["cobra_af1_s"] <= min(t_orig, t_heur) * 1.05)
        rows.append(out)
    return rows


def main(emit):
    for row in wilos_rows():
        tag = f"exp_wilos/{row['pattern']}"
        base = row["original_s"]
        for k in ("original_s", "heuristic_s", "cobra_af1_s", "cobra_af50_s"):
            frac = row[k] / base if base else 0.0
            emit(f"{tag}/{k}", row[k] * 1e6, f"frac_of_original={frac:.3f}")
        emit(f"{tag}/never_worse", int(row["cobra_never_worse"]), "bool")
