"""Experiment 4 (Fig. 14/15): the six Wilos patterns A–F.

Bars per pattern: Original, Heuristic ([4]: maximal SQL push, no prefetch),
Cobra(AF=1), Cobra(AF=50). Setup mirrors the paper: fast local network,
many-to-one ratio 10:1, ~20% selectivity; relation size scaled 1M → 20k for
CPU wall-time (times are simulated; ratios are scale-stable).
"""

from __future__ import annotations

from repro.core import CostCatalog, Interpreter, optimize
from repro.programs import WILOS_PROGRAMS, make_wilos_db
from repro.relational.database import ClientEnv, FAST_LOCAL

N_BIG = 4000


def run_program(prog, db, init=None):
    env = ClientEnv(db, FAST_LOCAL)
    Interpreter(env, "fast").run(prog, init)
    return env.clock


def wilos_rows():
    rows = []
    for pid, maker in WILOS_PROGRAMS.items():
        init = {"worklist": [1, 3, 5, 7, 9, 11]} if pid == "E" else None
        prog = maker()

        def fresh():
            return make_wilos_db(N_BIG, ratio=10)

        t_orig = run_program(prog, fresh(), init)
        res_h = optimize(prog, fresh(), CostCatalog(FAST_LOCAL),
                         choice="heuristic")
        t_heur = run_program(res_h.program, fresh(), init)
        out = {"pattern": pid, "original_s": t_orig, "heuristic_s": t_heur}
        for af in (1.0, 50.0):
            res_c = optimize(prog, fresh(), CostCatalog(FAST_LOCAL, af=af))
            t_c = run_program(res_c.program, fresh(), init)
            out[f"cobra_af{int(af)}_s"] = t_c
        out["cobra_never_worse"] = (
            out["cobra_af50_s"] <= min(t_orig, t_heur) * 1.05
            or out["cobra_af1_s"] <= min(t_orig, t_heur) * 1.05)
        rows.append(out)
    return rows


def main(emit):
    for row in wilos_rows():
        tag = f"exp_wilos/{row['pattern']}"
        base = row["original_s"]
        for k in ("original_s", "heuristic_s", "cobra_af1_s", "cobra_af50_s"):
            frac = row[k] / base if base else 0.0
            emit(f"{tag}/{k}", row[k] * 1e6, f"frac_of_original={frac:.3f}")
        emit(f"{tag}/never_worse", int(row["cobra_never_worse"]), "bool")
