"""Benchmark harness — one module per paper table/figure.

  exp_crossover  Fig. 13 a/b/c  (P0/P1/P2 crossover + Cobra's choice)
  exp_wilos      Fig. 14/15     (Wilos patterns A–F, 4 bars each)
  exp_opt_time   Sec. VIII      (optimization time < 1 s + plan-cache hit)
  bench_runtime  serving runtime: batch-size/throughput crossover +
                 plan-store warm start (beyond-paper)
  bench_kernels  kernel tile/roofline analysis + CPU reference timings
  bench_roofline §Roofline table from dry-run artifacts
  bench_planner  planner-vs-XLA validation (beyond-paper)

Usage::

    python -m benchmarks.run [--smoke] [module ...]

``--smoke`` sets ``REPRO_BENCH_SMOKE=1`` before importing the drivers,
shrinking every workload to a seconds-long configuration — the CI guard
against API drift in the benchmark drivers (``make bench-smoke``). With no
module arguments all modules run.

Prints ``name,us_per_call,derived`` CSV. A module whose ``main(emit)``
returns a dict additionally gets that trajectory written to
``BENCH_<module>.json`` (e.g. ``BENCH_runtime.json`` with throughput at
batch sizes 1/8/64).
"""

import json
import os
import sys
import time


def emit(name, value, derived=""):
    print(f"{name},{value},{derived}", flush=True)


def main() -> None:
    args = sys.argv[1:]
    if "--smoke" in args:
        args.remove("--smoke")
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    from . import (bench_kernels, bench_planner, bench_roofline,
                   bench_runtime, exp_crossover, exp_opt_time, exp_wilos)
    mods = {"exp_crossover": exp_crossover, "exp_wilos": exp_wilos,
            "exp_opt_time": exp_opt_time, "bench_runtime": bench_runtime,
            "bench_kernels": bench_kernels,
            "bench_roofline": bench_roofline, "bench_planner": bench_planner}
    unknown = [a for a in args if a not in mods]
    if unknown:
        print(f"unknown module(s) {unknown}; available: {sorted(mods)}",
              file=sys.stderr)
        sys.exit(2)
    selected = args or list(mods)
    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        mod = mods[name]
        t0 = time.time()
        try:
            trajectory = mod.main(emit)
            emit(f"{name}/__total_s", (time.time() - t0) * 1e6, "harness")
            if isinstance(trajectory, dict):
                out = f"BENCH_{name.replace('bench_', '')}.json"
                with open(out, "w") as f:
                    json.dump(trajectory, f, indent=1, sort_keys=True)
                emit(f"{name}/__trajectory", 0, out)
        except Exception as e:  # keep the harness going
            failures += 1
            emit(f"{name}/__error", 0, repr(e)[:120])
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
