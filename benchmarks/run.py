"""Benchmark harness — one module per paper table/figure.

  exp_crossover  Fig. 13 a/b/c  (P0/P1/P2 crossover + Cobra's choice)
  exp_wilos      Fig. 14/15     (Wilos patterns A–F, 4 bars each)
  exp_opt_time   Sec. VIII      (optimization time < 1 s)
  bench_kernels  kernel tile/roofline analysis + CPU reference timings
  bench_roofline §Roofline table from dry-run artifacts
  bench_planner  planner-vs-XLA validation (beyond-paper)

Prints ``name,us_per_call,derived`` CSV.
"""

import sys
import time


def emit(name, value, derived=""):
    print(f"{name},{value},{derived}", flush=True)


def main() -> None:
    from . import (bench_kernels, bench_planner, bench_roofline,
                   exp_crossover, exp_opt_time, exp_wilos)
    mods = {"exp_crossover": exp_crossover, "exp_wilos": exp_wilos,
            "exp_opt_time": exp_opt_time, "bench_kernels": bench_kernels,
            "bench_roofline": bench_roofline, "bench_planner": bench_planner}
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in mods.items():
        if only and name != only:
            continue
        t0 = time.time()
        try:
            mod.main(emit)
            emit(f"{name}/__total_s", (time.time() - t0) * 1e6, "harness")
        except Exception as e:  # keep the harness going
            emit(f"{name}/__error", 0, repr(e)[:120])


if __name__ == '__main__':
    main()
