"""Experiments 1–3 (Fig. 13): P0/P1/P2 crossover under varying network and
cardinalities, plus Cobra's cost-based choice.

The paper's alternative space for these experiments is {P0, P1, P2}
(generated with N1 + a T5 variation); we therefore use the
``paper-exp1-3`` config preset (no T3) for the faithful row, and ALSO
report the full-rule-set Cobra (beyond-paper: T3∘T4j projection-pushed
join) separately. All rows go through one ``CobraSession`` per database so
the faithful and full-rule compilations share the plan-cache machinery the
serving path uses.

``REPRO_BENCH_SMOKE=1`` (set by ``benchmarks/run.py --smoke``) shrinks the
cardinality sweep to a seconds-long API-drift check.
"""

from __future__ import annotations

import os

from repro.api import CobraSession, OptimizerConfig
from repro.core import CostCatalog
from repro.programs import make_orders_customer_db, make_p0, make_p1, make_p2
from repro.relational.database import FAST_LOCAL, SLOW_REMOTE


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


def crossover_rows(env_name: str, sweep: str = "orders"):
    net = SLOW_REMOTE if env_name == "slow_remote" else FAST_LOCAL
    rows = []
    if sweep == "orders":
        # Experiment 1/2: customers fixed (scaled-down 73k → 7300 for CPU
        # runtime; the crossover structure is cardinality-RATIO driven)
        n_cust = 730 if _smoke() else 7300
        order_counts = [100, 2000] if _smoke() else \
            [100, 1000, 5000, 20000, 100000]
        cases = [(n, n_cust) for n in order_counts]
    else:
        # Experiment 3: orders fixed at 10k (scaled 1k), vary customers
        cases = [(200, c) for c in [500, 4000]] if _smoke() else \
            [(1000, c) for c in [500, 2000, 8000, 32000]]

    for n_orders, n_cust in cases:
        db = make_orders_customer_db(n_orders, n_cust)
        session = CobraSession(db, CostCatalog(net),
                               config=OptimizerConfig.preset("paper-exp1-3"))
        t0 = session.execute(make_p0()).simulated_s if n_orders <= 20000 else None
        t1 = session.execute(make_p1()).simulated_s
        t2 = session.execute(make_p2()).simulated_s
        exe = session.compile(make_p0())
        t_cobra = exe.run().simulated_s
        body = repr(exe.program.body)
        pick = "P2" if "prefetch" in body else ("P1" if "JOIN" in body else "P0")
        exe_full = session.compile(make_p0(),
                                   config=OptimizerConfig.preset("full"))
        t_full = exe_full.run().simulated_s
        correct = t_cobra <= min(x for x in (t0, t1, t2) if x is not None) * 1.02
        rows.append({
            "env": env_name, "orders": n_orders, "customers": n_cust,
            "P0_s": t0, "P1_s": t1, "P2_s": t2,
            "cobra_pick": pick, "cobra_s": t_cobra,
            "cobra_correct": correct,
            "cobra_fullrules_s": t_full,
        })
    return rows


def main(emit):
    for env in ("slow_remote", "fast_local"):
        for row in crossover_rows(env, "orders"):
            tag = f"exp_crossover/{row['env']}/o{row['orders']}_c{row['customers']}"
            emit(tag + "/pick", row["cobra_pick"],
                 f"correct={row['cobra_correct']}")
            for k in ("P0_s", "P1_s", "P2_s", "cobra_s", "cobra_fullrules_s"):
                if row[k] is not None:
                    emit(tag + "/" + k, row[k] * 1e6, "simulated")
    for row in crossover_rows("slow_remote", "customers"):
        tag = f"exp3/c{row['customers']}"
        emit(tag + "/pick", row["cobra_pick"], f"correct={row['cobra_correct']}")
