"""Experiments 1–3 (Fig. 13): P0/P1/P2 crossover under varying network and
cardinalities, plus Cobra's cost-based choice.

The paper's alternative space for these experiments is {P0, P1, P2}
(generated with N1 + a T5 variation); we therefore restrict the rule set to
exclude T3 for the faithful row, and ALSO report the full-rule-set Cobra
(beyond-paper: T3∘T4j projection-pushed join) separately.
"""

from __future__ import annotations

import time

from repro.core import CostCatalog, Interpreter, optimize
from repro.core.rules import default_rules
from repro.programs import make_orders_customer_db, make_p0, make_p1, make_p2
from repro.relational.database import ClientEnv, FAST_LOCAL, SLOW_REMOTE


def run_program(prog, db, net, init=None):
    env = ClientEnv(db, net)
    Interpreter(env, "fast").run(prog, init)
    return env.clock


def paper_rules():
    return [r for r in default_rules() if r.name != "T3"]


def crossover_rows(env_name: str, sweep: str = "orders"):
    net = SLOW_REMOTE if env_name == "slow_remote" else FAST_LOCAL
    rows = []
    if sweep == "orders":
        # Experiment 1/2: customers fixed (scaled-down 73k → 7300 for CPU
        # runtime; the crossover structure is cardinality-RATIO driven)
        n_cust = 7300
        order_counts = [100, 1000, 5000, 20000, 100000]
        cases = [(n, n_cust) for n in order_counts]
    else:
        # Experiment 3: orders fixed at 10k (scaled 1k), vary customers
        cases = [(1000, c) for c in [500, 2000, 8000, 32000]]

    for n_orders, n_cust in cases:
        db = make_orders_customer_db(n_orders, n_cust)
        t0 = run_program(make_p0(), db, net) if n_orders <= 20000 else None
        t1 = run_program(make_p1(), db, net)
        t2 = run_program(make_p2(), db, net)
        res = optimize(make_p0(), db, CostCatalog(net), rules=paper_rules())
        t_cobra = run_program(res.program, db, net)
        body = repr(res.program.body)
        pick = "P2" if "prefetch" in body else ("P1" if "JOIN" in body else "P0")
        res_full = optimize(make_p0(), db, CostCatalog(net))
        t_full = run_program(res_full.program, db, net)
        correct = t_cobra <= min(x for x in (t0, t1, t2) if x is not None) * 1.02
        rows.append({
            "env": env_name, "orders": n_orders, "customers": n_cust,
            "P0_s": t0, "P1_s": t1, "P2_s": t2,
            "cobra_pick": pick, "cobra_s": t_cobra,
            "cobra_correct": correct,
            "cobra_fullrules_s": t_full,
        })
    return rows


def main(emit):
    for env in ("slow_remote", "fast_local"):
        for row in crossover_rows(env, "orders"):
            tag = f"exp_crossover/{row['env']}/o{row['orders']}_c{row['customers']}"
            emit(tag + "/pick", row["cobra_pick"],
                 f"correct={row['cobra_correct']}")
            for k in ("P0_s", "P1_s", "P2_s", "cobra_s", "cobra_fullrules_s"):
                if row[k] is not None:
                    emit(tag + "/" + k, row[k] * 1e6, "simulated")
    for row in crossover_rows("slow_remote", "customers"):
        tag = f"exp3/c{row['customers']}"
        emit(tag + "/pick", row["cobra_pick"], f"correct={row['cobra_correct']}")
