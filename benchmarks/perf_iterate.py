"""§Perf hillclimbing harness: hypothesis → change → re-lower → measure.

Run standalone (it forks a 512-device subprocess per variant so the XLA
device flag never leaks):

    PYTHONPATH=src python -m benchmarks.perf_iterate --pair kimi_train

Each pair has a list of (variant name, hypothesis, policy change). Results
land in reports/perf/<pair>.json: before/after roofline terms per variant,
confirmed/refuted per the recorded hypothesis.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

# (arch, shape) → list of variants: (name, hypothesis, kwargs for run_cell)
# The three hillclimbed pairs (§Perf):
#   kimi_train   — most collective-bound (869s ICI) AND most representative
#                  of the paper's technique: the FSDP-regather-vs-own
#                  decision is Cobra's N1 (prefetch/cache) analogue, and the
#                  expert dispatch is T4 (batch lookups into a join).
#   llama4_train — worst roofline fraction among train cells (0.012,
#                  memory-dominated MoE dispatch traffic).
#   rwkv_decode  — the only collective-dominant decode cell (weight
#                  regathers sit on a tiny-compute critical path).
PAIRS = {
    "kimi_train": ("kimi-k2-1t-a32b", "train_4k", [
        ("baseline_fsdp_tp",
         "baseline: FSDP regathers 8.5GB of expert weights per MoE layer "
         "per direction → collective-bound (measured 869s)", {}),
        ("ep_owned",
         "napkin: per layer, regather moves E/16·3·d·ff_moe·2B ≈ 8.5GB "
         "but the (E/16,C,d) activation buffer is only ≈ 0.6GB → owning "
         "experts (E on model × ffn on data) and reducing activations "
         "instead should cut the collective term ≈ 10×",
         {"strategy": "fsdp_tp_ep"}),
        ("ep_remat_dots",
         "with collectives down, remat=full recompute traffic may bound; "
         "dots-policy remat re-reads less",
         {"strategy": "fsdp_tp_ep", "remat": "dots"}),
        ("ep_remat_none",
         "remat off entirely: compute floor; memory_analysis tells whether "
         "activations still fit at mb=8",
         {"strategy": "fsdp_tp_ep", "remat": "none"}),
    ]),
    "llama4_train": ("llama4-scout-17b-a16e", "train_4k", [
        ("baseline_fsdp_tp",
         "baseline: memory term 176s — scatter/gather dispatch traffic "
         "plus remat=full re-reads dominate", {}),
        ("ep_owned",
         "same EP ownership as kimi: kill the per-layer expert regather "
         "(16e × 3·5120·8192·2B ≈ 1.3GB/layer/dir)",
         {"strategy": "fsdp_tp_ep"}),
        ("ep_remat_dots",
         "dots remat: recompute only matmuls, halve activation re-reads",
         {"strategy": "fsdp_tp_ep", "remat": "dots"}),
        ("ep_mb4",
         "fewer microbatches → fewer dispatch scatter passes over HBM per "
         "step at larger per-pass buffers",
         {"strategy": "fsdp_tp_ep", "microbatch": 4}),
    ]),
    # BONUS pair (beyond the required three): the planner's analytic model
    # predicts pure FSDP beats fsdp_tp for a 12B dense model at TP=16
    # (per-layer activation all-reduces cost more than the spread-out
    # regather) — test that prediction against the compiled artifact.
    "stablelm_train": ("stablelm-12b", "train_4k", [
        ("baseline_fsdp_tp",
         "baseline: TP(16) pays 4 all-reduces/layer of B_loc·T·d bytes", {}),
        ("fsdp_only",
         "planner prediction: drop TP — no per-layer activation "
         "all-reduces; 12B × 10B/param / 256 chips ≈ 0.5GB/chip resident",
         {"strategy": "fsdp"}),
        ("tp_only",
         "counter-hypothesis: TP keeps weights resident (1.5GB/chip), "
         "trades regather for activation all-reduces", {"strategy": "tp"}),
    ]),
    "rwkv_decode": ("rwkv6-3b", "decode_32k", [
        ("baseline_fsdp_tp",
         "baseline: collective 15.0ms > memory 4.9ms — per-step FSDP "
         "weight gathers sit on the decode critical path", {}),
        ("tp_only",
         "N1 analogue (gather once = keep resident): TP shards 3B params "
         "to 375MB/chip, removing the per-step regather → collective term "
         "should drop to activation all-reduces only", {"strategy": "tp"}),
        ("dp_replicated",
         "B=128 decode: replicate all weights (6GB, fits) → zero weight "
         "collectives; memory term becomes the pure floor",
         {"strategy": "dp"}),
    ]),
}

_RUNNER = r"""
import json, sys
from repro.launch.dryrun import run_cell   # sets XLA_FLAGS on import
spec = json.loads(sys.argv[1])
rec = run_cell(spec["arch"], spec["shape"], multi_pod=False,
               verbose=False, **spec["kwargs"])
slim = {k: rec[k] for k in ("roofline", "full_compile", "policy",
                            "flops_per_device", "bytes_per_device")
        if k in rec}
slim["collective_bytes_per_device"] = rec["collectives"]["bytes_per_device"]
print("@@RESULT@@" + json.dumps(slim))
"""


def run_variant(arch, shape, kwargs):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    spec = json.dumps({"arch": arch, "shape": shape, "kwargs": kwargs})
    proc = subprocess.run([sys.executable, "-c", _RUNNER, spec], env=env,
                          capture_output=True, text=True, timeout=1800)
    for line in proc.stdout.splitlines():
        if line.startswith("@@RESULT@@"):
            return json.loads(line[len("@@RESULT@@"):])
    raise RuntimeError(proc.stderr[-2000:])


def run_pair(pair: str, out_dir: str = "reports/perf"):
    arch, shape, variants = PAIRS[pair]
    os.makedirs(out_dir, exist_ok=True)
    results = []
    baseline_terms = None
    for name, hypothesis, kwargs in variants:
        print(f"[{pair}] {name} ...", flush=True)
        try:
            rec = run_variant(arch, shape, kwargs)
        except Exception as e:
            results.append({"variant": name, "hypothesis": hypothesis,
                            "status": "error", "error": repr(e)[:300]})
            continue
        rf = rec["roofline"]
        row = {"variant": name, "hypothesis": hypothesis, "status": "ok",
               "terms": {k: rf[k] for k in ("compute_s", "memory_s",
                                            "collective_s")},
               "dominant": rf["dominant"],
               "roofline_fraction": rf["roofline_fraction"],
               "fraction_vs_collective": rf.get("fraction_vs_collective"),
               "policy": rec["policy"]}
        if baseline_terms is None:
            baseline_terms = row["terms"]
            row["verdict"] = "baseline"
        else:
            dom0 = max(baseline_terms, key=baseline_terms.get)
            delta = (baseline_terms[dom0] - row["terms"][dom0]) \
                / max(baseline_terms[dom0], 1e-12)
            row["delta_on_baseline_dominant"] = delta
            row["verdict"] = "confirmed" if delta > 0.05 else (
                "neutral" if abs(delta) <= 0.05 else "refuted")
        results.append(row)
        print(f"    {row.get('verdict')} dom={row['dominant']} "
              f"frac={row['roofline_fraction']:.4f}", flush=True)
    path = os.path.join(out_dir, f"{pair}.json")
    with open(path, "w") as f:
        json.dump({"pair": pair, "arch": arch, "shape": shape,
                   "iterations": results}, f, indent=1)
    print(f"wrote {path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default=None, choices=list(PAIRS) + [None])
    args = ap.parse_args()
    for pair in ([args.pair] if args.pair else list(PAIRS)):
        run_pair(pair)


if __name__ == "__main__":
    main()
