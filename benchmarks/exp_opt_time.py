"""Optimization-time table (Sec. VIII: '<1 s for all programs').

Also reports the plan-cache effect the session API adds on top of the
paper: a second ``compile()`` of the same program must be served from the
cache in ~microseconds instead of re-running memo expansion.
"""

from __future__ import annotations

import os
import time

from repro.api import CobraSession
from repro.core import CostCatalog
from repro.programs import (WILOS_PROGRAMS, make_m0, make_orders_customer_db,
                            make_p0, make_sales_db, make_wilos_db)
from repro.relational.database import FAST_LOCAL, SLOW_REMOTE


def main(emit):
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    n = 200 if smoke else 1000
    cases = [("P0", make_p0, lambda: make_orders_customer_db(n, n // 2),
              SLOW_REMOTE),
             ("M0", make_m0, lambda: make_sales_db(n), SLOW_REMOTE)]
    cases += [(f"W_{pid}", maker, lambda: make_wilos_db(n), FAST_LOCAL)
              for pid, maker in WILOS_PROGRAMS.items()]
    for name, maker, dbf, net in cases:
        session = CobraSession(dbf(), CostCatalog(net))
        exe = session.compile(maker())
        res = exe.result
        emit(f"exp_opt_time/{name}", res.opt_time_s * 1e6,
             f"under_1s={res.opt_time_s < 1.0};"
             f"memo_nodes={res.memo_stats.get('and_nodes')}")
        t0 = time.perf_counter()
        again = session.compile(maker())
        cached_us = (time.perf_counter() - t0) * 1e6
        emit(f"exp_opt_time/{name}/cached", cached_us,
             f"from_cache={again.from_cache};"
             f"speedup={res.opt_time_s * 1e6 / max(cached_us, 1e-3):.0f}x")
