"""Optimization-time table (Sec. VIII: '<1 s for all programs')."""

from __future__ import annotations

from repro.core import CostCatalog, optimize
from repro.programs import (WILOS_PROGRAMS, make_m0, make_orders_customer_db,
                            make_p0, make_sales_db, make_wilos_db)
from repro.relational.database import FAST_LOCAL, SLOW_REMOTE


def main(emit):
    cases = [("P0", make_p0, lambda: make_orders_customer_db(1000, 500),
              SLOW_REMOTE),
             ("M0", make_m0, lambda: make_sales_db(1000), SLOW_REMOTE)]
    cases += [(f"W_{pid}", maker, lambda: make_wilos_db(1000), FAST_LOCAL)
              for pid, maker in WILOS_PROGRAMS.items()]
    for name, maker, dbf, net in cases:
        res = optimize(maker(), dbf(), CostCatalog(net))
        emit(f"exp_opt_time/{name}", res.opt_time_s * 1e6,
             f"under_1s={res.opt_time_s < 1.0};"
             f"memo_nodes={res.memo_stats.get('and_nodes')}")
