"""Repo-level pytest configuration.

  * registers the ``slow`` marker — the longest system/optimizer tests carry
    it, so ``pytest -m "not slow"`` (or ``make test-fast``) is the sub-60s
    inner loop while the default run keeps full coverage.

(The optional-``hypothesis`` guard lives in tests/test_properties.py itself
via ``pytest.importorskip``; hypothesis is a dev extra in pyproject.toml.)
"""

import os
import sys

# the tier-1 command is `PYTHONPATH=src python -m pytest`; make the import
# path robust for bare `pytest` invocations too
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running system/optimizer tests; deselect with -m 'not slow'")
