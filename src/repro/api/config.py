"""Optimizer configuration for the session API.

``OptimizerConfig`` unifies the three knobs that were previously scattered
across ``optimize()`` keyword arguments and module-level constants in
``core.search``:

  * **rule selection** — which Fig. 11 transformation rules participate in
    memo saturation, by name (``rules=None`` = the full default set,
    ``exclude_rules=("T3",)`` = the paper's Experiment 1–3 alternative
    space {P0, P1, P2});
  * **cost-choice strategy** — ``"cost"`` (Cobra) or ``"heuristic"``
    (the [4]-style maximal-SQL-push comparator, Fig. 15's baseline);
  * **search budgets** — top-K plans per memo group, the cross-product
    bound at combination points, and the saturation round limit.

Presets mirror the paper's experiments::

    OptimizerConfig.preset("paper-exp1-3")   # no T3: {P0, P1, P2} space
    OptimizerConfig.preset("full")           # every rule (beyond-paper T3∘T4j)
    OptimizerConfig.preset("heuristic")      # Fig. 15 baseline comparator
    OptimizerConfig.preset("wilos")          # Experiment 4: full rules

The config is hashable via :meth:`cache_key` so a ``CobraSession`` can key
its plan cache on it.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["OptimizerConfig", "PRESETS"]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    """Rule selection + cost-choice strategy + search budgets.

    Database/network cost-catalog knobs (C_NRT, BW, C_Z, AF_Q, ...) stay in
    ``core.cost.CostCatalog`` — the catalog describes the *environment*, this
    config describes the *optimizer*.
    """

    choice: str = "cost"                      # "cost" | "heuristic"
    rules: Optional[Tuple[str, ...]] = None   # rule names; None = full set
    exclude_rules: Tuple[str, ...] = ()       # subtracted from the above
    topk: int = 4                             # plans kept per memo group
    max_combos: int = 4096                    # combination cross-product bound
    max_rounds: int = 64                      # saturation round limit
    use_plan_cache: bool = True               # sessions may bypass the cache

    def __post_init__(self):
        if self.choice not in ("cost", "heuristic"):
            raise ValueError(f"choice must be 'cost' or 'heuristic', got {self.choice!r}")
        if isinstance(self.rules, list):
            object.__setattr__(self, "rules", tuple(self.rules))
        if isinstance(self.exclude_rules, list):
            object.__setattr__(self, "exclude_rules", tuple(self.exclude_rules))

    # ------------------------------------------------------------ resolution
    def resolve_rules(self) -> List:
        """Materialize the rule objects this config selects."""
        from ..core.rules import default_rules
        available = default_rules()
        by_name = {r.name: r for r in available}
        if self.rules is None:
            selected = available
        else:
            unknown = [n for n in self.rules if n not in by_name]
            if unknown:
                raise ValueError(f"unknown rule name(s): {unknown}; "
                                 f"available: {sorted(by_name)}")
            selected = [by_name[n] for n in self.rules]
        return [r for r in selected if r.name not in self.exclude_rules]

    def rule_names(self) -> Tuple[str, ...]:
        return tuple(r.name for r in self.resolve_rules())

    def cache_key(self) -> Tuple:
        """Stable identity for plan-cache keying."""
        return ("cfg", self.choice, self.rule_names(), self.topk,
                self.max_combos, self.max_rounds)

    # --------------------------------------------------------------- presets
    @classmethod
    def preset(cls, name: str, **overrides) -> "OptimizerConfig":
        try:
            base = PRESETS[name]
        except KeyError:
            raise ValueError(f"unknown preset {name!r}; "
                             f"available: {sorted(PRESETS)}") from None
        return dataclasses.replace(base, **overrides) if overrides else base


PRESETS = {
    # Full Fig. 11 rule set, cost-based choice (includes the beyond-paper
    # T3 ∘ T4j projection-pushed join).
    "full": OptimizerConfig(),
    # Experiments 1-3: the paper's alternative space {P0, P1, P2} is
    # generated without rule composition via T3.
    "paper-exp1-3": OptimizerConfig(exclude_rules=("T3",)),
    # Fig. 15 "Heuristic" bars: push as much into SQL as possible, never
    # prefetch.
    "heuristic": OptimizerConfig(choice="heuristic"),
    # Experiment 4 (Wilos patterns A-F): full rules, cost-based.
    "wilos": OptimizerConfig(),
}
