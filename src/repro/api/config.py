"""Optimizer configuration for the session API.

``OptimizerConfig`` unifies the three knobs that were previously scattered
across ``optimize()`` keyword arguments and module-level constants in
``core.search``:

  * **rule selection** — which transformation rules participate in memo
    saturation: ``rule_set`` plugs in a :class:`~repro.api.rules.RuleSet`
    (the public registry — user rules registered there fire alongside the
    Fig. 11 built-ins; ``None`` = ``RuleSet.default()``), then ``rules=`` /
    ``exclude_rules=`` select by name within it
    (``exclude_rules=("T3",)`` = the paper's Experiment 1–3 alternative
    space {P0, P1, P2});
  * **cost model** — ``cost_model`` accepts any class implementing the
    :class:`~repro.core.cost.CostModel` protocol, constructed as
    ``cost_model(db, catalog, context)``; ``None`` = the built-in Sec. VI
    model;
  * **cost-choice strategy** — ``"cost"`` (Cobra) or ``"heuristic"``
    (the [4]-style maximal-SQL-push comparator, Fig. 15's baseline);
  * **search budgets** — top-K plans per memo group, the cross-product
    bound at combination points, and the saturation round limit.

Presets mirror the paper's experiments::

    OptimizerConfig.preset("paper-exp1-3")   # no T3: {P0, P1, P2} space
    OptimizerConfig.preset("full")           # every rule (beyond-paper T3∘T4j)
    OptimizerConfig.preset("heuristic")      # Fig. 15 baseline comparator
    OptimizerConfig.preset("wilos")          # Experiment 4: full rules

The config is hashable via :meth:`cache_key` so a ``CobraSession`` can key
its plan cache on it.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["OptimizerConfig", "PRESETS"]

# fingerprint-only copy of the built-in registry: never handed to callers
# (resolve_rule_set returns fresh copies precisely so user mutation cannot
# leak across sessions), so caching it here is safe
_DEFAULT_RULESET = None


def _default_ruleset():
    global _DEFAULT_RULESET
    if _DEFAULT_RULESET is None:
        from .rules import RuleSet
        _DEFAULT_RULESET = RuleSet.default()
    return _DEFAULT_RULESET


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    """Rule selection + cost-choice strategy + search budgets.

    Database/network cost-catalog knobs (C_NRT, BW, C_Z, AF_Q, ...) stay in
    ``core.cost.CostCatalog`` — the catalog describes the *environment*, this
    config describes the *optimizer*.
    """

    choice: str = "cost"                      # "cost" | "heuristic"
    rules: Optional[Tuple[str, ...]] = None   # rule names; None = full set
    exclude_rules: Tuple[str, ...] = ()       # subtracted from the above
    rule_set: Optional[object] = None         # api.rules.RuleSet; None = default
    cost_model: Optional[type] = None         # CostModel-protocol class; None = built-in
    topk: int = 4                             # plans kept per memo group
    max_combos: int = 4096                    # combination cross-product bound
    max_rounds: int = 64                      # saturation round limit
    # compile-time saturation budgets (None = unbudgeted). When either
    # trips mid-saturation the search degrades to greedy best-first over
    # the partial memo and the plan reports `budget_exhausted` — never an
    # error. Budgets change which plan can be found, so they are part of
    # cache_key(); the unbudgeted result is unchanged.
    node_budget: Optional[int] = None         # cap on memo AND-nodes
    wall_budget_s: Optional[float] = None     # cap on saturation wall clock
    use_plan_cache: bool = True               # sessions may bypass the cache
    # promote a (program, plan, context) pair to the compiled execution tier
    # after this many interpreted invocations (None = compiled tier off).
    # An EXECUTION-tier knob, not plan identity: compiled and interpreted
    # executions are bit-identical, so it is deliberately NOT part of
    # cache_key() — flipping it must not invalidate cached/stored plans.
    compile_hot_plans: Optional[int] = None

    def __post_init__(self):
        if self.choice not in ("cost", "heuristic"):
            raise ValueError(f"choice must be 'cost' or 'heuristic', got {self.choice!r}")
        if self.compile_hot_plans is not None and self.compile_hot_plans < 1:
            raise ValueError("compile_hot_plans must be >= 1 (or None: "
                             "compiled tier disabled)")
        if self.node_budget is not None and self.node_budget < 1:
            raise ValueError("node_budget must be >= 1 (or None: unbudgeted)")
        if self.wall_budget_s is not None and self.wall_budget_s <= 0:
            raise ValueError("wall_budget_s must be > 0 (or None: "
                             "unbudgeted)")
        if isinstance(self.rules, list):
            object.__setattr__(self, "rules", tuple(self.rules))
        if isinstance(self.exclude_rules, list):
            object.__setattr__(self, "exclude_rules", tuple(self.exclude_rules))
        if self.cost_model is not None and not callable(self.cost_model):
            raise TypeError("cost_model must be a CostModel-protocol class "
                            "(constructed as cost_model(db, catalog, context))")

    # ------------------------------------------------------------ resolution
    def resolve_rule_set(self):
        """The :class:`~repro.api.rules.RuleSet` this config draws from."""
        from .rules import RuleSet
        if self.rule_set is not None:
            if not isinstance(self.rule_set, RuleSet):
                raise TypeError(f"rule_set must be a repro.api.RuleSet, got "
                                f"{type(self.rule_set).__name__}")
            return self.rule_set
        return RuleSet.default()

    def resolve_rules(self) -> List:
        """Materialize the (core-engine) rule objects this config selects,
        in constraint-resolved firing order (declared ``before``/``after``
        on the selected rules are honored via ``RuleSet.resolve``)."""
        rs = self.resolve_rule_set()
        by_name = {r.name: r for r in rs}
        if self.rules is None:
            names = list(rs.names())
        else:
            unknown = [n for n in self.rules if n not in by_name]
            if unknown:
                raise ValueError(f"unknown rule name(s): {unknown}; "
                                 f"available: {sorted(by_name)}")
            names = list(self.rules)
        names = [n for n in names if n not in self.exclude_rules]
        return [r.to_dag_rule() for r in rs.resolve(names)]

    def rule_names(self) -> Tuple[str, ...]:
        return tuple(r.name for r in self.resolve_rules())

    def _rules_key(self) -> Tuple:
        """(name, revision, phase) triples of the selected rules — a user
        rule's revision is a source hash, so editing its body (or moving it
        to another saturation phase) changes every cache key it
        participated in.

        Runs on EVERY compile (plan-cache hits included), so it avoids
        materializing rule objects: for the default registry a module-level
        read-only copy is fingerprinted (rebuilding it per call doubled the
        warm-compile wall clock); a custom ``rule_set`` is fingerprinted
        live, since its registry is mutable (latest-wins ``register``)."""
        rs = _default_ruleset() if self.rule_set is None \
            else self.resolve_rule_set()     # type-checks, returns it as-is
        names = rs.names() if self.rules is None else self.rules
        return rs.fingerprint(tuple(n for n in names
                                    if n not in self.exclude_rules))

    def _cost_model_key(self) -> Tuple:
        if self.cost_model is None:
            return ("cost-model", "builtin")
        cm = self.cost_model
        rev = getattr(cm, "revision", None)
        if rev is None:
            # same safeguard user rules get: editing the model's body must
            # invalidate every (persistent) plan it costed; set a `revision`
            # class attribute to pin identity across cosmetic edits
            from .rules import _source_revision
            rev = _source_revision(cm)
        return ("cost-model",
                f"{cm.__module__}.{getattr(cm, '__qualname__', cm)}", rev)

    def budget(self):
        """The :class:`~repro.core.dag.Budget` this config implies, or
        ``None`` when unbudgeted."""
        if self.node_budget is None and self.wall_budget_s is None:
            return None
        from ..core.dag import Budget
        return Budget(node_budget=self.node_budget,
                      wall_budget_s=self.wall_budget_s)

    def cache_key(self) -> Tuple:
        """Stable identity for plan-cache keying."""
        return ("cfg", self.choice, self._rules_key(), self._cost_model_key(),
                self.topk, self.max_combos, self.max_rounds,
                self.node_budget, self.wall_budget_s)

    # --------------------------------------------------------------- presets
    @classmethod
    def preset(cls, name: str, **overrides) -> "OptimizerConfig":
        try:
            base = PRESETS[name]
        except KeyError:
            raise ValueError(f"unknown preset {name!r}; "
                             f"available: {sorted(PRESETS)}") from None
        return dataclasses.replace(base, **overrides) if overrides else base


PRESETS = {
    # Full Fig. 11 rule set, cost-based choice (includes the beyond-paper
    # T3 ∘ T4j projection-pushed join).
    "full": OptimizerConfig(),
    # Experiments 1-3: the paper's alternative space {P0, P1, P2} is
    # generated without rule composition via T3.
    "paper-exp1-3": OptimizerConfig(exclude_rules=("T3",)),
    # Fig. 15 "Heuristic" bars: push as much into SQL as possible, never
    # prefetch.
    "heuristic": OptimizerConfig(choice="heuristic"),
    # Experiment 4 (Wilos patterns A-F): full rules, cost-based.
    "wilos": OptimizerConfig(),
}
