"""`CobraSession` — the unified public surface of the framework.

One object owns the database handle, the cost catalog, the optimizer
configuration, and a stats-versioned plan cache::

    session = CobraSession(db, CostCatalog(SLOW_REMOTE),
                           config=OptimizerConfig.preset("paper-exp1-3"))
    exe = session.compile(make_p0())       # memo search runs (once)
    out = exe.run()                        # execute the rewritten program
    exe2 = session.compile(make_p0())      # served from the plan cache
    db.analyze()                           # stats changed -> version bump
    exe3 = session.compile(make_p0())      # recompiled against fresh stats

The same session also fronts the distributed TPU planner
(``core.planner.plan``) through :meth:`CobraSession.plan_step`, so program
rewriting and step-program sharding share one configuration/result
vocabulary: both return a :class:`PlanReport` (domain ``"program"`` vs
``"step"``) with the chosen alternative, its estimated cost, the number of
alternatives considered, and memo statistics.
"""

from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

from ..core.context import ExecutionContext, ONE_SHOT
from ..core.cost import CostCatalog
from ..core.regions import Interpreter, Program
from ..core.search import OptimizationResult, run_search
from ..obs.metrics import MetricsRegistry, registry_counter
from ..obs.trace import NOOP_TRACER
from ..relational.database import ClientEnv, DatabaseServer, NetworkProfile, SLOW_REMOTE
from .cache import (PlanCache, PlanCacheKey, program_fingerprint,
                    program_sites, program_tables)
from .config import OptimizerConfig

__all__ = ["CobraSession", "Executable", "ExecutionResult", "PlanReport"]


# --------------------------------------------------------------------------
# Shared result vocabulary (program rewriting AND step-program planning)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class PlanReport:
    """What any Cobra planning pass reports, regardless of domain."""

    domain: str                 # "program" (SQL/prefetch rewriting) | "step" (TPU sharding)
    name: str                   # program name or arch/workload cell
    choice: object              # search.Plan | planner.PlanChoice
    est_cost_s: float           # model-estimated cost of the winner
    alternatives: int           # alternatives enumerated by the search
    memo_stats: Dict[str, int]
    opt_time_s: float
    artifact: object            # rewritten Program | planner terms dict
    from_cache: bool = False
    # ExecutionContext fingerprint the plan was costed under (telemetry:
    # serving plans are distinguishable from one-shot plans at a glance)
    context_fp: Tuple = ONE_SHOT.fingerprint()
    # which execution tier last served this plan ("interpreter"|"compiled")
    tier: str = "interpreter"
    # anti-regression swap-guard outcome for the recompile that produced
    # this plan (FeedbackController.validate_swap): was it checked, was the
    # swap accepted, how many bindings were replayed
    swap_checked: bool = False
    swap_accepted: Optional[bool] = None
    swap_replayed: int = 0
    # True when a compile-time budget tripped during saturation and this
    # plan came from the greedy best-first fallback over a partial memo
    budget_exhausted: bool = False

    @property
    def binding_diversity(self) -> Dict[str, float]:
        """The observed distinct-binding fractions this plan was costed
        under (from the context fingerprint, restricted to the program's
        parameterized-site groups). Empty = never observed (the cost model
        assumed no binding sharing)."""
        if len(self.context_fp) > 4:
            return dict(self.context_fp[4])
        return {}

    def describe(self) -> str:
        src = "cache" if self.from_cache else "search"
        batch = self.context_fp[1] if len(self.context_fp) > 1 else 1
        ctx = f", batch={batch}" if batch != 1 else ""
        div = self.binding_diversity
        if div:
            avg = sum(div.values()) / len(div)
            ctx += f", binding-diversity~{avg:.2f}@{len(div)} site(s)"
        if self.budget_exhausted:
            ctx += ", BUDGET EXHAUSTED (greedy fallback)"
        return (f"[{self.domain}] {self.name}: est {self.est_cost_s:.4g}s "
                f"over {self.alternatives} alternatives "
                f"({self.opt_time_s*1e3:.1f}ms, {src}{ctx})")


@dataclasses.dataclass
class ExecutionResult(Mapping):
    """Outputs of one program execution plus its simulated-clock telemetry."""

    outputs: Dict[str, object]
    simulated_s: float
    n_queries: int
    n_round_trips: int

    # Mapping over outputs so ``exe.run()["result"]`` reads naturally.
    def __getitem__(self, k):
        return self.outputs[k]

    def __iter__(self):
        return iter(self.outputs)

    def __len__(self):
        return len(self.outputs)


class Executable:
    """A compiled program: the chosen plan + rewritten region IR, runnable
    many times against the session's database."""

    def __init__(self, session: "CobraSession", source: Program,
                 result: OptimizationResult, from_cache: bool,
                 context: Optional[ExecutionContext] = None):
        self.session = session
        self.source = source
        self.result = result
        self.from_cache = from_cache
        self.context = context if context is not None else ONE_SHOT
        self.n_runs = 0
        self._lowered: Dict[str, object] = {}  # backend -> LoweredProgram
        # which tier served the most recent run_batch (set by runtime.batch)
        self.last_tier = "interpreter"
        # swap-guard verdict for the recompile that produced this executable
        # (set by FeedbackController.validate_swap when it judged this plan)
        self.swap_outcome: Optional[Dict[str, object]] = None

    # ------------------------------------------------------------ plan view
    @property
    def program(self) -> Program:
        """The rewritten (optimized) program."""
        return self.result.program

    @property
    def plan(self):
        return self.result.plan

    @property
    def est_cost_s(self) -> float:
        return self.result.est_cost

    @property
    def report(self) -> PlanReport:
        swap = self.swap_outcome or {}
        return PlanReport(
            domain="program", name=self.source.name, choice=self.result.plan,
            est_cost_s=self.result.est_cost,
            alternatives=self.result.alternatives,
            memo_stats=self.result.memo_stats,
            opt_time_s=self.result.opt_time_s, artifact=self.result.program,
            from_cache=self.from_cache,
            context_fp=self.context.fingerprint(
                sites=program_sites(self.source)),
            tier=self.last_tier,
            swap_checked=bool(swap.get("checked", False)),
            swap_accepted=swap.get("accepted"),
            swap_replayed=int(swap.get("replayed", 0)),
            budget_exhausted=bool(getattr(self.result, "budget_exhausted",
                                          False)))

    def describe(self) -> str:
        body = repr(self.program.body)
        kind = ("prefetch" if "prefetch" in body
                else "join" if "JOIN" in body else "original-shape")
        return f"{self.report.describe()} -> {kind}"

    def explain(self, *, feedback=None, site_cache=None,
                compiler=None) -> str:
        """EXPLAIN-style rendering of the winning plan: the region tree
        annotated per site with estimated cost, estimated-vs-observed
        counts (q-error), cache/tier status, and which rules derived it
        (rewrite provenance). Pass the serving runtime's ``feedback`` /
        ``site_cache`` / ``compiler`` to annotate with observed serving
        statistics (``ServingRuntime.explain(name)`` does)."""
        from ..obs.explain import explain_plan
        return explain_plan(self, feedback=feedback, site_cache=site_cache,
                            compiler=compiler)

    def scan(self, *, feedback=None, stats=None):
        """Run the bad-plan-pattern catalog over the REWRITTEN program
        (:func:`repro.obs.signals.scan_plan`); returns the list of
        :class:`~repro.obs.signals.Signal`\\ s still present after the
        optimizer had its say."""
        from ..obs.signals import scan_plan
        return scan_plan(self, feedback=feedback, stats=stats)

    # ------------------------------------------------------------ execution
    def run(self, *, network: Optional[NetworkProfile] = None,
            mode: str = "fast", **params) -> ExecutionResult:
        """Execute the optimized program. ``params`` bind program inputs
        (e.g. ``run(worklist=[1, 3, 5])``)."""
        self.n_runs += 1
        self.session.executions += 1
        return self.session.execute(self.program, network=network, mode=mode,
                                    **params)

    def lower(self, backend: Optional[str] = None):
        """The compiled-tier lowering of this plan
        (:class:`~repro.compiled.lower.LoweredProgram`), memoized per
        backend: columnar loops bound to vectorized kernel-backed
        executables, everything else kept on the interpreter."""
        from ..compiled.lower import lower_program, resolve_backend
        be = resolve_backend(backend)
        lowered = self._lowered.get(be)
        if lowered is None:
            lowered = lower_program(self.program, be)
            self._lowered[be] = lowered
        return lowered

    def run_batch(self, param_sets: Sequence[Mapping[str, object]], *,
                  network: Optional[NetworkProfile] = None,
                  mode: str = "fast", site_cache=None,
                  tier: str = "auto", compiler=None):
        """Execute the optimized program over a BATCH of parameter bindings.

        The whole batch shares one client environment: each query site is
        fetched from the server once per batch (a shared site cache plus a
        bulk navigation fetch in the vectorized interpreter), amortizing
        C_NRT across invocations exactly like the paper's batching
        transformation. Pass a serving-scoped
        :class:`~repro.runtime.sitecache.SiteCache` (``site_cache=``) to
        extend the sharing across batches and programs (one fetch per site
        per stats epoch). Returns a
        :class:`repro.runtime.batch.BatchResult` whose per-invocation
        outputs match per-invocation :meth:`run` bit-for-bit. Programs
        containing updates execute sequentially on isolated environments,
        but sites over tables they never write still share the cache
        (write-set analysis).

        ``tier``/``compiler`` select the execution tier (see
        :func:`repro.runtime.batch.run_batch`): ``tier="compiled"`` forces
        the kernel-backed columnar tier, ``"interpreter"`` forces it off,
        and the default ``"auto"`` promotes through a
        :class:`~repro.compiled.manager.CompileManager` when one is
        passed — always bit-identical to the interpreted tier."""
        from ..runtime.batch import run_batch
        return run_batch(self.session, self.program, param_sets,
                         network=network, mode=mode, executable=self,
                         site_cache=site_cache, tier=tier, compiler=compiler)

    def run_baseline(self, *, network: Optional[NetworkProfile] = None,
                     mode: str = "fast", **params) -> ExecutionResult:
        """Execute the ORIGINAL (unoptimized) program for comparison."""
        return self.session.execute(self.source, network=network, mode=mode,
                                    **params)


# --------------------------------------------------------------------------
# Session
# --------------------------------------------------------------------------

class CobraSession:
    """Compile-once / execute-many frontend over one simulated database."""

    # telemetry counters live in the session's MetricsRegistry; these
    # descriptors keep `session.memo_runs += 1`-style call sites (and the
    # telemetry dict shape) working unchanged as backwards-compatible views
    compile_calls = registry_counter()
    memo_runs = registry_counter()      # actual memo build+saturate+search passes
    executions = registry_counter()
    compiled_executions = registry_counter()  # served by the compiled tier
    # feedback plan-swap guard outcomes (runtime.feedback.validate_swap)
    plan_swaps_accepted = registry_counter()
    plan_swaps_rejected = registry_counter()

    def __init__(self, db: DatabaseServer,
                 catalog: Optional[CostCatalog] = None,
                 config: Optional[OptimizerConfig] = None,
                 plan_cache_entries: int = 256,
                 plan_store=None,
                 context: Optional[ExecutionContext] = None,
                 tracer=None):
        self.db = db
        # observability: the registry must exist before the first counter
        # write below (the descriptors route attribute writes through it)
        self.metrics = MetricsRegistry()
        self.tracer = tracer if tracer is not None else NOOP_TRACER
        self.catalog = catalog if catalog is not None else CostCatalog(SLOW_REMOTE)
        self.config = config if config is not None else OptimizerConfig()
        # default ExecutionContext compiles are costed for (one-shot unless
        # the session serves batches); per-compile `context=` overrides it
        self.context = context if context is not None else ONE_SHOT
        self.plan_cache = PlanCache(plan_cache_entries)
        # cross-program memo-group sharing: saturated loop groups replay
        # into later compiles (other programs, context-driven recompiles);
        # hit/miss counters land in self.metrics at hit time
        from ..core.memopool import MemoPool
        self.memo_pool = MemoPool(metrics=self.metrics)
        # optional disk-backed cross-session store (a PlanStore or a dir path)
        if plan_store is not None:
            from ..runtime.store import PlanStore
            plan_store = PlanStore.coerce(plan_store)
        self.plan_store = plan_store
        self._step_cache: Dict[Tuple, PlanReport] = {}
        # zero the registry-backed telemetry counters (class descriptors)
        self.compile_calls = 0
        self.memo_runs = 0
        self.executions = 0
        self.compiled_executions = 0
        self.plan_swaps_accepted = 0
        self.plan_swaps_rejected = 0

    # ------------------------------------------------------------- keys
    def _catalog_key(self, catalog: CostCatalog) -> Tuple:
        return dataclasses.astuple(catalog)

    def _cache_key(self, program: Program, catalog: CostCatalog,
                   config: OptimizerConfig,
                   rules_override: Optional[Sequence],
                   context: Optional[ExecutionContext] = None) -> PlanCacheKey:
        context = context if context is not None else self.context
        if rules_override is not None:
            config_key = ("cfg", config.choice,
                          tuple(r.name for r in rules_override),
                          config._cost_model_key(),
                          config.topk, config.max_combos, config.max_rounds,
                          config.node_budget, config.wall_budget_s)
        else:
            config_key = config.cache_key()
        # per-table stats versions of exactly the tables the program touches:
        # an analyze() on an unrelated table leaves this plan's entry hot.
        # The context fingerprint is likewise restricted to the program's
        # iteration sites, so observed stats at other programs' sites never
        # invalidate this plan.
        return PlanCacheKey(
            program_fp=program_fingerprint(program),
            catalog_key=self._catalog_key(catalog),
            config_key=config_key,
            stats_version=self.db.stats_token(program_tables(program)),
            context_key=context.fingerprint(sites=program_sites(program)))

    # ---------------------------------------------------------- compilation
    def compile(self, program: Program, *,
                config: Optional[OptimizerConfig] = None,
                catalog: Optional[CostCatalog] = None,
                rules: Optional[Sequence] = None,
                context: Optional[ExecutionContext] = None) -> Executable:
        """Optimize ``program`` (or fetch its cached plan) -> :class:`Executable`.

        ``config``/``catalog``/``context`` override the session defaults for
        this call — ``context`` is the :class:`ExecutionContext` the plan is
        costed for (batch size + observed iteration statistics), so a
        serving deployment can compile a *different* plan than one-shot for
        the same program. ``rules`` takes pre-built ``Rule`` objects (the
        back-compat path used by ``repro.core.optimize``)."""
        cfg = config if config is not None else self.config
        cat = catalog if catalog is not None else self.catalog
        ctx = context if context is not None else self.context
        self.compile_calls += 1

        key = self._cache_key(program, cat, cfg, rules, ctx)
        if cfg.use_plan_cache:
            cached = self.plan_cache.get(key)
            if cached is not None:
                return Executable(self, program, cached, from_cache=True,
                                  context=ctx)
            if self.plan_store is not None:
                # store validity is judged by statistics CONTENT, so a
                # restarted process (version counters back at zero) still
                # warm-starts from byte-equal stats
                stats_fp = self.db.stats_fingerprint(program_tables(program))
                stored = self.plan_store.get(key, stats_fp=stats_fp)
                if stored is not None:
                    # warmed from disk: promote into the in-memory LRU so
                    # repeated compiles in this session stay O(1)
                    self.plan_cache.put(key, stored)
                    return Executable(self, program, stored, from_cache=True,
                                      context=ctx)

        rule_objs = list(rules) if rules is not None else cfg.resolve_rules()
        with self.tracer.span("compile", program=program.name) as sp:
            result = run_search(program, self.db, cat, choice=cfg.choice,
                                rules=rule_objs, topk=cfg.topk,
                                max_combos=cfg.max_combos,
                                max_rounds=cfg.max_rounds,
                                context=ctx, cost_model=cfg.cost_model,
                                tracer=self.tracer, budget=cfg.budget(),
                                memo_pool=self.memo_pool)
            if self.tracer.enabled:
                sp.attrs["est_cost_s"] = result.est_cost
                sp.attrs["alternatives"] = result.alternatives
        self.memo_runs += 1
        self.metrics.observe("compile_opt_time_s", result.opt_time_s)
        if cfg.use_plan_cache:
            if self.plan_store is not None:
                # first-writer-wins: if another session compiled the same
                # cold program concurrently, serve ITS stored plan so every
                # session converges on the one canonical artifact
                result = self.plan_store.put(
                    key, result,
                    stats_fp=self.db.stats_fingerprint(program_tables(program)))
            self.plan_cache.put(key, result)
        return Executable(self, program, result, from_cache=False, context=ctx)

    # ------------------------------------------------------------ execution
    def execute(self, program: Program, *,
                network: Optional[NetworkProfile] = None,
                mode: str = "fast", **params) -> ExecutionResult:
        """Run any program (optimized or not) against the session database
        on a fresh simulated client, returning outputs + clock telemetry."""
        declared = {n for n, _ in program.inputs}
        unknown = set(params) - declared
        if unknown:
            raise TypeError(
                f"unknown program input(s) {sorted(unknown)}; "
                f"{program.name} declares {sorted(declared) or 'no inputs'}")
        env = ClientEnv(self.db, network or self.catalog.network,
                        c_z=self.catalog.c_z)
        outputs = Interpreter(env, mode).run(program, params or None)
        return ExecutionResult(outputs=outputs, simulated_s=env.clock,
                               n_queries=env.n_queries,
                               n_round_trips=env.n_round_trips)

    # --------------------------------------------- distributed-planner facade
    def plan_step(self, arch: Union[str, object], seq_len: int,
                  global_batch: int, kind: str,
                  mesh: Tuple[int, ...] = (1, 16, 16),
                  top_k: int = 1) -> Union[PlanReport, list]:
        """Front the TPU step-program planner with the same result vocabulary.

        Accepts an architecture name (resolved via ``models.arch.get_arch``)
        or an ``ArchConfig``. ``top_k > 1`` returns the K best reports."""
        from ..core.planner import enumerate_plans, plan as planner_plan
        cfg = arch
        if isinstance(arch, str):
            from ..models.arch import get_arch
            cfg = get_arch(arch)
        name = f"{getattr(cfg, 'name', arch)}/{kind}/T{seq_len}/B{global_batch}"
        # the hardware profile is a memo-key component like the catalog is
        # for program plans: an HW-table override (e.g. a different chip's
        # peak FLOPs) must not be served a plan costed for the old hardware
        from ..analysis.roofline import HW
        # a context-pinned HW profile overlays the global table for this
        # plan; the cache keys on the EFFECTIVE values, so a global HW
        # override (e.g. a different chip's peak FLOPs) still invalidates
        # and a pinned profile is genuinely what the plan is costed for
        override = dict(self.context.hw)
        hw_key = tuple(sorted({**HW, **override}.items()))
        key = (name, tuple(mesh), top_k, hw_key)
        cached = self._step_cache.get(key)
        if cached is not None:
            return cached

        t0 = time.perf_counter()
        saved = {k: HW[k] for k in override if k in HW}
        added = set(override) - set(HW)
        HW.update(override)
        try:
            out = planner_plan(cfg, seq_len, global_batch, kind, mesh=mesh,
                               top_k=top_k)
        finally:
            HW.update(saved)
            for k in added:
                HW.pop(k, None)
        dt = time.perf_counter() - t0
        if top_k == 1:
            report = PlanReport(
                domain="step", name=name, choice=out["choice"],
                est_cost_s=out["cost_s"], alternatives=out["n_alternatives"],
                memo_stats=out["memo"], opt_time_s=dt, artifact=out["terms"])
        else:
            n_alts = len(enumerate_plans(cfg, kind))
            report = [PlanReport(domain="step", name=name, choice=c["choice"],
                                 est_cost_s=c["cost_s"], alternatives=n_alts,
                                 memo_stats={}, opt_time_s=dt,
                                 artifact=c["terms"])
                      for c in out]
        self._step_cache[key] = report
        return report

    # ------------------------------------------------------- tracing frontend
    def trace(self, fn=None, *, name: Optional[str] = None,
              relations: Sequence[Tuple] = ()):
        """Decorator: compile a **plain Python function** into an
        :class:`Executable` via AST lifting (``repro.api.lift``).

        Every parameter becomes a declared program input (its Python default
        is the input default); real ``for``/``if``/``while`` +
        ``break``/``continue`` and early ``return`` lower to Region IR; the
        returned value(s) become the program outputs. ``relations`` registers
        ORM FK relationships (``(table, fk_field, target, target_key[,
        attr])``) so ``row.<attr>`` traces to navigation. The decorated name
        binds to an Executable compiled by this session — plan-cache/store
        backed like any other ``compile()``::

            from repro.api import q, col, param

            @session.trace
            def hours(worklist=()):
                out = []
                for wid in worklist:
                    for y in q("tasks").where(col("t_role_id")
                                              .eq(param("r"))).bind(r=wid):
                        out.append(y.t_hours)
                return out

            hours.run(worklist=[1, 2])

        **Builder escape hatch**: a function whose first parameter is named
        ``b`` or ``builder`` is instead called with a
        :class:`~repro.api.builder.ProgramBuilder` (the lifter's own
        lowering target) and may use the full builder vocabulary directly —
        for programs outside the liftable subset.
        """
        from .builder import ProgramBuilder

        def decorate(f):
            params = list(inspect.signature(f).parameters.items())
            if params and params[0][0] in ("b", "builder"):
                b = ProgramBuilder(name or f.__name__)
                handles = []
                for pname, p in params[1:]:
                    default = () if p.default is inspect.Parameter.empty \
                        else p.default
                    handles.append(b.input(pname, default))
                out = f(b, *handles)
                if out is None:
                    outputs: Tuple = ()
                elif isinstance(out, (tuple, list)):
                    outputs = tuple(out)
                else:
                    outputs = (out,)
                return self.compile(b.build(outputs=outputs))
            from .lift import lift_program
            return self.compile(lift_program(f, name=name or f.__name__,
                                             relations=relations))

        return decorate(fn) if fn is not None else decorate

    # ------------------------------------------------------------- telemetry
    def analyze(self, *tables: str,
                columns: Optional[Tuple[str, ...]] = None) -> int:
        """Refresh table statistics (bumps the named tables' stats versions,
        or every table's when none are named, invalidating exactly the
        cached plans that touch them); returns the new global version.
        ``columns`` restricts the (comparatively expensive) histogram
        rebuilds to the named columns — scalar statistics always refresh —
        which is how the feedback controller's q-error path re-analyzes
        only the columns whose estimates drifted."""
        self.db.analyze(*tables, columns=columns)
        return self.db.stats_version

    @property
    def telemetry(self) -> Dict[str, int]:
        # a backwards-compatible view over the metrics registry: the counter
        # reads go through the registry_counter descriptors, and the
        # cache/store stats are mirrored into the registry as gauges so
        # `session.metrics.snapshot()` carries the full picture
        t = {"compile_calls": self.compile_calls,
             "memo_runs": self.memo_runs,
             "executions": self.executions,
             "compiled_executions": self.compiled_executions,
             "plan_swaps_accepted": self.plan_swaps_accepted,
             "plan_swaps_rejected": self.plan_swaps_rejected,
             "memo_pool_hits": self.memo_pool.hits,
             "memo_pool_misses": self.memo_pool.misses,
             "memo_pool_entries": len(self.memo_pool),
             "stats_version": self.db.stats_version}
        self.metrics.gauge("memo_pool_entries", len(self.memo_pool))
        self.metrics.gauge("stats_version", self.db.stats_version)
        cache_stats = {f"cache_{k}": v
                       for k, v in self.plan_cache.stats().items()}
        t.update(cache_stats)
        self.metrics.ingest(cache_stats)
        if self.plan_store is not None:
            store_stats = {f"store_{k}": v
                           for k, v in self.plan_store.stats().items()}
            t.update(store_stats)
            self.metrics.ingest(store_stats)
        return t
