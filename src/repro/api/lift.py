"""AST lifting: compile a **plain Python function** to Region IR.

This is Cobra's real-application frontend. Where :mod:`repro.api.builder`
asks for builder calls (``b.loop(...)``, ``b.let(...)``), the lifter takes
ordinary imperative code — the form application logic actually arrives in —
and lowers its AST onto the builder, which stays the single emission path
for Region IR::

    from repro.api import load_all

    @session.trace(relations=[("orders", "o_customer_sk",
                               "customer", "c_customer_sk", "customer")])
    def P0():
        result = []
        for o in load_all("orders"):
            cust = o.customer                      # ORM navigation (N+1)
            val = myFunc(o.o_id, cust.c_birth_year)
            result.append(val)
        return result

Supported constructs (all lower to the same IR the builder emits by hand):

  * ``for x in <source>`` over query handles (``q(...)``), ``load_all``,
    or traced collection variables — :class:`~repro.core.regions.LoopRegion`;
  * ``if``/``elif``/``else`` over traced predicates — ``CondRegion``;
  * ``while`` + ``break``/``continue`` — ``WhileRegion`` and the early-exit
    statements (paper Sec. V limitations, now first-class);
  * early ``return`` anywhere — ``ReturnStmt`` (outputs are the declared
    names; a return of expressions assigns them first);
  * list/dict accumulation (``xs = []; xs.append(v)``, ``m = {}; m[k] = v``)
    and subscript reads on traced values (``xs[0]``, ``m[key]`` —
    :class:`~repro.core.regions.IIndex`), augmented assignment, scalar
    arithmetic/comparisons/boolean operators;
  * **list/set/dict comprehensions** over traced collections/queries
    (``[f(t.x) for t in load_all("tasks") if t.y > 0]``,
    ``{t.k: t.x for t in ...}``, ``{t.x for t in ...}``) — lowered to the
    same loop-accumulation IR an explicit loop emits (fresh accumulator +
    ``LoopRegion`` + guarded ``CollectionAdd``/``MapPut``; a set is the
    keyed map with the member as its own key); generator expressions and
    nested comprehensions stay ``LiftError``;
  * calls to :func:`~repro.core.regions.register_function`-registered pure
    functions by name, plus ``len``/``min``/``max`` builtins;
  * **small pure helper functions inlined automatically** — an unregistered
    helper reached through the closure/globals whose body is simple
    ``name = expr`` assignments plus a single trailing ``return expr`` (no
    loops, branches, queries, or markers) is inlined by expression
    substitution, producing IR byte-identical to inlining it by hand; a
    helper outside that subset raises :class:`LiftError` naming the
    violated constraint and its location;
  * ORM attribute navigation (``row.customer``) via the ``relations``
    mapping — the Hibernate-style entity relationships that in a real ORM
    live outside the code.

**Partial evaluation.** Names that do not refer to program state resolve at
trace time from the function's closure/globals: query construction
(``q("tasks").where(col(...).eq(param(...))).bind(rid=x.r_id)``) executes
immediately and only its *result* (a query handle with symbolic parameter
bindings) enters the IR, exactly as it would in builder-style code.

Anything outside this vocabulary raises :class:`LiftError` pointing at the
offending source line, with the builder as the documented escape hatch.
"""

from __future__ import annotations

import ast
import builtins
import inspect
import operator
import textwrap
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.regions import _FUNCTIONS, Program
from ..relational.algebra import Query
from .builder import Expr, ProgramBuilder, Q
from .builder import q as _q

__all__ = [
    "LiftError", "lift_program", "lift_source",
    # tracing markers (recognized by identity inside lifted functions)
    "load_all", "cache_lookup", "scalar_query", "query_values",
    "prefetch", "update_row", "cache_by_column", "noop",
]


class LiftError(Exception):
    """A construct the lifter cannot lower, with source location context.

    The message names the unsupported construct and where it is; the
    builder API (``repro.api.ProgramBuilder``) remains the escape hatch
    for programs outside the liftable vocabulary."""


# --------------------------------------------------------------------------
# Tracing markers
# --------------------------------------------------------------------------
# These exist to be *recognized by identity* when a lifted function calls
# them; they are never executed. Each mirrors a ProgramBuilder method.

def _marker(fn):
    def stub(*args, **kwargs):
        raise LiftError(
            f"{fn.__name__}() is a tracing marker — it only has meaning "
            f"inside a function being lifted by session.trace / lift_program")
    stub.__name__ = fn.__name__
    stub.__doc__ = fn.__doc__
    return stub


@_marker
def load_all(table):
    """ORM ``loadAll(Entity.class)`` — full-table fetch (expression)."""


@_marker
def cache_lookup(table, column, key, all_matches=False):
    """``Utils.lookupCache`` over a prefetched column-keyed cache."""


@_marker
def scalar_query(source, column):
    """Execute a query, return one scalar (first row of ``column``)."""


@_marker
def query_values(source, column):
    """Execute a query, return ``column`` as a list value."""


@_marker
def prefetch(source, by, cache_name=None):
    """``prefetch(R, A)`` — fetch + cache keyed by column (statement)."""


@_marker
def update_row(table, set_col, value, key_col, key):
    """``UPDATE table SET set_col = value WHERE key_col = key``."""


@_marker
def cache_by_column(var, column):
    """``Utils.cacheByColumn`` on an already-fetched query result."""


@_marker
def noop(note=""):
    """An explicit no-op statement."""


_EXPR_MARKERS = {"load_all", "cache_lookup", "scalar_query", "query_values"}
_STMT_MARKERS = {"prefetch", "update_row", "cache_by_column", "noop"}
_MARKERS = {name: globals()[name] for name in _EXPR_MARKERS | _STMT_MARKERS}


# --------------------------------------------------------------------------
# Operator tables
# --------------------------------------------------------------------------

_BINOPS = {ast.Add: "+", ast.Sub: "-", ast.Mult: "*", ast.Div: "/"}
_STATIC_BINOPS = {ast.Add: operator.add, ast.Sub: operator.sub,
                  ast.Mult: operator.mul, ast.Div: operator.truediv,
                  ast.Mod: operator.mod, ast.Pow: operator.pow,
                  ast.FloorDiv: operator.floordiv}
_CMPOPS = {ast.Eq: "==", ast.NotEq: "!=", ast.Lt: "<", ast.LtE: "<=",
           ast.Gt: ">", ast.GtE: ">="}
_PY_OPS = {"+": operator.add, "-": operator.sub, "*": operator.mul,
           "/": operator.truediv,
           "==": operator.eq, "!=": operator.ne, "<": operator.lt,
           "<=": operator.le, ">": operator.gt, ">=": operator.ge,
           "and": lambda a, b: a and b, "or": lambda a, b: a or b,
           "min": min, "max": max}


class _Static:
    """A trace-time (partially-evaluated) binding in the local scope."""

    __slots__ = ("value",)

    def __init__(self, value):
        self.value = value


_SCALARS = (bool, int, float, str)

# sentinel: the call is not even an inlining candidate (fall through to the
# generic cannot-call error rather than an inliner-specific one)
_NOT_INLINED = object()


# --------------------------------------------------------------------------
# The lifter
# --------------------------------------------------------------------------

class _Lifter:
    def __init__(self, fnode: ast.FunctionDef, env: Dict[str, object], *,
                 name: str, relations: Sequence[Tuple],
                 inputs: Sequence[Tuple[str, object]],
                 filename: str = "<lifted>", line_offset: int = 0):
        self.fnode = fnode
        self.env = env
        self.filename = filename
        self.line_offset = line_offset
        self.b = ProgramBuilder(name)
        for rel in relations:
            self.b.relate(*rel)
        self.scope: Dict[str, object] = {}
        for pname, default in inputs:
            self.scope[pname] = self.b.input(pname, default)
        self.out_names: Tuple[str, ...] = self._scan_outputs(fnode)
        self._comp_depth = 0           # comprehensions never nest
        self._in_while_test = False    # comprehensions can't lower there
        self._inline_depth = 0         # helper-inlining recursion guard

    # ------------------------------------------------------------ diagnostics
    def _err(self, node, msg: str) -> LiftError:
        line = self.line_offset + getattr(node, "lineno", 0)
        return LiftError(
            f"cannot lift {self.fnode.name}(): {msg} "
            f"[{self.filename}:{line}] — use repro.api.ProgramBuilder for "
            f"constructs outside the lifted subset")

    def _need_static(self, value, node, what: str):
        if isinstance(value, Expr):
            raise self._err(node, f"{what} must be a trace-time value, not a "
                                  f"traced expression")
        return value

    # ---------------------------------------------------------------- outputs
    def _scan_outputs(self, fnode: ast.FunctionDef) -> Tuple[str, ...]:
        """Canonical output names: from the LAST value-carrying ``return``.

        Elements that are plain names keep them; expressions get positional
        ``_ret{i}`` names. Every other return site must match the arity (a
        bare early ``return`` is always allowed: outputs keep their current
        values)."""
        rets: List[ast.Return] = []

        def walk(node):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda)):
                    continue  # rejected later with a targeted error
                if isinstance(child, ast.Return):
                    rets.append(child)
                walk(child)

        walk(fnode)
        valued = [r for r in rets if r.value is not None]
        if not valued:
            return ()
        v = valued[-1].value
        elems = list(v.elts) if isinstance(v, ast.Tuple) else [v]
        return tuple(e.id if isinstance(e, ast.Name) else f"_ret{i}"
                     for i, e in enumerate(elems))

    def _lower_return(self, node: ast.Return, is_final: bool) -> None:
        if node.value is not None:
            v = node.value
            elems = list(v.elts) if isinstance(v, ast.Tuple) else [v]
            if len(elems) != len(self.out_names):
                raise self._err(
                    node, f"return arity mismatch: this site returns "
                          f"{len(elems)} value(s), the program declares "
                          f"outputs {list(self.out_names)}")
            for canonical, e in zip(self.out_names, elems):
                if isinstance(e, ast.Name) and e.id == canonical:
                    v = self.scope.get(canonical)
                    if v is None:
                        raise self._err(e, f"returned name {canonical!r} was "
                                           f"never assigned")
                    if not isinstance(v, Expr):
                        raise self._err(
                            e, f"returned name {canonical!r} is a trace-time "
                               f"{type(v.value).__name__}, not traced program "
                               f"state — iterate it in a loop and accumulate "
                               f"the rows instead")
                    continue
                val = self._expr(e)
                if not isinstance(val, (Expr,) + _SCALARS):
                    raise self._err(e, "can only return traced expressions, "
                                       "scalars, or assigned variables")
                self.scope[canonical] = self.b.let(canonical, val)
        if not is_final:
            self.b.ret()

    # ------------------------------------------------------------------ build
    def lift(self) -> Program:
        body = self.fnode.body
        for i, stmt in enumerate(body):
            self._stmt(stmt, is_final=(i == len(body) - 1))
        return self.b.build(outputs=self.out_names)

    # ------------------------------------------------------------- statements
    def _stmt(self, node: ast.stmt, is_final: bool = False) -> None:
        if isinstance(node, ast.Expr):
            if isinstance(node.value, ast.Constant) and isinstance(
                    node.value.value, str):
                return  # docstring
            if isinstance(node.value, ast.Call):
                self._call_stmt(node.value)
                return
            raise self._err(node, "expression statement has no effect")
        if isinstance(node, ast.Assign):
            self._assign(node)
            return
        if isinstance(node, ast.AugAssign):
            self._aug_assign(node)
            return
        if isinstance(node, ast.For):
            self._for(node)
            return
        if isinstance(node, ast.If):
            self._if(node)
            return
        if isinstance(node, ast.While):
            self._while(node)
            return
        if isinstance(node, ast.Break):
            self.b.brk()
            return
        if isinstance(node, ast.Continue):
            self.b.cont()
            return
        if isinstance(node, ast.Return):
            self._lower_return(node, is_final)
            return
        if isinstance(node, ast.Pass):
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            raise self._err(node, "nested function definitions are not "
                                  "liftable — register it as a pure function "
                                  "(register_function) or inline it")
        raise self._err(node, f"unsupported statement "
                              f"{type(node).__name__!r}")

    def _assign(self, node: ast.Assign) -> None:
        if len(node.targets) != 1:
            raise self._err(node, "chained assignment (a = b = ...)")
        target = node.targets[0]
        if isinstance(target, ast.Name):
            self._bind(target.id, self._expr(node.value), node)
            return
        if isinstance(target, ast.Subscript):
            base = target.value
            if not (isinstance(base, ast.Name)
                    and isinstance(self.scope.get(base.id), Expr)):
                raise self._err(node, "subscript assignment requires a traced "
                                      "map variable (m = {}; m[k] = v)")
            key = self._expr(target.slice)
            val = self._expr(node.value)
            self.b.put(base.id, key, val)
            return
        raise self._err(node, f"unsupported assignment target "
                              f"{type(target).__name__!r}")

    def _bind(self, name: str, value, node) -> None:
        """Name binding: program state becomes a ``let``; everything else
        (query handles, helpers) stays a trace-time binding."""
        if isinstance(value, (Expr,) + _SCALARS):
            self.scope[name] = self.b.let(name, value)
        else:
            self.scope[name] = _Static(value)

    def _aug_assign(self, node: ast.AugAssign) -> None:
        if not isinstance(node.target, ast.Name):
            raise self._err(node, "augmented assignment target must be a "
                                  "plain variable")
        name = node.target.id
        cur = self.scope.get(name)
        if not isinstance(cur, Expr):
            raise self._err(node, f"{name!r} is not a traced program "
                                  f"variable (assign it first)")
        opname = _BINOPS.get(type(node.op))
        if opname is None:
            raise self._err(node, f"unsupported augmented operator "
                                  f"{type(node.op).__name__!r}")
        self.scope[name] = self.b.let(name, cur._bin(opname,
                                                     self._expr(node.value)))

    def _for(self, node: ast.For) -> None:
        if node.orelse:
            raise self._err(node, "for/else")
        if not isinstance(node.target, ast.Name):
            raise self._err(node, "loop target must be a single variable")
        src = self._expr(node.iter)
        if not isinstance(src, (Expr, Q, Query, str)):
            raise self._err(node.iter,
                            f"cannot iterate a trace-time "
                            f"{type(src).__name__} — loop sources are query "
                            f"handles (q(...)), load_all(...), or traced "
                            f"collection variables")
        var = node.target.id
        with self.b.loop(src, var=var) as cursor:
            self.scope[var] = cursor
            for s in node.body:
                self._stmt(s)

    def _if(self, node: ast.If) -> None:
        pred = self._expr(node.test)
        if not isinstance(pred, Expr):
            raise self._err(node.test,
                            "condition is a trace-time constant — lifted "
                            "branches must test traced program state")
        with self.b.when(pred):
            for s in node.body:
                self._stmt(s)
        if node.orelse:
            with self.b.otherwise():
                for s in node.orelse:
                    self._stmt(s)

    def _while(self, node: ast.While) -> None:
        if node.orelse:
            raise self._err(node, "while/else")
        # the guard is lowered OUTSIDE the WhileRegion and re-evaluated by
        # the interpreter each iteration — that only works for pure
        # expressions. A comprehension would emit its accumulation loop
        # here, frozen at entry, silently diverging from Python's
        # re-evaluate-every-iteration semantics — reject it.
        self._in_while_test = True
        try:
            pred = self._expr(node.test)
        finally:
            self._in_while_test = False
        if not isinstance(pred, (Expr, bool, int)):
            raise self._err(node.test, "while guard must be a traced "
                                       "expression (or the literal True)")
        with self.b.while_(pred):
            for s in node.body:
                self._stmt(s)

    def _call_stmt(self, call: ast.Call) -> None:
        func = call.func
        # collection/map mutation methods on traced variables
        if isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name):
            holder = self.scope.get(func.value.id)
            if isinstance(holder, Expr):
                args = [self._expr(a) for a in call.args]
                if func.attr in ("append", "add") and len(args) == 1:
                    self.b.add(func.value.id, args[0])
                    return
                if func.attr == "put" and len(args) == 2:
                    self.b.put(func.value.id, args[0], args[1])
                    return
                raise self._err(call, f"unsupported method .{func.attr}() on "
                                      f"traced variable {func.value.id!r}")
        f = self._maybe_static(func)
        marker = self._marker_name(f)
        if marker in _STMT_MARKERS:
            args, kwargs = self._call_args(call)
            try:
                getattr(self.b, marker)(*args, **kwargs)
            except TypeError as e:
                raise self._err(call, f"{marker}(): {e}")
            return
        value = self._expr(call)
        if isinstance(value, Expr):
            raise self._err(call, "traced expression used as a statement has "
                                  "no effect — assign it to a variable")
        # trace-time call already executed for its (trace-time) effect

    # ------------------------------------------------------------ expressions
    def _expr(self, node: ast.expr):
        """Lower to a traced :class:`Expr` or a trace-time Python value."""
        if isinstance(node, ast.Constant):
            return node.value
        if isinstance(node, ast.Name):
            return self._name(node)
        if isinstance(node, ast.Attribute):
            base = self._expr(node.value)
            if isinstance(base, Expr):
                if node.attr.startswith("_"):
                    raise self._err(node, f"traced attribute {node.attr!r}")
                return getattr(base, node.attr)  # IField / INav
            try:
                return getattr(base, node.attr)
            except AttributeError:
                raise self._err(node, f"trace-time object "
                                      f"{type(base).__name__} has no "
                                      f"attribute {node.attr!r}")
        if isinstance(node, ast.BinOp):
            l, r = self._expr(node.left), self._expr(node.right)
            opname = _BINOPS.get(type(node.op))
            if opname is not None:
                return self._apply_op(opname, l, r, node)
            static_op = _STATIC_BINOPS.get(type(node.op))
            if static_op is not None and not isinstance(l, Expr) \
                    and not isinstance(r, Expr):
                return static_op(l, r)
            raise self._err(node, f"unsupported operator "
                                  f"{type(node.op).__name__!r} on traced "
                                  f"values")
        if isinstance(node, ast.Compare):
            if len(node.ops) != 1:
                raise self._err(node, "chained comparison (a < b < c)")
            opname = _CMPOPS.get(type(node.ops[0]))
            if opname is None:
                raise self._err(node, f"unsupported comparison "
                                      f"{type(node.ops[0]).__name__!r}")
            return self._apply_op(opname, self._expr(node.left),
                                  self._expr(node.comparators[0]), node)
        if isinstance(node, ast.BoolOp):
            opname = "and" if isinstance(node.op, ast.And) else "or"
            vals = [self._expr(v) for v in node.values]
            out = vals[0]
            for v in vals[1:]:
                out = self._apply_op(opname, out, v, node)
            return out
        if isinstance(node, ast.UnaryOp):
            v = self._expr(node.operand)
            if isinstance(node.op, ast.USub) and not isinstance(v, Expr):
                return -v
            if isinstance(node.op, ast.Not) and not isinstance(v, Expr):
                return not v
            raise self._err(node, f"unsupported unary "
                                  f"{type(node.op).__name__!r} on a traced "
                                  f"value")
        if isinstance(node, ast.Call):
            return self._call(node)
        if isinstance(node, ast.List):
            if not node.elts:
                return self.b.empty_list()
            vals = [self._expr(e) for e in node.elts]
            if any(isinstance(v, Expr) for v in vals):
                raise self._err(node, "list literals of traced values — "
                                      "initialize empty and .append()")
            return vals
        if isinstance(node, ast.Dict):
            if not node.keys:
                return self.b.empty_map()
            raise self._err(node, "non-empty dict literals — initialize "
                                  "empty and assign m[k] = v")
        if isinstance(node, ast.Tuple):
            vals = [self._expr(e) for e in node.elts]
            if any(isinstance(v, Expr) for v in vals):
                raise self._err(node, "tuples of traced values")
            return tuple(vals)
        if isinstance(node, ast.Subscript):
            if isinstance(node.slice, ast.Slice):
                raise self._err(node, "slice reads (a[i:j]) — index one "
                                      "element at a time")
            base = self._expr(node.value)
            key = self._expr(node.slice)
            # traced collection/map read -> IIndex (Expr.__getitem__);
            # trace-time base -> ordinary Python subscript
            if isinstance(base, Expr) and not isinstance(key,
                                                         (Expr,) + _SCALARS):
                raise self._err(node, f"subscript key must be a traced "
                                      f"expression or scalar, not a "
                                      f"trace-time {type(key).__name__}")
            return base[key]
        if isinstance(node, ast.ListComp):
            return self._comp(node, "list")
        if isinstance(node, ast.SetComp):
            return self._comp(node, "set")
        if isinstance(node, ast.DictComp):
            return self._comp(node, "dict")
        if isinstance(node, ast.GeneratorExp):
            raise self._err(node, "generator expressions — materialize with "
                                  "a list/set/dict comprehension or an "
                                  "explicit loop")
        if isinstance(node, ast.IfExp):
            raise self._err(node, "conditional expressions — write an "
                                  "explicit if statement")
        if isinstance(node, ast.Lambda):
            raise self._err(node, "lambda — register it as a pure function "
                                  "(register_function)")
        raise self._err(node, f"unsupported expression "
                              f"{type(node).__name__!r}")

    def _name(self, node: ast.Name):
        name = node.id
        if name in self.scope:
            v = self.scope[name]
            return v.value if isinstance(v, _Static) else v
        if name in self.env:
            return self.env[name]
        raise self._err(node, f"unknown name {name!r} (not a program "
                              f"variable, parameter, or closure/global)")

    def _apply_op(self, opname: str, l, r, node):
        if isinstance(l, Expr):
            return l._bin(opname, r)
        if isinstance(r, Expr):
            return r._bin(opname, l, swap=True)  # preserves operand order
        try:
            return _PY_OPS[opname](l, r)
        except Exception as e:
            raise self._err(node, f"trace-time {opname!r} failed: {e}")

    # -------------------------------------------------------- comprehensions
    def _comp(self, node, kind: str):
        """Lower ``[elt for v in src if cond ...]`` (and the ``{...}`` set
        and ``{k: v ...}`` dict forms) onto the loop-accumulation path an
        explicit loop takes: a fresh empty accumulator (``empty_list`` for
        lists, ``empty_map`` for sets and dicts), a ``LoopRegion`` over the
        source, one nested ``CondRegion`` per ``if`` clause, and the
        accumulation statement — ``CollectionAdd`` of the element for
        lists, ``MapPut`` of (key, value) for dicts, and ``MapPut`` of
        (element, element) for sets (a set IS the keyed map with the member
        as its own key, exactly what ``m[e] = e`` in an explicit loop
        emits). The value of the expression is the accumulator variable."""
        if self._in_while_test:
            raise self._err(node, "a comprehension in a while guard — its "
                                  "loop would run once at entry instead of "
                                  "every iteration; compute it inside the "
                                  "loop body into a variable")
        if self._comp_depth:
            raise self._err(node, "nested comprehensions — write explicit "
                                  "loops")
        if len(node.generators) != 1:
            raise self._err(node, "comprehensions with multiple `for` "
                                  "clauses — write explicit nested loops")
        gen = node.generators[0]
        if getattr(gen, "is_async", 0):
            raise self._err(node, "async comprehensions")
        if not isinstance(gen.target, ast.Name):
            raise self._err(node, "comprehension target must be a single "
                                  "variable")
        src = self._expr(gen.iter)
        if not isinstance(src, (Expr, Q, Query, str)):
            raise self._err(
                gen.iter, f"cannot iterate a trace-time "
                          f"{type(src).__name__} — comprehension sources "
                          f"are query handles (q(...)), load_all(...), or "
                          f"traced collection variables")
        var = gen.target.id
        acc_name = self.b._fresh_var("comp")
        init = self.b.empty_list() if kind == "list" else self.b.empty_map()
        acc = self.b.let(acc_name, init)
        _missing = object()
        saved = self.scope.get(var, _missing)

        def lowered(part: ast.expr, what: str):
            val = self._expr(part)
            if not isinstance(val, (Expr,) + _SCALARS):
                raise self._err(
                    part, f"comprehension {what} must be a traced "
                          f"expression or scalar, not a trace-time "
                          f"{type(val).__name__}")
            return val

        self._comp_depth += 1
        try:
            with self.b.loop(src, var=var) as cursor:
                self.scope[var] = cursor

                def emit(i: int) -> None:
                    if i == len(gen.ifs):
                        if kind == "dict":
                            k = lowered(node.key, "key")
                            self.b.put(acc_name, k,
                                       lowered(node.value, "value"))
                        elif kind == "set":
                            e = lowered(node.elt, "element")
                            self.b.put(acc_name, e, e)
                        else:
                            self.b.add(acc_name, lowered(node.elt, "element"))
                        return
                    pred = self._expr(gen.ifs[i])
                    if not isinstance(pred, Expr):
                        raise self._err(
                            gen.ifs[i], "comprehension condition is a "
                                        "trace-time constant — it must test "
                                        "traced program state")
                    with self.b.when(pred):
                        emit(i + 1)

                emit(0)
        finally:
            self._comp_depth -= 1
            if saved is _missing:
                self.scope.pop(var, None)
            else:
                self.scope[var] = saved
        return acc

    # ------------------------------------------------------------------ calls
    def _maybe_static(self, node: ast.expr):
        """Resolve an expression to a trace-time value if possible, else
        None (no IR is emitted either way)."""
        try:
            if isinstance(node, ast.Name):
                v = self.scope.get(node.id)
                if isinstance(v, _Static):
                    return v.value
                if v is not None:
                    return None  # traced
                return self.env.get(node.id)
            if isinstance(node, ast.Attribute):
                base = self._maybe_static(node.value)
                if base is None or isinstance(base, Expr):
                    return None
                return getattr(base, node.attr, None)
        except Exception:
            return None
        return None

    def _marker_name(self, f) -> Optional[str]:
        for mname, mf in _MARKERS.items():
            if f is mf:
                return mname
        return None

    def _call_args(self, call: ast.Call):
        args = [self._expr(a) for a in call.args]
        kwargs = {}
        for kw in call.keywords:
            if kw.arg is None:
                raise self._err(call, "**kwargs expansion in calls")
            kwargs[kw.arg] = self._expr(kw.value)
        return args, kwargs

    def _call(self, node: ast.Call):
        func = node.func
        # registered pure functions called by name trace to ICall — when the
        # name is unbound or bound to the registered callable itself, so
        # lifted functions stay runnable as ordinary Python too. A DIFFERENT
        # callable shadowing a registered name is the user's and falls
        # through to normal handling (a traced-arg call on it then errors
        # loudly instead of silently running the registry entry).
        if isinstance(func, ast.Name) and func.id not in self.scope \
                and func.id in _FUNCTIONS:
            bound = self.env.get(func.id)
            if bound is None or bound is _FUNCTIONS[func.id]:
                args, kwargs = self._call_args(node)
                if kwargs:
                    raise self._err(node, f"registered function {func.id!r} "
                                          f"takes positional arguments only")
                return self.b.call(func.id, *args)
        f = self._expr(func)
        if isinstance(f, Expr):
            raise self._err(node, "calling a traced value")
        for rname, rfn in _FUNCTIONS.items():
            if f is rfn:  # registered callable reached through a binding
                args, kwargs = self._call_args(node)
                if kwargs:
                    raise self._err(node, f"registered function {rname!r} "
                                          f"takes positional arguments only")
                return self.b.call(rname, *args)
        marker = self._marker_name(f)
        args, kwargs = self._call_args(node)
        if marker in _EXPR_MARKERS:
            try:
                return getattr(self.b, marker)(*args, **kwargs)
            except TypeError as e:
                raise self._err(node, f"{marker}(): {e}")
        if marker in _STMT_MARKERS:
            raise self._err(node, f"{marker}() is a statement, not an "
                                  f"expression")
        if f is builtins.len:
            (a,) = args
            return a.len() if isinstance(a, Expr) else len(a)
        if f in (builtins.min, builtins.max):
            if any(isinstance(a, Expr) for a in args):
                if len(args) != 2:
                    raise self._err(node, f"traced {f.__name__}() takes "
                                          f"exactly two arguments")
                return self._apply_op(f.__name__, args[0], args[1], node)
            return f(*args, **kwargs)
        traced = (any(isinstance(a, Expr) for a in args)
                  or any(isinstance(v, Expr) for v in kwargs.values()))
        if not traced:
            try:
                return f(*args, **kwargs)
            except LiftError:
                raise
            except Exception as e:
                raise self._err(node, f"trace-time call failed: {e!r}")
        # traced arguments on a trace-time callable: only the relational
        # query surface accepts them (Q.bind embeds traced parameter exprs)
        if f is _q or isinstance(getattr(f, "__self__", None), Q):
            try:
                return f(*args, **kwargs)
            except Exception as e:
                raise self._err(node, f"query construction failed: {e!r}")
        inlined = self._inline_call(node, f, args, kwargs)
        if inlined is not _NOT_INLINED:
            return inlined
        fname = getattr(f, "__name__", repr(f))
        raise self._err(node, f"cannot call {fname!r} on traced values — "
                              f"register_function({fname!r}, fn) makes it "
                              f"traceable as a pure function, or a small "
                              f"single-return helper is inlined automatically")

    # ---------------------------------------------------------- helper inlining
    _INLINE_MAX_DEPTH = 8

    def _inline_call(self, node: ast.Call, f, args, kwargs):
        """Inline a small pure helper called with traced arguments.

        The inlined subset is exactly what manual inlining by expression
        substitution supports: a body of simple ``name = expr`` assignments
        followed by a single ``return expr``, no loops/branches/queries and
        no query markers. Parameters and intermediate names bind in a
        TEMPORARY scope without emitting ``let`` statements, so the IR is
        byte-identical to the user substituting the helper's expression by
        hand (a temp used twice duplicates its expression, exactly as
        manual substitution would).

        Returns ``_NOT_INLINED`` when ``f`` is not even a candidate (not a
        plain source-available Python function) — the caller falls through
        to its generic error. A candidate that VIOLATES the inlinable
        subset raises a located :class:`LiftError` naming the constraint."""
        if not inspect.isfunction(f):
            return _NOT_INLINED
        shadowed = _FUNCTIONS.get(f.__name__)
        if shadowed is not None and f is not shadowed:
            # a local helper sharing a registered function's name is
            # ambiguous — NEVER resolve it silently, in either direction
            raise self._err(
                node, f"local callable {f.__name__!r} shadows the registered "
                      f"function of the same name — rename the helper, or "
                      f"register_function({f.__name__!r}, fn) to replace the "
                      f"registry entry")
        try:
            lines, lnum = inspect.getsourcelines(f)
            fnode, _ = _function_node("".join(lines))
        except (OSError, TypeError, SyntaxError, LiftError):
            return _NOT_INLINED
        fname = f.__name__

        def inline_err(msg: str) -> LiftError:
            return self._err(node, f"cannot inline helper {fname}(): {msg}")

        if self._inline_depth >= self._INLINE_MAX_DEPTH:
            raise inline_err(f"inlining recursion deeper than "
                             f"{self._INLINE_MAX_DEPTH} (is it recursive?)")
        try:
            bound = inspect.signature(f).bind(*args, **kwargs)
            bound.apply_defaults()
        except TypeError as e:
            raise inline_err(f"argument mismatch: {e}")
        # body shape: optional docstring, simple assigns, one trailing return
        body = list(fnode.body)
        if body and isinstance(body[0], ast.Expr) \
                and isinstance(body[0].value, ast.Constant) \
                and isinstance(body[0].value.value, str):
            body = body[1:]
        if not body or not isinstance(body[-1], ast.Return) \
                or body[-1].value is None:
            raise inline_err("body must end in a single `return <expr>`")
        assigns: List[ast.Assign] = []
        for stmt in body[:-1]:
            if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)):
                raise inline_err(
                    f"only `name = expr` assignments and a trailing return "
                    f"are inlinable, found {type(stmt).__name__!r} at line "
                    f"{lnum - 1 + getattr(stmt, 'lineno', 0)}")
            assigns.append(stmt)
        helper_env = _base_env(getattr(f, "__globals__", {}))
        if getattr(f, "__closure__", None):
            for cname, cell in zip(f.__code__.co_freevars, f.__closure__):
                try:
                    helper_env[cname] = cell.cell_contents
                except ValueError:
                    pass
        self._check_inlinable_exprs(
            [a.value for a in assigns] + [body[-1].value],
            helper_env, inline_err)
        # evaluate in the helper's own environment: a temp scope holding the
        # bound parameters (traced Exprs pass through; trace-time values stay
        # static) — crucially no b.let, so nothing is emitted for the binding
        scope: Dict[str, object] = {}
        for pname, v in bound.arguments.items():
            scope[pname] = v if isinstance(v, Expr) else _Static(v)
        saved = (self.scope, self.env, self.filename, self.line_offset)
        self.scope, self.env = scope, helper_env
        self.filename = f.__code__.co_filename
        self.line_offset = lnum - 1
        self._inline_depth += 1
        try:
            for stmt in assigns:
                v = self._expr(stmt.value)
                scope[stmt.targets[0].id] = \
                    v if isinstance(v, Expr) else _Static(v)
            return self._expr(body[-1].value)
        finally:
            self._inline_depth -= 1
            self.scope, self.env, self.filename, self.line_offset = saved

    def _check_inlinable_exprs(self, exprs: Sequence[ast.expr], helper_env,
                               inline_err) -> None:
        """Reject constructs manual expression substitution could not
        produce: anything that emits IR statements (loops via
        comprehensions) or touches the database (query construction,
        tracing markers) from inside the helper."""
        forbidden = (ast.ListComp, ast.SetComp, ast.DictComp,
                     ast.GeneratorExp, ast.Lambda, ast.IfExp, ast.Await,
                     ast.Yield, ast.YieldFrom, ast.NamedExpr)
        for e in exprs:
            for sub in ast.walk(e):
                if isinstance(sub, forbidden):
                    raise inline_err(
                        f"{type(sub).__name__!r} in the body — inlined "
                        f"helpers are straight-line scalar expressions")
                if isinstance(sub, ast.Call) \
                        and isinstance(sub.func, ast.Name):
                    target = helper_env.get(sub.func.id)
                    if target is _q or self._marker_name(target) is not None:
                        raise inline_err(
                            f"{sub.func.id}() in the body — inlined helpers "
                            f"must not construct queries or use tracing "
                            f"markers; call the query at the call site and "
                            f"pass the value in")


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------

def _function_node(source: str) -> Tuple[ast.FunctionDef, str]:
    tree = ast.parse(textwrap.dedent(source))
    for stmt in tree.body:
        if isinstance(stmt, ast.FunctionDef):
            return stmt, source
    raise LiftError("no function definition found in source")


def _base_env(extra: Optional[Dict[str, object]] = None) -> Dict[str, object]:
    env: Dict[str, object] = dict(vars(builtins))
    if extra:
        env.update(extra)
    return env


def lift_program(fn, *, name: Optional[str] = None,
                 relations: Sequence[Tuple] = ()) -> Program:
    """Lift a plain Python function to a :class:`~repro.core.regions.Program`.

    Parameters become declared program inputs (their Python defaults are the
    input defaults); the returned value(s) become the program outputs;
    ``relations`` registers ORM FK relationships as
    ``(table, fk_field, target, target_key[, attribute_name])`` tuples so
    ``row.<attribute>`` lowers to navigation (``INav``)."""
    try:
        lines, lnum = inspect.getsourcelines(fn)
    except (OSError, TypeError) as e:
        raise LiftError(f"cannot lift {getattr(fn, '__name__', fn)!r}: "
                        f"source is unavailable ({e}); pass source text to "
                        f"lift_source() instead")
    fnode, _ = _function_node("".join(lines))
    env = _base_env(getattr(fn, "__globals__", {}))
    if getattr(fn, "__closure__", None):
        for cname, cell in zip(fn.__code__.co_freevars, fn.__closure__):
            try:
                env[cname] = cell.cell_contents
            except ValueError:
                pass  # unfilled cell
    inputs = []
    for pname, p in inspect.signature(fn).parameters.items():
        if p.kind not in (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                          inspect.Parameter.KEYWORD_ONLY):
            raise LiftError(f"cannot lift {fn.__name__}(): *args/**kwargs "
                            f"parameters are not liftable program inputs")
        default = () if p.default is inspect.Parameter.empty else p.default
        inputs.append((pname, default))
    lifter = _Lifter(fnode, env, name=name or fn.__name__,
                     relations=relations, inputs=inputs,
                     filename=fn.__code__.co_filename, line_offset=lnum - 1)
    return lifter.lift()


def lift_source(source: str, *, env: Optional[Dict[str, object]] = None,
                name: Optional[str] = None,
                relations: Sequence[Tuple] = ()) -> Program:
    """Lift a function from *source text* (no live function object needed).

    ``env`` supplies the trace-time names the function body references
    (``q``, ``col``, ``param``, markers, constants). Parameter defaults must
    be literals. Used by tooling and the round-trip property tests."""
    fnode, _ = _function_node(source)
    args = fnode.args
    if args.vararg or args.kwarg:
        raise LiftError(f"cannot lift {fnode.name}(): *args/**kwargs")

    def literal(a, d):
        if d is None:
            return ()
        try:
            return ast.literal_eval(d)
        except ValueError:
            raise LiftError(f"cannot lift {fnode.name}(): parameter "
                            f"{a.arg!r} default must be a literal")

    positional = list(args.posonlyargs) + list(args.args)
    defaults = [None] * (len(positional) - len(args.defaults)) \
        + list(args.defaults)
    inputs = [(a.arg, literal(a, d)) for a, d in zip(positional, defaults)]
    inputs += [(a.arg, literal(a, d))
               for a, d in zip(args.kwonlyargs, args.kw_defaults)]
    lifter = _Lifter(fnode, _base_env(env), name=name or fnode.name,
                     relations=relations, inputs=inputs)
    return lifter.lift()
