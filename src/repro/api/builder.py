"""Tracing program builder: the lowering target of the Region IR frontends.

The primary way into Cobra is a **plain Python function** handed to
``session.trace`` / ``repro.api.lift``: the AST lifter lowers real
``for``/``if``/``while`` code onto THIS builder, which is the single
emission path for Region IR. Use the builder directly as the **escape
hatch** — programs outside the liftable subset (or tooling that constructs
programs programmatically) record statements as straight-line code inside
``with``-scoped loops and conditionals and produce the identical IR::

    b = ProgramBuilder("P0")
    b.relate("orders", "o_customer_sk", "customer", "c_customer_sk",
             name="customer")
    result = b.let("result", b.empty_list())
    with b.loop(b.load_all("orders"), var="o") as o:
        cust = b.let("cust", o.customer)           # ORM navigation (N+1)
        val = b.let("val", b.call("myFunc", o.o_id, cust.c_birth_year))
        b.add(result, val)
    p0 = b.build(outputs=(result,))

Control flow covers everything the lifter emits: ``loop``/``when``/
``otherwise`` plus ``while_`` guarded loops and the early-exit statements
``brk``/``cont``/``ret`` (break / continue / early return).

Three kinds of handles flow through user code:

  * :class:`Expr` — wraps an ``IExpr``; Python operators (``+ - * / ==``,
    ...) trace into ``IBin`` nodes, attribute access into ``IField`` (or
    ``INav`` when a relationship is registered for the variable's table).
  * :class:`Q` — a fluent relational query handle from :func:`q`:
    ``q("tasks").where(col("t_role_id").eq(param("rid"))).bind(rid=x.r_id)``.
  * :class:`VarHandle` — a named program variable (from ``let`` / ``loop``).

Scoping rule (matches the hand-built programs exactly): a loop body or
conditional branch with one region stays unwrapped; multiple regions become
a ``SeqRegion``; the program top level is always a ``SeqRegion``.
"""

from __future__ import annotations

import contextlib
import itertools
from typing import Dict, List, Optional, Sequence, Tuple, Union

from ..relational.algebra import (AggSpec, Aggregate, Col, Join, Limit,
                                  OrderBy, Param, Project, Query, Scalar,
                                  Scan, Select)
from ..core.regions import (Assign, BasicBlock, BreakStmt, CacheByColumn,
                            CollectionAdd, CondRegion, ContinueStmt, IBin,
                            ICacheLookup, ICall, IConst, IEmptyList, IEmptyMap,
                            IExpr, IField, IIndex, ILen, ILoadAll, INav,
                            IQuery, IQueryValues, IScalarQuery, IVar,
                            LoopRegion, MapPut, NoOp, Prefetch, Program,
                            Region, ReturnStmt, SeqRegion, Stmt, UpdateRow,
                            WhileRegion)

__all__ = ["ProgramBuilder", "Expr", "VarHandle", "Q", "q", "col", "param"]


# --------------------------------------------------------------------------
# Relational query handles
# --------------------------------------------------------------------------

def col(name: str) -> Col:
    """Column reference for relational predicates/projections."""
    return Col(name)


def param(name: str) -> Param:
    """Named query parameter, bound later via ``Q.bind(name=<expr>)``."""
    return Param(name)


class Q:
    """Fluent wrapper over a relational ``Query`` tree plus pending
    parameter bindings (imperative expressions for each ``Param``)."""

    __slots__ = ("query", "bindings")

    def __init__(self, query: Query,
                 bindings: Tuple[Tuple[str, IExpr], ...] = ()):
        self.query = query
        self.bindings = bindings

    # ------------------------------------------------------------- operators
    def where(self, pred: Scalar) -> "Q":
        return Q(Select(pred, self.query), self.bindings)

    def select(self, *cols: str, **computed: Scalar) -> "Q":
        return Q(Project(tuple(cols), self.query,
                         tuple(sorted(computed.items()))), self.bindings)

    def join(self, other: Union["Q", Query, str], left_key: str,
             right_key: str) -> "Q":
        rhs = q(other)
        return Q(Join(self.query, rhs.query, left_key, right_key),
                 self.bindings + rhs.bindings)

    def order_by(self, *keys: str, descending: bool = False) -> "Q":
        return Q(OrderBy(tuple(keys), self.query, descending), self.bindings)

    def limit(self, k: int) -> "Q":
        return Q(Limit(k, self.query), self.bindings)

    def agg(self, group_by: Sequence[str] = (), **aggs) -> "Q":
        """``.agg(total=("sum", "o_amt"), n=("count", None))``"""
        specs = tuple(AggSpec(func, c, out)
                      for out, (func, c) in sorted(aggs.items()))
        return Q(Aggregate(tuple(group_by), specs, self.query), self.bindings)

    def bind(self, **exprs) -> "Q":
        """Bind query ``Param``s to imperative expressions."""
        new = tuple((n, _ir(e)) for n, e in sorted(exprs.items()))
        return Q(self.query, self.bindings + new)

    def sql(self) -> str:
        return self.query.sql()

    def __repr__(self):
        return f"q[{self.query.sql()}]"


def q(source: Union[str, Query, Q]) -> Q:
    """Query handle: ``q("orders")`` scans a table; also accepts an existing
    relational ``Query`` tree or another handle (idempotent)."""
    if isinstance(source, Q):
        return source
    if isinstance(source, Query):
        return Q(source)
    if isinstance(source, str):
        return Q(Scan(source))
    raise TypeError(f"q() takes a table name or Query, got {type(source)}")


# --------------------------------------------------------------------------
# Imperative expression handles
# --------------------------------------------------------------------------

def _ir(v) -> IExpr:
    """Coerce a user-facing value into an IExpr."""
    if isinstance(v, Expr):
        return v._ir
    if isinstance(v, IExpr):
        return v
    if isinstance(v, (int, float, str, bool)):
        return IConst(v)
    raise TypeError(f"cannot trace {type(v).__name__} into an expression")


class Expr:
    """Traced expression handle; operators build ``IBin`` / ``IField`` IR."""

    __slots__ = ("_ir", "_builder", "_table")

    def __init__(self, ir: IExpr, builder: Optional["ProgramBuilder"] = None,
                 table: Optional[str] = None):
        object.__setattr__(self, "_ir", ir)
        object.__setattr__(self, "_builder", builder)
        object.__setattr__(self, "_table", table)

    @property
    def ir(self) -> IExpr:
        return self._ir

    # ------------------------------------------------------------ operators
    def _bin(self, op, other, swap=False):
        l, r = _ir(self), _ir(other)
        if swap:
            l, r = r, l
        return Expr(IBin(op, l, r), self._builder)

    def __add__(self, o):      return self._bin("+", o)
    def __radd__(self, o):     return self._bin("+", o, swap=True)
    def __sub__(self, o):      return self._bin("-", o)
    def __rsub__(self, o):     return self._bin("-", o, swap=True)
    def __mul__(self, o):      return self._bin("*", o)
    def __rmul__(self, o):     return self._bin("*", o, swap=True)
    def __truediv__(self, o):  return self._bin("/", o)
    def __rtruediv__(self, o): return self._bin("/", o, swap=True)
    def __eq__(self, o):       return self._bin("==", o)   # type: ignore[override]
    def __ne__(self, o):       return self._bin("!=", o)   # type: ignore[override]
    def __lt__(self, o):       return self._bin("<", o)
    def __le__(self, o):       return self._bin("<=", o)
    def __gt__(self, o):       return self._bin(">", o)
    def __ge__(self, o):       return self._bin(">=", o)

    def and_(self, o):         return self._bin("and", o)
    def or_(self, o):          return self._bin("or", o)
    def min_(self, o):         return self._bin("min", o)
    def max_(self, o):         return self._bin("max", o)

    __hash__ = None  # traced handles are not container keys

    def __bool__(self):
        raise TypeError(
            "a traced Expr has no truth value — use it inside "
            "ProgramBuilder.when(...) instead of a Python `if`")

    # ----------------------------------------------------------- navigation
    def __getattr__(self, name: str):
        if name.startswith("_"):
            raise AttributeError(name)
        b, table = self._builder, self._table
        if b is not None and table is not None:
            rel = b._relationships.get((table, name))
            if rel is not None:
                fk, target, target_key = rel
                return Expr(INav(self._ir, fk, target, target_key), b,
                            table=target)
        return Expr(IField(self._ir, name), b)

    def nav(self, fk_field: str, target: str, target_key: str) -> "Expr":
        """Explicit ORM relationship navigation (the N+1 point query)."""
        return Expr(INav(self._ir, fk_field, target, target_key),
                    self._builder, table=target)

    def __getitem__(self, key) -> "Expr":
        """Subscript read ``coll[key]`` / ``m[key]`` on a traced value."""
        return Expr(IIndex(self._ir, _ir(key)), self._builder)

    def len(self) -> "Expr":
        return Expr(ILen(self._ir), self._builder)

    def __repr__(self):
        return f"Expr[{self._ir!r}]"


class VarHandle(Expr):
    """A named program variable (the result of ``let`` or a loop cursor)."""

    __slots__ = ("name",)

    def __init__(self, name: str, builder: "ProgramBuilder",
                 table: Optional[str] = None):
        super().__init__(IVar(name), builder, table)
        object.__setattr__(self, "name", name)

    def __repr__(self):
        return f"VarHandle[{self.name}]"


def _var_name(v: Union[str, VarHandle]) -> str:
    return v.name if isinstance(v, VarHandle) else v


# --------------------------------------------------------------------------
# The builder
# --------------------------------------------------------------------------

class ProgramBuilder:
    """Records statements into region scopes; ``build()`` emits a Program."""

    def __init__(self, name: str):
        self.name = name
        self._scopes: List[List[Region]] = [[]]
        self._relationships: Dict[Tuple[str, str], Tuple[str, str, str]] = {}
        self._inputs: List[Tuple[str, object]] = []
        self._fresh = itertools.count()

    # ------------------------------------------------------------- plumbing
    def _emit(self, region: Region) -> Region:
        self._scopes[-1].append(region)
        return region

    def _stmt(self, stmt: Stmt) -> Region:
        return self._emit(BasicBlock(stmt))

    def _close_scope(self, parts: List[Region]) -> Region:
        if not parts:
            return BasicBlock(NoOp("empty-scope"))
        if len(parts) == 1:
            return parts[0]
        return SeqRegion(tuple(parts))

    def _fresh_var(self, prefix: str = "v") -> str:
        return f"_{prefix}{next(self._fresh)}"

    # ---------------------------------------------------------- registration
    def relate(self, table: str, fk_field: str, target: str, target_key: str,
               name: Optional[str] = None) -> None:
        """Register a FK relationship so ``row.<name>`` traces to ORM
        navigation (``INav``), like a Hibernate ``@ManyToOne`` attribute."""
        self._relationships[(table, name or target)] = (fk_field, target,
                                                        target_key)

    def input(self, name: str, default: object = ()) -> VarHandle:
        """Declare a program input (bound per-execution via ``run(**params)``)."""
        self._inputs.append((name, default))
        return VarHandle(name, self)

    # ---------------------------------------------------------- expressions
    def const(self, value) -> Expr:
        return Expr(IConst(value), self)

    def var(self, name: str) -> VarHandle:
        return VarHandle(name, self)

    def empty_list(self) -> Expr:
        return Expr(IEmptyList(), self)

    def empty_map(self) -> Expr:
        return Expr(IEmptyMap(), self)

    def call(self, func: str, *args) -> Expr:
        return Expr(ICall(func, tuple(_ir(a) for a in args)), self)

    def load_all(self, table: str) -> Expr:
        """ORM ``loadAll(Entity.class)`` — full-table fetch."""
        return Expr(ILoadAll(table), self, table=table)

    def query(self, source: Union[str, Query, Q]) -> Expr:
        """``executeQuery(q)`` as an expression (a ``Table`` value)."""
        h = q(source)
        tbl = h.query.table if isinstance(h.query, Scan) else None
        return Expr(IQuery(h.query, h.bindings), self, table=tbl)

    def scalar_query(self, source: Union[str, Query, Q], column: str) -> Expr:
        h = q(source)
        return Expr(IScalarQuery(h.query, column, h.bindings), self)

    def query_values(self, source: Union[str, Query, Q], column: str) -> Expr:
        h = q(source)
        return Expr(IQueryValues(h.query, column), self)

    def cache_lookup(self, table: str, column: str, key,
                     all_matches: bool = False) -> Expr:
        """``Utils.lookupCache`` over a prefetched column-keyed cache."""
        return Expr(ICacheLookup(table, column, _ir(key), all_matches), self,
                    table=table)

    # ----------------------------------------------------------- statements
    def let(self, name: str, expr) -> VarHandle:
        """``name = expr`` — also the (re)assignment form."""
        self._stmt(Assign(name, _ir(expr)))
        table = expr._table if isinstance(expr, Expr) else None
        return VarHandle(name, self, table=table)

    def assign(self, target: Union[str, VarHandle], expr) -> VarHandle:
        return self.let(_var_name(target), expr)

    def add(self, target: Union[str, VarHandle], expr) -> None:
        """``target.add(expr)`` — collection append."""
        self._stmt(CollectionAdd(_var_name(target), _ir(expr)))

    def put(self, target: Union[str, VarHandle], key, value) -> None:
        """``target.put(key, value)`` — map insert."""
        self._stmt(MapPut(_var_name(target), _ir(key), _ir(value)))

    def prefetch(self, source: Union[str, Query, Q], by: str,
                 cache_name: Optional[str] = None) -> None:
        """``prefetch(R, A)``: fetch + cache keyed by column ``by``."""
        self._stmt(Prefetch(q(source).query, by, cache_name))

    def cache_by_column(self, var: Union[str, VarHandle], column: str) -> None:
        self._stmt(CacheByColumn(_var_name(var), column))

    def update_row(self, table: str, set_col: str, value, key_col: str,
                   key) -> None:
        """``UPDATE table SET set_col = value WHERE key_col = key``."""
        self._stmt(UpdateRow(table, set_col, _ir(value), key_col, _ir(key)))

    def noop(self, note: str = "") -> None:
        self._stmt(NoOp(note))

    # --------------------------------------------------------- control flow
    @contextlib.contextmanager
    def loop(self, source, var: Optional[str] = None, label: str = ""):
        """Cursor loop ``for (var : source)``; yields the cursor handle.

        ``source`` may be a ``Q``/``Query``/table name (executed as a query),
        an expression from :meth:`load_all`, or any traced collection
        expression (e.g. a worklist input variable)."""
        if isinstance(source, (str, Query, Q)) and not isinstance(source, Expr):
            src_expr = self.load_all(source) if isinstance(source, str) \
                else self.query(source)
        else:
            src_expr = source
        src_ir = _ir(src_expr)
        table = src_expr._table if isinstance(src_expr, Expr) else None
        name = var or self._fresh_var()
        cursor = VarHandle(name, self, table=table)
        self._scopes.append([])
        try:
            yield cursor
        finally:
            body = self._close_scope(self._scopes.pop())
            self._emit(LoopRegion(name, src_ir, body, label))

    @contextlib.contextmanager
    def while_(self, pred, label: str = ""):
        """Guarded loop ``while pred { ... }`` (a :class:`WhileRegion`)."""
        self._scopes.append([])
        try:
            yield
        finally:
            body = self._close_scope(self._scopes.pop())
            self._emit(WhileRegion(_ir(pred), body, label))

    def brk(self) -> None:
        """``break`` — exit the nearest enclosing loop."""
        self._stmt(BreakStmt())

    def cont(self) -> None:
        """``continue`` — skip to the next iteration of the nearest loop."""
        self._stmt(ContinueStmt())

    def ret(self) -> None:
        """Early ``return`` — exit the program; outputs keep their current
        values (assign them before calling this)."""
        self._stmt(ReturnStmt())

    @contextlib.contextmanager
    def when(self, pred):
        """Conditional region ``if pred { ... }``; chain :meth:`otherwise`."""
        self._scopes.append([])
        try:
            yield
        finally:
            then_r = self._close_scope(self._scopes.pop())
            self._emit(CondRegion(_ir(pred), then_r))

    @contextlib.contextmanager
    def otherwise(self):
        """Else-branch for the immediately preceding :meth:`when` block."""
        prev = self._scopes[-1][-1] if self._scopes[-1] else None
        if not isinstance(prev, CondRegion) or prev.else_r is not None:
            raise RuntimeError("otherwise() must directly follow a when() block")
        self._scopes.append([])
        try:
            yield
        finally:
            else_r = self._close_scope(self._scopes.pop())
            self._scopes[-1][-1] = CondRegion(prev.pred, prev.then_r, else_r,
                                              prev.label)

    # ---------------------------------------------------------------- build
    def build(self, outputs: Sequence[Union[str, VarHandle]] = (),
              inputs: Optional[Sequence[Tuple[str, object]]] = None) -> Program:
        if len(self._scopes) != 1:
            raise RuntimeError("unclosed loop()/when() scope at build()")
        body = SeqRegion(tuple(self._scopes[0]))  # top level is always a seq
        ins = tuple(inputs) if inputs is not None else tuple(self._inputs)
        return Program(self.name, body, tuple(_var_name(o) for o in outputs),
                       ins)
