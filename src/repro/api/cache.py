"""Stats-versioned plan cache: compile once, execute many.

Keyed by (program fingerprint, cost-catalog key, optimizer-config key,
database stats version). The stats version is a monotonic counter on
``DatabaseServer`` bumped whenever table statistics change (``analyze()``
or table replacement), so a cached plan is automatically invalidated when
the data the cost model saw is stale — the winning plan may legitimately
flip (e.g. P1 join → P2 prefetch) after cardinalities shift.

Entries are LRU-evicted beyond ``max_entries``; hit/miss/eviction counters
feed ``CobraSession.telemetry``.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, Optional, Tuple

__all__ = ["PlanCache", "PlanCacheKey", "program_fingerprint"]


def program_fingerprint(program) -> str:
    """Stable content hash of a Program's structural key (name excluded, so
    two identically-shaped programs share compiled plans)."""
    key = program.key()
    # drop the name component ("P", name, body_key, outputs) -> structure
    # only; declared inputs (name, default) are NOT part of Program.key()
    # but change run() semantics, so they must distinguish fingerprints
    structural = (key[0],) + tuple(key[2:]) + (tuple(program.inputs),)
    return hashlib.sha256(repr(structural).encode()).hexdigest()[:32]


@dataclasses.dataclass(frozen=True)
class PlanCacheKey:
    program_fp: str
    catalog_key: Tuple
    config_key: Tuple
    stats_version: int


class PlanCache:
    """A small LRU over compiled :class:`~repro.core.search.OptimizationResult`s."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: "OrderedDict[PlanCacheKey, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: PlanCacheKey) -> Optional[object]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            # a stale sibling (same program/catalog/config, older stats
            # version) counts as an invalidation, not a cold miss
            for k in self._entries:
                if (k.program_fp == key.program_fp
                        and k.catalog_key == key.catalog_key
                        and k.config_key == key.config_key
                        and k.stats_version != key.stats_version):
                    self.invalidations += 1
                    break
            return None
        self.hits += 1
        self._entries.move_to_end(key)
        return entry

    def put(self, key: PlanCacheKey, value: object) -> None:
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1

    def drop_stale(self, current_stats_version: int) -> int:
        """Eagerly drop entries compiled against older statistics."""
        stale = [k for k in self._entries
                 if k.stats_version != current_stats_version]
        for k in stale:
            del self._entries[k]
        return len(stale)

    def clear(self) -> None:
        self._entries.clear()

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "invalidations": self.invalidations}
