"""Stats-versioned plan cache: compile once, execute many.

Keyed by (program fingerprint, cost-catalog key, optimizer-config key,
stats token). The stats token is the vector of PER-TABLE statistics
versions for exactly the tables the program touches (``program_tables``),
so a cached plan is invalidated when the statistics its cost model
consumed go stale — the winning plan may legitimately flip (e.g. P1 join
→ P2 prefetch) after cardinalities shift — while an ``analyze()`` of an
unrelated table leaves it hot.

Entries are LRU-evicted beyond ``max_entries``; hit/miss/eviction counters
feed ``CobraSession.telemetry``. The disk-backed, cross-session variant
lives in ``repro.runtime.store.PlanStore`` and shares this key vocabulary.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, Optional, Tuple

from ..core.context import ONE_SHOT

__all__ = ["ArtifactCache", "PlanCache", "PlanCacheKey",
           "program_fingerprint", "program_tables", "program_write_tables",
           "program_read_tables", "program_sites", "program_param_sites",
           "query_tables"]


def program_fingerprint(program) -> str:
    """Stable content hash of a Program's structural key (name excluded, so
    two identically-shaped programs share compiled plans)."""
    key = program.key()
    # drop the name component ("P", name, body_key, outputs) -> structure
    # only; declared inputs (name, default) are NOT part of Program.key()
    # but change run() semantics, so they must distinguish fingerprints
    structural = (key[0],) + tuple(key[2:]) + (tuple(program.inputs),)
    return hashlib.sha256(repr(structural).encode()).hexdigest()[:32]


def query_tables(q) -> Tuple[str, ...]:
    """All base tables a relational ``Query`` tree scans."""
    from ..relational.algebra import scan_tables
    return scan_tables(q)


def program_tables(program) -> Tuple[str, ...]:
    """All base tables a Program touches (queries, ORM navigations, cache
    lookups, prefetches, updates). The plan-cache key carries the stats
    versions of exactly these tables."""
    from ..core.regions import (BasicBlock, CondRegion, ICacheLookup, ILoadAll,
                                INav, IExpr, LoopRegion, Prefetch, SeqRegion,
                                UpdateRow, WhileRegion)
    out = set()

    def from_expr(e):
        if not isinstance(e, IExpr):
            return
        if isinstance(e, ILoadAll):
            out.add(e.table)
            return
        if isinstance(e, INav):
            out.add(e.target)
        if isinstance(e, ICacheLookup):
            out.add(e.table)
        q = getattr(e, "query", None)
        if q is not None:
            out.update(query_tables(q))
        for attr in ("base", "left", "right", "keyexpr"):
            k = getattr(e, attr, None)
            if k is not None:
                from_expr(k)
        for a in getattr(e, "args", ()):
            from_expr(a)
        for _, b in getattr(e, "bindings", ()):
            from_expr(b)

    def from_stmt(s):
        if isinstance(s, Prefetch):
            out.update(query_tables(s.query))
            return
        if isinstance(s, UpdateRow):
            out.add(s.table)
        for attr in ("expr", "val", "keyexpr", "valexpr"):
            e = getattr(s, attr, None)
            if e is not None:
                from_expr(e)

    def walk(r):
        if isinstance(r, BasicBlock):
            from_stmt(r.stmt)
        elif isinstance(r, SeqRegion):
            for p in r.parts:
                walk(p)
        elif isinstance(r, LoopRegion):
            from_expr(r.source)
            walk(r.body)
        elif isinstance(r, CondRegion):
            from_expr(r.pred)
            walk(r.then_r)
            if r.else_r is not None:
                walk(r.else_r)
        elif isinstance(r, WhileRegion):
            from_expr(r.pred)
            walk(r.body)

    walk(program.body)
    return tuple(sorted(out))


def program_write_tables(program) -> Tuple[str, ...]:
    """The base tables a Program WRITES (``UpdateRow`` statements only).

    The write-set half of the read/write split: sites over tables outside
    this set stay shareable through the serving site cache even when the
    program mutates other tables (``runtime.batch``'s write-set-aware
    sequential path)."""
    from ..core.regions import write_tables
    return write_tables(program)


def program_read_tables(program) -> Tuple[str, ...]:
    """The base tables a Program only READS: ``program_tables`` minus
    ``program_write_tables``."""
    writes = set(program_write_tables(program))
    return tuple(t for t in program_tables(program) if t not in writes)


def program_param_sites(program) -> Tuple[str, ...]:
    """The PARAMETERIZED query-site groups a Program contains (``qdiv:…``
    keys, one per distinct base-table set among its parameterized query /
    scalar-query / prefetch sites).

    These are the sites whose fetch cost depends on how often bindings
    repeat at runtime: the serving site cache observes their distinct-
    binding fraction and the cost model amortizes by it
    (:meth:`~repro.core.cost.CostModel.param_site_amortization`). Like
    iteration sites, they participate in a program's context fingerprint,
    so a published diversity moves exactly the plans that can act on it.

    Groups over tables the program WRITES are excluded: the runtime never
    caches those sites (each invocation must see earlier writes), so no
    published diversity can be delivered there — the cost model refuses it
    too (its ``write_tables`` guard) and keying plans on it would only
    cause spurious recompiles."""
    from ..core.context import param_group_key
    return _param_site_keys(program,
                            lambda q: param_group_key(query_tables(q)))


def program_param_prov_sites(program) -> Tuple[str, ...]:
    """The parameterized sites' PROVENANCE keys (``qprov:…``,
    :func:`~repro.core.context.param_prov_key`): one per distinct
    (base-table set, param-compared columns) pair among the program's
    parameterized sites. Finer than :func:`program_param_sites`'s table
    groups — this is what lets two differently-diverse sites over one
    table carry separately-published diversities — with the same
    write-table exclusion."""
    from ..core.context import param_prov_key
    from ..core.cost import query_param_cols
    return _param_site_keys(
        program,
        lambda q: param_prov_key(query_tables(q), query_param_cols(q)))


def _param_site_keys(program, key_of) -> Tuple[str, ...]:
    """Shared walk behind :func:`program_param_sites` /
    :func:`program_param_prov_sites`: apply ``key_of`` to every
    parameterized (or pre-bound) query site over non-written tables."""
    from ..core.cost import query_has_params
    from ..core.regions import (BasicBlock, IExpr, LoopRegion, Prefetch,
                                Region)
    out = set()
    written = set(program_write_tables(program))

    def from_query(q, bindings=()):
        if (bindings or query_has_params(q)) \
                and not written & set(query_tables(q)):
            out.add(key_of(q))

    def from_expr(e):
        if not isinstance(e, IExpr):
            return
        q = getattr(e, "query", None)
        if q is not None:
            from_query(q, getattr(e, "bindings", ()))
        for attr in ("base", "left", "right", "keyexpr"):
            k = getattr(e, attr, None)
            if k is not None:
                from_expr(k)
        for a in getattr(e, "args", ()):
            from_expr(a)
        for _, b in getattr(e, "bindings", ()):
            from_expr(b)

    def walk(r: Region):
        if isinstance(r, BasicBlock):
            s = r.stmt
            if isinstance(s, Prefetch):
                from_query(s.query)
            for attr in ("expr", "val", "keyexpr", "valexpr"):
                e = getattr(s, attr, None)
                if e is not None:
                    from_expr(e)
        elif isinstance(r, LoopRegion):
            from_expr(r.source)
        pred = getattr(r, "pred", None)
        if pred is not None:
            from_expr(pred)
        for c in r.children():
            walk(c)

    walk(program.body)
    return tuple(sorted(out))


def program_sites(program) -> Tuple[str, ...]:
    """The observation sites a Program contains that table statistics
    cannot estimate: while guards and cursor loops over collection (non-
    query) sources (iteration counts), plus its parameterized query-site
    groups (binding diversity, :func:`program_param_sites`). An
    :class:`~repro.core.context.ExecutionContext`'s fingerprint restricts
    its observed stats to exactly these, so observations at other programs'
    sites leave this program's plans hot."""
    from ..core.context import loop_site_key, while_site_key
    from ..core.regions import (ILoadAll, IQuery, LoopRegion, Region,
                                WhileRegion)
    out = []

    def walk(r: Region):
        if isinstance(r, WhileRegion):
            out.append(while_site_key(r.pred))
        elif isinstance(r, LoopRegion) and not isinstance(
                r.source, (IQuery, ILoadAll)):
            out.append(loop_site_key(r.var, r.source))
        for c in r.children():
            walk(c)

    walk(program.body)
    out.extend(program_param_sites(program))
    out.extend(program_param_prov_sites(program))
    return tuple(sorted(set(out)))


@dataclasses.dataclass(frozen=True)
class PlanCacheKey:
    program_fp: str
    catalog_key: Tuple
    config_key: Tuple
    # per-table stats token ((table, version), ...) for the tables the
    # program touches; any hashable works (unit tests use plain ints)
    stats_version: object
    # ExecutionContext fingerprint (batch size + observed iteration stats
    # restricted to the program's sites); default = one-shot/no-stats, so
    # directly-constructed keys in unit tests keep working
    context_key: Tuple = ONE_SHOT.fingerprint()


class PlanCache:
    """A small LRU over compiled :class:`~repro.core.search.OptimizationResult`s."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: "OrderedDict[PlanCacheKey, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: PlanCacheKey) -> Optional[object]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            # a stale sibling (same program/catalog/config, older stats
            # version) counts as an invalidation, not a cold miss
            for k in self._entries:
                if (k.program_fp == key.program_fp
                        and k.catalog_key == key.catalog_key
                        and k.config_key == key.config_key
                        and k.stats_version != key.stats_version):
                    self.invalidations += 1
                    break
            return None
        self.hits += 1
        self._entries.move_to_end(key)
        return entry

    def put(self, key: PlanCacheKey, value: object) -> None:
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1

    def drop_stale(self, current_stats_version: int) -> int:
        """Eagerly drop entries compiled against older statistics."""
        stale = [k for k in self._entries
                 if k.stats_version != current_stats_version]
        for k in stale:
            del self._entries[k]
        return len(stale)

    def clear(self) -> None:
        self._entries.clear()

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "invalidations": self.invalidations}


class ArtifactCache:
    """LRU over compiled execution artifacts (the lowered-executable tier).

    The compiled sibling of :class:`PlanCache`: where the plan cache memoizes
    the *optimizer's* output (which plan wins), this memoizes the *lowering's*
    output (the columnar executable for that plan), content-addressed by the
    same fingerprint vocabulary (see ``runtime.store.content_address``).
    Invalidation is predicate-based because artifact staleness is decided by
    the owner (:class:`repro.compiled.manager.CompileManager` drops artifacts
    whose programs touch drifted tables)."""

    def __init__(self, max_entries: int = 64):
        self.max_entries = max_entries
        self._entries: "OrderedDict[object, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key) -> Optional[object]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        self._entries.move_to_end(key)
        return entry

    def put(self, key, value) -> None:
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1

    def invalidate(self, pred) -> int:
        """Drop every entry for which ``pred(key, value)`` is true."""
        stale = [k for k, v in self._entries.items() if pred(k, v)]
        for k in stale:
            del self._entries[k]
        self.invalidations += len(stale)
        return len(stale)

    def clear(self) -> None:
        self._entries.clear()

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "invalidations": self.invalidations}
