"""Cobra's public API: session, config, tracing frontend, plan cache.

    from repro.api import CobraSession, OptimizerConfig, ProgramBuilder, q

    session = CobraSession(db, CostCatalog(SLOW_REMOTE),
                           config=OptimizerConfig.preset("paper-exp1-3"))
    exe = session.compile(program)     # memo search once, then cached
    out = exe.run()                    # execute-many

See ``examples/quickstart.py`` for the end-to-end walkthrough and
``repro.api.builder`` for the tracing program frontend.
"""

from ..core.context import ExecutionContext, ONE_SHOT, StatsProfile
from ..core.cost import CostModel
from .builder import Expr, ProgramBuilder, Q, VarHandle, col, param, q
from .cache import (PlanCache, PlanCacheKey, program_fingerprint,
                    program_param_sites, program_read_tables, program_sites,
                    program_tables, program_write_tables, query_tables)
from .config import OptimizerConfig, PRESETS
from .lift import (LiftError, cache_by_column, cache_lookup, lift_program,
                   lift_source, load_all, noop, prefetch, query_values,
                   scalar_query, update_row)
from .rules import (CobraRule, RuleSet, SlotView, add_slot_variant,
                    cobra_rule, slot_view)
from .session import CobraSession, Executable, ExecutionResult, PlanReport

__all__ = [
    "CobraSession", "Executable", "ExecutionResult", "PlanReport",
    "OptimizerConfig", "PRESETS",
    "ExecutionContext", "ONE_SHOT", "StatsProfile", "CostModel",
    "RuleSet", "CobraRule", "cobra_rule", "SlotView", "slot_view",
    "add_slot_variant",
    "ProgramBuilder", "Expr", "VarHandle", "Q", "q", "col", "param",
    "LiftError", "lift_program", "lift_source",
    "load_all", "cache_lookup", "scalar_query", "query_values",
    "prefetch", "update_row", "cache_by_column", "noop",
    "PlanCache", "PlanCacheKey", "program_fingerprint", "program_sites",
    "program_param_sites", "program_read_tables", "program_tables",
    "program_write_tables", "query_tables",
]
