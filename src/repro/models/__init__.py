"""Model definitions: architecture registry, layers, composable assembly."""

from .arch import ArchConfig, get_arch, list_archs, register_arch
from .model import (forward, init_params, lm_loss, loss_fn, make_caches)
from .layers import NULL_POLICY, NullPolicy

__all__ = [
    "ArchConfig", "get_arch", "list_archs", "register_arch",
    "forward", "init_params", "lm_loss", "loss_fn", "make_caches",
    "NULL_POLICY", "NullPolicy",
]
