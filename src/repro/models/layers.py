"""Model layers in pure JAX (params = pytrees of jnp arrays).

Covers every assigned architecture family:
  * GQA attention with RoPE / M-RoPE, optional sliding window (SWA) and
    chunked local attention (llama4-style);
  * MLA (multi-head latent attention, MiniCPM3): low-rank compressed KV with
    a shared rope head — the KV cache stores the LATENT, not full K/V;
  * SwiGLU MLP;
  * MoE with top-k routing, capacity-based sort-free dispatch (one-hot-free,
    scatter into (E, C, d) buffers — TPU/MXU friendly, EP-shardable);
  * RWKV6 time/channel mix (data-dependent per-channel decay) and Mamba2
    (SSD, scalar per-head decay), both via one numerically-stable chunked
    decay-linear-attention primitive with lax.scan across chunks;
  * embeddings and the shared norm/linear primitives.

Sharding: every layer threads a ``ShardingPolicy`` (see
``repro.launch.sharding``); ``pol.cs(x, name)`` applies a
with_sharding_constraint when a rule for the logical name exists. The Cobra
distributed planner emits these policies.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .arch import ArchConfig

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# Sharding policy hook
# --------------------------------------------------------------------------

class NullPolicy:
    """No-op policy (single device / tests)."""

    def cs(self, x, name: str):
        return x

    remat: str = "none"
    use_kernels: bool = False


NULL_POLICY = NullPolicy()


# --------------------------------------------------------------------------
# Primitives
# --------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (y * w).astype(dt)


def init_rms(key, d):
    return jnp.ones((d,), jnp.float32)


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.bfloat16):
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def act_fn(kind: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[kind]


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------

def rope_freqs(hd_rot: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, hd_rot, 2, dtype=jnp.float32) / hd_rot))


def apply_rope(x, positions, theta: float = 1e4, mrope_sections: Optional[Tuple[int, ...]] = None):
    """x: (B, T, H, hd). positions: (B, T) or (B, T, 3) for M-RoPE.

    M-RoPE (Qwen2-VL): the rotary half-dims are split into sections, each
    rotated by its own position stream (temporal / height / width). For text
    tokens the three streams coincide."""
    B, T, H, hd = x.shape
    half = hd // 2
    freqs = rope_freqs(hd)  # (half,)
    if mrope_sections is None:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B,T,half)
    else:
        assert positions.ndim == 3 and positions.shape[-1] == len(mrope_sections)
        parts = []
        start = 0
        for i, sec in enumerate(mrope_sections):
            parts.append(positions[..., i:i + 1].astype(jnp.float32)
                         * freqs[start:start + sec])
            start += sec
        ang = jnp.concatenate(parts, axis=-1)  # (B,T,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * cos - x2f * sin,
                            x2f * cos + x1f * sin], axis=-1).astype(dt)


def default_mrope_sections(hd: int) -> Tuple[int, int, int]:
    half = hd // 2
    a = half // 4
    return (half - 2 * a, a, a)  # e.g. hd=128 → (32,16,16)


# --------------------------------------------------------------------------
# Attention (GQA + SWA/chunked) and MLA
# --------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 8)
    if cfg.attn_kind == "mla":
        qr = cfg.q_lora_rank or d
        kvr = cfg.kv_lora_rank or d
        qk_dim = cfg.qk_rope_dim + cfg.qk_nope_dim
        return {
            "wq_a": dense_init(ks[0], (d, qr)),
            "q_norm": init_rms(ks[1], qr),
            "wq_b": dense_init(ks[2], (qr, H * qk_dim)),
            "wkv_a": dense_init(ks[3], (d, kvr + cfg.qk_rope_dim)),
            "kv_norm": init_rms(ks[4], kvr),
            "wkv_b": dense_init(ks[5], (kvr, H * (cfg.qk_nope_dim + cfg.vhd))),
            "wo": dense_init(ks[6], (H * cfg.vhd, d)),
        }
    return {
        "wq": dense_init(ks[0], (d, H * hd)),
        "wk": dense_init(ks[1], (d, KV * hd)),
        "wv": dense_init(ks[2], (d, KV * hd)),
        "wo": dense_init(ks[3], (H * hd, d)),
    }


def _attn_mask(Tq: int, Tk: int, q_offset, causal: bool,
               window: Optional[int], chunk: Optional[int]):
    """(Tq, Tk) boolean mask. q position i attends k position j."""
    qpos = q_offset + jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    m = jnp.ones((Tq, Tk), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    if chunk is not None:
        m &= (kpos // chunk) == (qpos // chunk)
    return m


def sdpa(q, k, v, mask=None, scale=None, pol=NULL_POLICY):
    """q: (B,Tq,H,hd) k/v: (B,Tk,KV,hd[v]); GQA broadcast; fp32 softmax."""
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qh = q.reshape(B, Tq, KV, rep, hd)
    scores = jnp.einsum("bqkrh,bskh->bkrqs", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrqs,bskh->bqkrh", p, v.astype(jnp.float32))
    return out.reshape(B, Tq, H, v.shape[-1]).astype(q.dtype)


def attention_gqa(params: Params, x, cfg: ArchConfig, positions,
                  cache: Optional[Dict] = None, cache_index=None,
                  pol=NULL_POLICY):
    """Returns (out, new_cache). cache: {"k","v"} of (B, S_max, KV, hd)."""
    B, T, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ params["wq"]).reshape(B, T, H, hd)
    k = (x @ params["wk"]).reshape(B, T, KV, hd)
    v = (x @ params["wv"]).reshape(B, T, KV, hd)
    q = pol.cs(q, "act_bthd")
    k = pol.cs(k, "act_btkd")
    v = pol.cs(v, "act_btkd")
    if cfg.rope_kind == "mrope":
        secs = default_mrope_sections(hd)
        pos3 = positions if positions.ndim == 3 else \
            jnp.repeat(positions[..., None], 3, axis=-1)
        q = apply_rope(q, pos3, mrope_sections=secs)
        k = apply_rope(k, pos3, mrope_sections=secs)
    elif cfg.rope_kind == "rope":
        q = apply_rope(q, positions)
        k = apply_rope(k, positions)
    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, cache_index, 0, 0))
        new_cache = {"k": ck, "v": cv}
        Tk = ck.shape[1]
        mask = _attn_mask(T, Tk, cache_index, True, cfg.window, cfg.chunk_size)
        # mask out beyond-written positions
        mask = mask & (jnp.arange(Tk)[None, :] <= cache_index + T - 1)
        out = sdpa(q, ck, cv, mask, pol=pol)
    else:
        mask = _attn_mask(T, T, 0, True, cfg.window, cfg.chunk_size)
        out = sdpa(q, k, v, mask, pol=pol)
    out = pol.cs(out, "act_bthd")
    y = out.reshape(B, T, H * hd) @ params["wo"]
    return pol.cs(y, "act_btd"), new_cache


def attention_mla(params: Params, x, cfg: ArchConfig, positions,
                  cache: Optional[Dict] = None, cache_index=None,
                  pol=NULL_POLICY):
    """MLA: KV compressed to a latent of kv_lora_rank (+ shared rope key).
    The cache stores the latent — this is the memory-term win for decode."""
    B, T, d = x.shape
    H = cfg.n_heads
    nope, rdim, vhd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.vhd
    kvr = cfg.kv_lora_rank or d

    q_lat = rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = (q_lat @ params["wq_b"]).reshape(B, T, H, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions)

    kv_all = x @ params["wkv_a"]                      # (B,T,kvr+rdim)
    kv_lat = rms_norm(kv_all[..., :kvr], params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv_all[..., kvr:][:, :, None, :], positions)  # (B,T,1,rdim)

    if cache is not None:
        lat = jax.lax.dynamic_update_slice(
            cache["lat"], kv_lat.astype(cache["lat"].dtype), (0, cache_index, 0))
        kr = jax.lax.dynamic_update_slice(
            cache["rope"], k_rope[:, :, 0, :].astype(cache["rope"].dtype),
            (0, cache_index, 0))
        new_cache = {"lat": lat, "rope": kr}
        kv_lat_full, k_rope_full = lat, kr[:, :, None, :]
        Tk = lat.shape[1]
        q_off = cache_index
    else:
        new_cache = None
        kv_lat_full, k_rope_full = kv_lat, k_rope
        Tk = T
        q_off = 0

    kv = (kv_lat_full @ params["wkv_b"]).reshape(B, Tk, H, nope + vhd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope_full, (B, Tk, H, rdim)).astype(k_nope.dtype)], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    mask = _attn_mask(T, Tk, q_off, True, cfg.window, cfg.chunk_size)
    if cache is not None:
        mask = mask & (jnp.arange(Tk)[None, :] <= cache_index + T - 1)
    out = sdpa(qfull, k, v, mask, scale=1.0 / math.sqrt(nope + rdim), pol=pol)
    y = out.reshape(B, T, H * vhd) @ params["wo"]
    return pol.cs(y, "act_btd"), new_cache


# --------------------------------------------------------------------------
# MLP / MoE
# --------------------------------------------------------------------------

def init_mlp(key, d, ff) -> Params:
    k1, k2 = jax.random.split(key)
    return {"w_in": dense_init(k1, (d, 2 * ff)),   # fused gate+up
            "w_out": dense_init(k2, (ff, d))}


def mlp(params: Params, x, act: str = "silu", pol=NULL_POLICY):
    gu = x @ params["w_in"]
    gu = pol.cs(gu, "act_btf2")
    g, u = jnp.split(gu, 2, axis=-1)
    h = act_fn(act)(g.astype(jnp.float32)).astype(x.dtype) * u
    y = h @ params["w_out"]
    return pol.cs(y, "act_btd")


def init_moe(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    mff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),
        "w_in": dense_init(ks[1], (E, d, 2 * mff)),
        "w_out": dense_init(ks[2], (E, mff, d)),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[3], d, mff * cfg.n_shared_experts)
    return p


def moe(params: Params, x, cfg: ArchConfig, pol=NULL_POLICY):
    """Top-k routing with capacity-based dispatch.

    Tokens are sorted by destination expert and scattered into an
    (E, C, d) buffer: expert compute is then one batched einsum — ideal for
    the MXU and shardable on the "model" axis (expert parallelism). Overflow
    beyond capacity is dropped (standard Switch-style); aux load-balance loss
    is returned for training."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    n = B * T
    xf = x.reshape(n, d)
    logits = (xf.astype(jnp.float32) @ params["router"])      # (n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # (n, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(max(8, math.ceil(n * k / E * cfg.capacity_factor)))
    flat_expert = gate_idx.reshape(-1)                        # (n*k,)
    # position of each (token, slot) within its expert, via sorted cumcount
    order = jnp.argsort(flat_expert)
    sorted_e = flat_expert[order]
    ones = jnp.ones_like(sorted_e)
    seg_pos = jax.lax.associative_scan(jnp.add, ones) - 1
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos_sorted = seg_pos - seg_start[sorted_e]
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)  # (n*k,)
    keep = pos < cap
    dst = jnp.where(keep, flat_expert * cap + pos, E * cap)   # overflow → trash

    buf = jnp.zeros((E * cap + 1, d), x.dtype)
    tok_rep = jnp.repeat(xf, k, axis=0)
    buf = buf.at[dst].set(tok_rep)
    buf = buf[:-1].reshape(E, cap, d)
    buf = pol.cs(buf, "moe_ecd")

    gu = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    g, u = jnp.split(gu, 2, axis=-1)
    h = act_fn(cfg.act)(g.astype(jnp.float32)).astype(x.dtype) * u
    out = jnp.einsum("ecf,efd->ecd", h, params["w_out"])
    out = pol.cs(out, "moe_ecd")

    out_flat = out.reshape(E * cap, d)
    out_flat = jnp.concatenate([out_flat, jnp.zeros((1, d), x.dtype)], 0)
    gathered = out_flat[dst]                                  # (n*k, d)
    w = (gate_vals.reshape(-1) * keep).astype(x.dtype)
    y = (gathered * w[:, None]).reshape(n, k, d).sum(axis=1)

    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], xf, cfg.act, pol=NULL_POLICY)

    # load-balance aux loss (Switch): E * Σ_e f_e * p_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[flat_expert].add(1.0) / (n * k)
    aux = E * jnp.sum(me * ce)
    return pol.cs(y.reshape(B, T, d), "act_btd"), aux


# --------------------------------------------------------------------------
# Chunked decay linear attention (shared by RWKV6 and Mamba2)
# --------------------------------------------------------------------------

def decay_linear_attention(r, kk, v, w_log, u=None, state=None,
                           chunk: Optional[int] = None, scalar_decay: bool = False,
                           pol=NULL_POLICY):
    """Numerically-stable chunked scan for S_t = diag(exp(w_log_t))·S_{t-1}
    + k_t ⊗ v_t, with output:
        u given  (RWKV6):  y_t = r_t·S_{t-1} + (u⊙k_t·r_t)·v_t
        u None   (Mamba2): y_t = r_t·S_t      (current token decayed in)

    Shapes: r/k/w_log (B,H,T,K), v (B,H,T,V), state (B,H,K,V).

    Stability: every exponential has exponent ≤ 0 (only benign underflow).
    Inter-chunk terms factor through the running log-decay A (≤ 0); the
    intra-chunk decay matrix is computed from pairwise DIFFERENCES — as a
    (C,C) outer difference when the decay is scalar per head (Mamba2), or a
    (C,C,K) difference tensor at a smaller chunk when per-channel (RWKV6).
    The Pallas kernel applies the same scheme blockwise in VMEM.
    """
    B, H, T, K = r.shape
    V = v.shape[-1]
    C = chunk if chunk is not None else (128 if scalar_decay else 32)
    C = min(C, T)
    if T % C != 0:
        pad = C - T % C
        z = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
        r, kk, v, w_log = z(r), z(kk), z(v), z(w_log)
        T_p = T + pad
    else:
        T_p = T
    nC = T_p // C
    rc = r.reshape(B, H, nC, C, K)
    kc = kk.reshape(B, H, nC, C, K)
    vc = v.reshape(B, H, nC, C, V)
    wc = w_log.reshape(B, H, nC, C, K).astype(jnp.float32)
    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)

    rwkv_mode = u is not None
    tt = jnp.arange(C)
    mask = (tt[:, None] > tt[None, :]) if rwkv_mode else (tt[:, None] >= tt[None, :])

    def chunk_step(S, inp):
        rC, kC, vC, wC = inp          # (B,H,C,K/V)
        A = jnp.cumsum(wC, axis=2)    # inclusive: A_t = Σ_{r≤t} w_r  (≤ 0)
        A_end = A[:, :, -1:, :]
        rf = rC.astype(jnp.float32)
        kf = kC.astype(jnp.float32)
        vf = vC.astype(jnp.float32)
        A_q = (A - wC) if rwkv_mode else A          # A_{t-1} vs A_t
        # ---- inter-chunk: y += (r ⊙ exp(A_q)) · S      (exponents ≤ 0)
        q_in = rf * jnp.exp(A_q)
        y = jnp.einsum("bhtk,bhkv->bhtv", q_in, S)
        # ---- intra-chunk: decay exp(A_q[t] − A[s]) for s<t (or ≤t), ≤ 0
        if scalar_decay:
            d1 = A_q[..., 0]                        # (B,H,C)
            d2 = A[..., 0]
            D = jnp.exp(jnp.where(mask[None, None],
                                  d1[:, :, :, None] - d2[:, :, None, :], -jnp.inf))
            qk = jnp.einsum("bhtk,bhsk->bhts", rf, kf)
            y = y + jnp.einsum("bhts,bhsv->bhtv", qk * D, vf)
        else:
            diff = A_q[:, :, :, None, :] - A[:, :, None, :, :]  # (B,H,C,C,K)
            D = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -jnp.inf))
            y = y + jnp.einsum("bhtk,bhtsk,bhsk,bhsv->bhtv", rf, D, kf, vf)
        if rwkv_mode:
            uu = u[None, :, None, :] if u.ndim == 2 else u
            bonus = jnp.einsum("bhtk,bhtk->bht", rf, uu * kf)
            y = y + bonus[..., None] * vf
        # ---- state update (exponents ≤ 0)
        k_carry = kf * jnp.exp(A_end - A)
        S_new = S * jnp.exp(A_end[:, :, 0, :])[..., None] \
            + jnp.einsum("bhsk,bhsv->bhkv", k_carry, vf)
        return S_new, y

    inputs = (jnp.moveaxis(rc, 2, 0), jnp.moveaxis(kc, 2, 0),
              jnp.moveaxis(vc, 2, 0), jnp.moveaxis(wc, 2, 0))
    state, ys = jax.lax.scan(chunk_step, state, inputs)
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, T_p, V)[:, :, :T]
    return y.astype(r.dtype), state


# --------------------------------------------------------------------------
# RWKV6 block
# --------------------------------------------------------------------------

def init_rwkv6(key, cfg: ArchConfig) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 12)
    lora = max(32, d // 16)
    return {
        "mix": (jax.random.uniform(ks[0], (5, d), jnp.float32) * 0.1 + 0.45
                ).astype(jnp.bfloat16),  # token-shift mixes for r,k,v,w,g
        "wr": dense_init(ks[1], (d, d)),
        "wk": dense_init(ks[2], (d, d)),
        "wv": dense_init(ks[3], (d, d)),
        "wg": dense_init(ks[4], (d, d)),
        "wo": dense_init(ks[5], (d, d)),
        "w0": (jax.random.normal(ks[6], (d,), jnp.float32) * 0.3 - 6.0),
        "w_lora_a": dense_init(ks[7], (d, lora)),
        "w_lora_b": dense_init(ks[8], (lora, d), scale=0.01),
        "u": (jax.random.normal(ks[9], (H, hd), jnp.float32) * 0.3),
        "ln_x": init_rms(ks[10], d),
        # channel mix
        "cm_mix": (jax.random.uniform(ks[11], (2, d), jnp.float32) * 0.1 + 0.45
                   ).astype(jnp.bfloat16),
        "cm_k": dense_init(ks[1], (d, cfg.d_ff)),
        "cm_v": dense_init(ks[2], (cfg.d_ff, d)),
        "cm_r": dense_init(ks[3], (d, d)),
    }


def _token_shift(x, last):
    """shifted(x)[t] = x[t-1]; position 0 takes `last` (decode state)."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def rwkv6_block(params: Params, x, cfg: ArchConfig,
                state: Optional[Dict] = None, pol=NULL_POLICY):
    """Time-mix with data-dependent decay + channel-mix.
    state: {"shift_t","shift_c": (B,d), "wkv": (B,H,hd,hd)}."""
    B, T, d = x.shape
    H = cfg.n_heads
    hd = d // H
    if state is None:
        state = {"shift_t": jnp.zeros((B, d), x.dtype),
                 "shift_c": jnp.zeros((B, d), x.dtype),
                 "wkv": jnp.zeros((B, H, hd, hd), jnp.float32)}
    prev = _token_shift(x, state["shift_t"])
    mix = params["mix"].astype(x.dtype)
    xr = x + (prev - x) * mix[0]
    xk = x + (prev - x) * mix[1]
    xv = x + (prev - x) * mix[2]
    xw = x + (prev - x) * mix[3]
    xg = x + (prev - x) * mix[4]
    r = (xr @ params["wr"]).reshape(B, T, H, hd)
    k = (xk @ params["wk"]).reshape(B, T, H, hd)
    v = (xv @ params["wv"]).reshape(B, T, H, hd)
    g = jax.nn.silu((xg @ params["wg"]).astype(jnp.float32))
    # data-dependent decay: w = exp(-exp(w0 + lora(xw)))  ∈ (0,1)
    dd = params["w0"] + (jnp.tanh(xw.astype(jnp.float32) @
                                  params["w_lora_a"].astype(jnp.float32))
                         @ params["w_lora_b"].astype(jnp.float32))
    w_log = -jnp.exp(jnp.clip(dd, -12.0, 2.0)).reshape(B, T, H, hd)

    rT = jnp.moveaxis(r, 2, 1)  # (B,H,T,hd)
    kT = jnp.moveaxis(k, 2, 1)
    vT = jnp.moveaxis(v, 2, 1)
    wT = jnp.moveaxis(w_log, 2, 1)
    y, wkv = decay_linear_attention(rT, kT, vT, wT, u=params["u"],
                                    state=state["wkv"], pol=pol)
    y = jnp.moveaxis(y, 1, 2).reshape(B, T, d)
    y = rms_norm(y, params["ln_x"], cfg.norm_eps) * g.astype(x.dtype)
    out_t = y @ params["wo"]

    # channel mix
    xc = x + out_t
    prev_c = _token_shift(xc, state["shift_c"])
    cmix = params["cm_mix"].astype(x.dtype)
    xk2 = xc + (prev_c - xc) * cmix[0]
    xr2 = xc + (prev_c - xc) * cmix[1]
    kk = jnp.square(jax.nn.relu((xk2 @ params["cm_k"]).astype(jnp.float32)))
    cm = (kk.astype(x.dtype) @ params["cm_v"])
    rr = jax.nn.sigmoid((xr2 @ params["cm_r"]).astype(jnp.float32)).astype(x.dtype)
    out = xc + rr * cm
    new_state = {"shift_t": x[:, -1, :], "shift_c": xc[:, -1, :], "wkv": wkv}
    return pol.cs(out, "act_btd"), new_state


# --------------------------------------------------------------------------
# Mamba2 block (SSD, scalar per-head decay)
# --------------------------------------------------------------------------

def init_mamba2(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    dn = cfg.ssm_state
    H = cfg.n_heads
    P = 2 * d // H                      # head dim of the expanded stream
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d + 2 * dn + H)),  # x(2d),z(2d),B,C,dt
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": init_rms(ks[1], 2 * d),
        "w_out": dense_init(ks[2], (2 * d, d)),
    }


def mamba2_block(params: Params, x, cfg: ArchConfig,
                 state: Optional[jnp.ndarray] = None, pol=NULL_POLICY):
    """SSD: y_t = Σ_{s≤t} exp(A·Σdt) (C_t·B_s) x_s + D x_t (per head)."""
    B, T, d = x.shape
    H = cfg.n_heads
    dn = cfg.ssm_state
    P = 2 * d // H
    zxbcdt = x @ params["w_in"]
    xs, z, Bm, Cm, dt = jnp.split(
        zxbcdt, [2 * d, 4 * d, 4 * d + dn, 4 * d + 2 * dn], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,T,H)
    a = -jnp.exp(params["A_log"])                                     # (H,)
    w_log = (dt * a)                                                  # (B,T,H) ≤ 0
    xh = xs.reshape(B, T, H, P)
    # r=C, k=B (shared across heads), v = dt-scaled x
    r = jnp.broadcast_to(Cm[:, :, None, :], (B, T, H, dn))
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, T, H, dn))
    v = xh * dt[..., None].astype(xh.dtype)
    rT = jnp.moveaxis(r, 2, 1).astype(x.dtype)
    kT = jnp.moveaxis(k, 2, 1).astype(x.dtype)
    vT = jnp.moveaxis(v, 2, 1)
    wT = jnp.broadcast_to(jnp.moveaxis(w_log, 2, 1)[..., None], (B, H, T, dn))
    y, new_state = decay_linear_attention(rT, kT, vT, wT, u=None,
                                          state=state, scalar_decay=True,
                                          pol=pol)
    y = jnp.moveaxis(y, 1, 2).reshape(B, T, 2 * d)
    y = y + (xh * params["D"].astype(xh.dtype)[None, None, :, None]
             ).reshape(B, T, 2 * d)
    y = rms_norm(y, params["norm"], cfg.norm_eps) * jax.nn.silu(
        z.astype(jnp.float32)).astype(x.dtype)
    out = y @ params["w_out"]
    return pol.cs(out, "act_btd"), new_state


# --------------------------------------------------------------------------
# Embedding
# --------------------------------------------------------------------------

def init_embed(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"tok": dense_init(k1, (cfg.vocab_size, cfg.d_model), scale=0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), scale=0.02)
    return p


def embed(params: Params, tokens, pol=NULL_POLICY):
    y = jnp.take(params["tok"], tokens, axis=0)
    return pol.cs(y, "act_btd")


def unembed(params: Params, x, cfg: ArchConfig, pol=NULL_POLICY):
    w = params["tok"].T if cfg.tie_embeddings else params["unembed"]
    logits = x @ w
    return pol.cs(logits, "logits")
