"""Architecture configuration schema covering all 10 assigned architectures.

One dataclass describes dense GQA/MLA/SWA transformers, RWKV6, Mamba2
hybrids, MoE (top-1 and top-k), enc-dec, and modality-frontend stubs.
``scaled()`` produces the reduced smoke-test configs; full configs live in
``repro.configs`` and are exercised only through the dry-run
(ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ArchConfig", "register_arch", "get_arch", "list_archs"]

_REGISTRY = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | ssm | hybrid | vlm | audio | moe
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention flavor
    attn_kind: str = "gqa"         # gqa | mla | none
    window: Optional[int] = None   # sliding-window size (SWA)
    chunk_size: Optional[int] = None  # chunked local attention (llama4-style)
    rope_kind: str = "rope"        # rope | mrope | none

    # MLA (MiniCPM3 / Kimi-K2 style latent attention)
    q_lora_rank: Optional[int] = None
    kv_lora_rank: Optional[int] = None
    qk_rope_dim: int = 64
    qk_nope_dim: int = 64
    v_head_dim: Optional[int] = None

    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 1
    moe_d_ff: Optional[int] = None
    n_shared_experts: int = 0
    n_dense_layers: int = 0        # leading dense layers before MoE stack
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_kind: Optional[str] = None  # rwkv6 | mamba2
    ssm_state: int = 64
    hybrid_every: int = 0           # shared attn block every N ssm layers
    shared_attn: bool = False       # zamba2: ONE attn block's params shared

    # encoder-decoder
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # modality frontend stub: inputs arrive as precomputed embeddings
    frontend: Optional[str] = None  # vision | audio | None

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"
    dtype: str = "bfloat16"
    max_seq_len: int = 8192

    # ------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vhd(self) -> int:
        return self.v_head_dim if self.v_head_dim is not None else self.hd

    @property
    def subquadratic(self) -> bool:
        """Can this arch run 500k-token decode without a full-attention KV?"""
        if self.ssm_kind is not None and self.hybrid_every == 0 and not self.shared_attn:
            return True
        if self.ssm_kind is not None:  # hybrid: few attn layers, linear state
            return True
        if self.window is not None or self.chunk_size is not None:
            return True
        return False

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs can decode (seamless has a decoder)

    def n_params(self) -> float:
        """Approximate parameter count (for 6·N·D roofline math)."""
        d, L = self.d_model, self.n_layers
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.attn_kind == "mla":
            q = d * (self.q_lora_rank or d) + \
                (self.q_lora_rank or d) * self.n_heads * (self.qk_rope_dim + self.qk_nope_dim)
            kv = d * (self.kv_lora_rank or d) + \
                (self.kv_lora_rank or d) * self.n_heads * (self.qk_nope_dim + self.vhd)
            o = self.n_heads * self.vhd * d
            attn = q + kv + o
        elif self.attn_kind == "none":
            attn = 0.0
        else:
            attn = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd \
                + self.n_heads * self.hd * d
        mlp_dense = 3 * d * self.d_ff
        if self.ssm_kind == "rwkv6":
            per_layer = 4 * d * d + 2 * d * self.d_ff + 2.5 * d * d
            return embed + L * per_layer
        if self.ssm_kind == "mamba2":
            # w_in: x(2d)+z(2d)+B,C,dt; w_out: 2d→d — no per-layer MLP (zamba2)
            ssm = d * (4 * d + 2 * self.ssm_state + self.n_heads) + 2 * d * d
            total = embed + L * ssm
            if self.shared_attn:
                total += attn + mlp_dense  # the ONE shared block
            return total
        if self.moe:
            mff = self.moe_d_ff or self.d_ff
            moe_mlp = 3 * d * mff * self.n_experts \
                + 3 * d * mff * self.n_shared_experts
            n_moe = L - self.n_dense_layers
            return embed + L * attn + self.n_dense_layers * mlp_dense + n_moe * moe_mlp
        if self.enc_dec:
            Lt = self.n_enc_layers + self.n_dec_layers
            cross = self.n_dec_layers * attn
            return embed + Lt * (attn + mlp_dense) + cross
        return embed + L * (attn + mlp_dense)

    def n_active_params(self) -> float:
        """Active parameters per token (MoE: routed top-k + shared only)."""
        if not self.moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        mff = self.moe_d_ff or self.d_ff
        full = self.n_params()
        all_experts = (L - self.n_dense_layers) * 3 * d * mff * self.n_experts
        active = (L - self.n_dense_layers) * 3 * d * mff * self.top_k
        return full - all_experts + active

    # ------------------------------------------------------------- scaling
    def scaled(self, n_layers: int = 2, d_model: int = 64, n_heads: int = 4,
               n_kv_heads: Optional[int] = None, d_ff: int = 128,
               vocab: int = 256, n_experts: Optional[int] = None) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        kv = n_kv_heads if n_kv_heads is not None else max(1, n_heads // 2)
        if self.attn_kind != "gqa":
            kv = n_heads if self.n_kv_heads == self.n_heads else kv
        updates = dict(
            n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=min(kv, n_heads), d_ff=d_ff, vocab_size=vocab,
            head_dim=d_model // n_heads, max_seq_len=256,
        )
        if self.attn_kind == "mla":
            updates.update(q_lora_rank=32, kv_lora_rank=32, qk_rope_dim=8,
                           qk_nope_dim=8, v_head_dim=d_model // n_heads)
        if self.moe:
            ne = n_experts if n_experts is not None else min(self.n_experts, 8)
            updates.update(n_experts=ne, top_k=min(self.top_k, ne),
                           moe_d_ff=d_ff, n_dense_layers=min(self.n_dense_layers, 1))
        if self.window is not None:
            updates.update(window=32)
        if self.chunk_size is not None:
            updates.update(chunk_size=32)
        if self.enc_dec:
            updates.update(n_enc_layers=n_layers, n_dec_layers=n_layers)
        if self.ssm_kind is not None:
            updates.update(ssm_state=16)
        if self.hybrid_every:
            updates.update(hybrid_every=max(1, n_layers // 2))
        return dataclasses.replace(self, **updates)


def register_arch(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        import importlib
        importlib.import_module("repro.configs")  # registers all assigned archs
    return _REGISTRY[name]


def list_archs():
    import importlib
    importlib.import_module("repro.configs")  # registers all assigned archs
    return sorted(_REGISTRY)
