"""Composable model assembly: init, forward, prefill/decode, loss.

One ``forward`` covers all 10 assigned architectures, dispatching per
family; layer stacks run under ``jax.lax.scan`` over stacked parameters so
HLO size is O(1) in depth (critical at 61–80 layers × 512 devices).

Caches (decode):
  gqa      {"k","v"}           (L, B, S_max, KV, hd)
  mla      {"lat","rope"}      (L, B, S_max, kvr | rdim)     ← latent only
  rwkv6    {"shift_t","shift_c","wkv"}  (L,B,d) / (L,B,H,hd,hd)
  mamba2   {"ssm"}             (L, B, H, dn, P)
  zamba2   mamba states + per-application-site KV for the ONE shared block
  enc-dec  decoder self KV + precomputed cross KV from the encoder

Modality frontends (vlm/audio) are stubs per the assignment: inputs arrive
as precomputed patch/frame embeddings of shape (B, T, d_model).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .arch import ArchConfig
from .layers import (NULL_POLICY, attention_gqa, attention_mla, embed,
                     init_attention, init_embed, init_mlp, init_moe,
                     init_mamba2, init_rwkv6, mamba2_block, mlp, moe, rms_norm,
                     rwkv6_block, unembed, init_rms)

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# Initialization
# --------------------------------------------------------------------------

def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def _init_block(key, cfg: ArchConfig, kind: str) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"ln1": init_rms(k3, cfg.d_model)}
    if kind == "dense":
        p["attn"] = init_attention(k1, cfg)
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff)
        p["ln2"] = init_rms(k4, cfg.d_model)
    elif kind == "moe":
        p["attn"] = init_attention(k1, cfg)
        p["moe"] = init_moe(k2, cfg)
        p["ln2"] = init_rms(k4, cfg.d_model)
    elif kind == "rwkv6":
        p = {"rwkv": init_rwkv6(k1, cfg)}
    elif kind == "mamba2":
        p["mamba"] = init_mamba2(k1, cfg)
    elif kind == "cross":  # decoder block: self-attn + cross-attn + mlp
        p["attn"] = init_attention(k1, cfg)
        p["cross"] = init_attention(k2, cfg)
        p["ln_cross"] = init_rms(k4, cfg.d_model)
        p["mlp"] = init_mlp(jax.random.fold_in(k2, 7), cfg.d_model, cfg.d_ff)
        p["ln2"] = init_rms(jax.random.fold_in(k4, 7), cfg.d_model)
    else:
        raise ValueError(kind)
    return p


def init_params(key, cfg: ArchConfig) -> Params:
    """Concrete initialization (smoke tests / examples; full configs are only
    ever lowered abstractly via param_specs)."""
    ks = jax.random.split(key, 8)
    p: Params = {"embed": init_embed(ks[0], cfg),
                 "ln_f": init_rms(ks[1], cfg.d_model)}
    if cfg.enc_dec:
        p["enc"] = _stack([_init_block(jax.random.fold_in(ks[2], i), cfg, "dense")
                           for i in range(cfg.n_enc_layers)])
        p["dec"] = _stack([_init_block(jax.random.fold_in(ks[3], i), cfg, "cross")
                           for i in range(cfg.n_dec_layers)])
        p["ln_enc"] = init_rms(ks[4], cfg.d_model)
        return p
    if cfg.ssm_kind == "rwkv6":
        p["layers"] = _stack([_init_block(jax.random.fold_in(ks[2], i), cfg, "rwkv6")
                              for i in range(cfg.n_layers)])
        return p
    if cfg.ssm_kind == "mamba2":
        p["layers"] = _stack([_init_block(jax.random.fold_in(ks[2], i), cfg, "mamba2")
                              for i in range(cfg.n_layers)])
        if cfg.shared_attn:
            p["shared_attn"] = _init_block(ks[5], cfg, "dense")
        return p
    if cfg.moe:
        if cfg.n_dense_layers:
            p["dense_layers"] = _stack(
                [_init_block(jax.random.fold_in(ks[2], i), cfg, "dense")
                 for i in range(cfg.n_dense_layers)])
        p["layers"] = _stack(
            [_init_block(jax.random.fold_in(ks[3], i), cfg, "moe")
             for i in range(cfg.n_layers - cfg.n_dense_layers)])
        return p
    p["layers"] = _stack([_init_block(jax.random.fold_in(ks[2], i), cfg, "dense")
                          for i in range(cfg.n_layers)])
    return p


# --------------------------------------------------------------------------
# Caches
# --------------------------------------------------------------------------

def make_caches(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16,
                abstract: bool = False):
    """Concrete zeros (or ShapeDtypeStructs for the dry-run)."""
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
         (lambda s, d: jnp.zeros(s, d))
    B = batch
    if cfg.enc_dec:
        L = cfg.n_dec_layers
        KV, hd = cfg.n_kv_heads, cfg.hd
        return {"k": mk((L, B, s_max, KV, hd), dtype),
                "v": mk((L, B, s_max, KV, hd), dtype),
                "xk": mk((L, B, s_max, KV, hd), dtype),   # cross K (enc len)
                "xv": mk((L, B, s_max, KV, hd), dtype)}
    if cfg.ssm_kind == "rwkv6":
        L, d, H = cfg.n_layers, cfg.d_model, cfg.n_heads
        hd = d // H
        return {"shift_t": mk((L, B, d), dtype), "shift_c": mk((L, B, d), dtype),
                "wkv": mk((L, B, H, hd, hd), jnp.float32)}
    if cfg.ssm_kind == "mamba2":
        L, H, dn = cfg.n_layers, cfg.n_heads, cfg.ssm_state
        P = 2 * cfg.d_model // H
        c = {"ssm": mk((L, B, H, dn, P), jnp.float32)}
        if cfg.shared_attn:
            n_sites = max(1, cfg.n_layers // max(1, cfg.hybrid_every))
            c["k"] = mk((n_sites, B, s_max, cfg.n_kv_heads, cfg.hd), dtype)
            c["v"] = mk((n_sites, B, s_max, cfg.n_kv_heads, cfg.hd), dtype)
        return c
    if cfg.attn_kind == "mla":
        L = cfg.n_layers
        return {"lat": mk((L, B, s_max, cfg.kv_lora_rank), dtype),
                "rope": mk((L, B, s_max, cfg.qk_rope_dim), dtype)}
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    s_eff = min(s_max, cfg.window) if cfg.window else s_max
    s_eff = s_max  # keep absolute positions; window masks reads
    return {"k": mk((L, B, s_eff, KV, hd), dtype),
            "v": mk((L, B, s_eff, KV, hd), dtype)}


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

def _dense_block(bp, h, cfg, positions, cache, idx, pol):
    attn_fn = attention_mla if cfg.attn_kind == "mla" else attention_gqa
    a, new_cache = attn_fn(bp["attn"], rms_norm(h, bp["ln1"], cfg.norm_eps),
                           cfg, positions, cache, idx, pol)
    h = h + a
    h = h + mlp(bp["mlp"], rms_norm(h, bp["ln2"], cfg.norm_eps), cfg.act, pol)
    return h, new_cache


def _moe_block(bp, h, cfg, positions, cache, idx, pol):
    attn_fn = attention_mla if cfg.attn_kind == "mla" else attention_gqa
    a, new_cache = attn_fn(bp["attn"], rms_norm(h, bp["ln1"], cfg.norm_eps),
                           cfg, positions, cache, idx, pol)
    h = h + a
    y, aux = moe(bp["moe"], rms_norm(h, bp["ln2"], cfg.norm_eps), cfg, pol)
    return h + y, new_cache, aux


def _scan_blocks(stack_params, h, cfg, positions, caches, idx, pol, kind):
    """lax.scan over stacked layer params (+ per-layer caches)."""
    use_cache = caches is not None

    def body(carry, xs):
        h = carry
        if use_cache:
            bp, cache_l = xs
        else:
            bp, cache_l = xs, None
        if kind == "dense":
            h, nc = _dense_block(bp, h, cfg, positions, cache_l, idx, pol)
            aux = jnp.zeros((), jnp.float32)
        elif kind == "moe":
            h, nc, aux = _moe_block(bp, h, cfg, positions, cache_l, idx, pol)
        elif kind == "rwkv6":
            h, nc = rwkv6_block(bp["rwkv"], h, cfg, cache_l, pol)
            aux = jnp.zeros((), jnp.float32)
        elif kind == "mamba2":
            hn = rms_norm(h, bp["ln1"], cfg.norm_eps)
            y, nst = mamba2_block(bp["mamba"], hn, cfg,
                                  cache_l["ssm"] if cache_l else None, pol)
            h = h + y
            nc = {"ssm": nst} if use_cache else None
            aux = jnp.zeros((), jnp.float32)
        else:
            raise ValueError(kind)
        if not use_cache:
            nc = jnp.zeros((), jnp.float32)  # dummy scan output
        return h, (nc, aux)

    body_fn = body
    if getattr(pol, "remat", "none") != "none":
        policy = {"full": None,
                  "dots": jax.checkpoint_policies.checkpoint_dots,
                  "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
                  }.get(pol.remat, None)
        body_fn = jax.checkpoint(body, policy=policy) if policy is None \
            else jax.checkpoint(body, policy=policy)

    xs = (stack_params, caches) if use_cache else stack_params
    h, (new_caches, auxs) = _maybe_scan(body_fn, h, xs, pol)
    return h, (new_caches if use_cache else None), jnp.sum(auxs)


def _maybe_scan(body_fn, carry, xs, pol):
    """lax.scan, or an unrolled python loop when pol.unroll_layers is set.

    Unrolling is used by the dry-run so compiled.cost_analysis() counts
    every layer's FLOPs/bytes/collectives (XLA tallies while-loop bodies
    exactly once); real training uses the scan for O(1)-in-depth HLO."""
    if not getattr(pol, "unroll_layers", False):
        return jax.lax.scan(body_fn, carry, xs)
    L = jax.tree_util.tree_leaves(xs)[0].shape[0]
    outs = []
    for i in range(L):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, out = body_fn(carry, x_i)
        outs.append(out)
    stacked = jax.tree_util.tree_map(lambda *ys: jnp.stack(ys, axis=0), *outs)
    return carry, stacked


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def forward(params: Params, cfg: ArchConfig, inputs, positions,
            caches=None, cache_index=None, pol=NULL_POLICY,
            enc_inputs=None):
    """Returns (logits, new_caches, aux_loss).

    inputs: int tokens (B,T) or precomputed embeddings (B,T,d) for
    vlm/audio frontends. enc_inputs: encoder-side embeddings for enc-dec.
    """
    if inputs.dtype in (jnp.int32, jnp.int64):
        h = embed(params["embed"], inputs, pol)
    else:
        h = pol.cs(inputs.astype(jnp.bfloat16), "act_btd")
    aux_total = jnp.zeros((), jnp.float32)
    idx = cache_index if cache_index is not None else 0

    if cfg.enc_dec:
        return _forward_encdec(params, cfg, h, positions, caches, idx, pol,
                               enc_inputs)

    if cfg.ssm_kind == "mamba2" and cfg.shared_attn:
        return _forward_zamba(params, cfg, h, positions, caches, idx, pol)

    if cfg.ssm_kind in ("rwkv6", "mamba2"):
        kind = cfg.ssm_kind
        h, new_caches, aux = _scan_blocks(params["layers"], h, cfg, positions,
                                          caches, idx, pol, kind)
        aux_total += aux
    elif cfg.moe:
        new_caches = {}
        dense_caches = moe_caches = None
        if caches is not None:
            nd = cfg.n_dense_layers
            dense_caches = jax.tree_util.tree_map(lambda c: c[:nd], caches)
            moe_caches = jax.tree_util.tree_map(lambda c: c[nd:], caches)
        if cfg.n_dense_layers:
            h, ncd, _ = _scan_blocks(params["dense_layers"], h, cfg, positions,
                                     dense_caches, idx, pol, "dense")
        else:
            ncd = None
        h, ncm, aux = _scan_blocks(params["layers"], h, cfg, positions,
                                   moe_caches, idx, pol, "moe")
        aux_total += aux
        if caches is not None:
            if ncd is not None:
                new_caches = jax.tree_util.tree_map(
                    lambda a, b: jnp.concatenate([a, b], axis=0), ncd, ncm)
            else:
                new_caches = ncm
        else:
            new_caches = None
    else:
        h, new_caches, _ = _scan_blocks(params["layers"], h, cfg, positions,
                                        caches, idx, pol, "dense")

    h = rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg, pol)
    return logits, new_caches, aux_total


def _forward_zamba(params, cfg, h, positions, caches, idx, pol):
    """Zamba2: mamba2 stack with ONE shared attention block applied every
    `hybrid_every` layers. Each application site has its own KV cache but
    the SAME parameters (the paper's parameter-sharing trick)."""
    every = max(1, cfg.hybrid_every)
    n_sites = max(1, cfg.n_layers // every)
    mstack = params["layers"]
    new_ssm = []
    new_k, new_v = [], []
    for g in range(n_sites):
        lo, hi = g * every, min((g + 1) * every, cfg.n_layers)
        seg = jax.tree_util.tree_map(lambda a: a[lo:hi], mstack)
        seg_cache = None
        if caches is not None:
            seg_cache = {"ssm": caches["ssm"][lo:hi]}
        h, nc, _ = _scan_blocks(seg, h, cfg, positions, seg_cache, idx, pol,
                                "mamba2")
        if caches is not None:
            new_ssm.append(nc["ssm"])
        sp = params["shared_attn"]
        site_cache = None
        if caches is not None and "k" in caches:
            site_cache = {"k": caches["k"][g], "v": caches["v"][g]}
        h, site_nc = _dense_block(sp, h, cfg, positions, site_cache, idx, pol)
        if site_nc is not None:
            new_k.append(site_nc["k"])
            new_v.append(site_nc["v"])
    tail = n_sites * every
    if tail < cfg.n_layers:
        seg = jax.tree_util.tree_map(lambda a: a[tail:], mstack)
        seg_cache = {"ssm": caches["ssm"][tail:]} if caches is not None else None
        h, nc, _ = _scan_blocks(seg, h, cfg, positions, seg_cache, idx, pol,
                                "mamba2")
        if caches is not None:
            new_ssm.append(nc["ssm"])
    new_caches = None
    if caches is not None:
        new_caches = {"ssm": jnp.concatenate(new_ssm, axis=0)}
        if new_k:
            new_caches["k"] = jnp.stack(new_k, axis=0)
            new_caches["v"] = jnp.stack(new_v, axis=0)
    h = rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg, pol)
    return logits, new_caches, jnp.zeros((), jnp.float32)


def _forward_encdec(params, cfg, h_dec, positions, caches, idx, pol, enc_inputs):
    """Encoder-decoder (seamless): bidirectional encoder, causal decoder with
    cross attention. For decode steps, enc_inputs is None and the cross KV
    comes from the cache (computed at prefill)."""
    dcfg = cfg
    enc_out = None
    if enc_inputs is not None:
        he = pol.cs(enc_inputs.astype(jnp.bfloat16), "act_btd")
        enc_pos = jnp.broadcast_to(jnp.arange(he.shape[1])[None], he.shape[:2])
        ecfg = dataclasses.replace(cfg, window=None, chunk_size=None)

        def enc_body(carry, bp):
            hh = carry
            x = rms_norm(hh, bp["ln1"], cfg.norm_eps)
            B, T, d = x.shape
            H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
            q = (x @ bp["attn"]["wq"]).reshape(B, T, H, hd)
            k = (x @ bp["attn"]["wk"]).reshape(B, T, KV, hd)
            v = (x @ bp["attn"]["wv"]).reshape(B, T, KV, hd)
            from .layers import apply_rope, sdpa
            q = apply_rope(q, enc_pos)
            k = apply_rope(k, enc_pos)
            out = sdpa(q, k, v, mask=None, pol=pol)   # bidirectional
            hh = hh + out.reshape(B, T, H * hd) @ bp["attn"]["wo"]
            hh = hh + mlp(bp["mlp"], rms_norm(hh, bp["ln2"], cfg.norm_eps),
                          cfg.act, pol)
            return hh, jnp.zeros((), jnp.float32)

        he, _ = _maybe_scan(enc_body, he, params["enc"], pol)
        enc_out = rms_norm(he, params["ln_enc"], cfg.norm_eps)

    # decoder
    use_cache = caches is not None

    def dec_body(carry, xs):
        hh = carry
        bp, cache_l = xs if use_cache else (xs, None)
        self_cache = {"k": cache_l["k"], "v": cache_l["v"]} if use_cache else None
        a, nc_self = attention_gqa(bp["attn"],
                                   rms_norm(hh, bp["ln1"], cfg.norm_eps),
                                   dcfg, positions, self_cache, idx, pol)
        hh = hh + a
        # cross attention
        from .layers import sdpa
        x = rms_norm(hh, bp["ln_cross"], cfg.norm_eps)
        B, T, d = x.shape
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q = (x @ bp["cross"]["wq"]).reshape(B, T, H, hd)
        if enc_out is not None:
            xk = (enc_out @ bp["cross"]["wk"]).reshape(B, enc_out.shape[1], KV, hd)
            xv = (enc_out @ bp["cross"]["wv"]).reshape(B, enc_out.shape[1], KV, hd)
            if use_cache:
                S = cache_l["xk"].shape[1]
                xk_c = jax.lax.dynamic_update_slice(
                    cache_l["xk"], xk.astype(cache_l["xk"].dtype), (0, 0, 0, 0))
                xv_c = jax.lax.dynamic_update_slice(
                    cache_l["xv"], xv.astype(cache_l["xv"].dtype), (0, 0, 0, 0))
            else:
                xk_c, xv_c = xk, xv
        else:
            xk_c, xv_c = cache_l["xk"], cache_l["xv"]
        out = sdpa(q, xk_c, xv_c, mask=None, pol=pol)
        hh = hh + out.reshape(B, T, H * hd) @ bp["cross"]["wo"]
        hh = hh + mlp(bp["mlp"], rms_norm(hh, bp["ln2"], cfg.norm_eps),
                      cfg.act, pol)
        if use_cache:
            return hh, ({"k": nc_self["k"], "v": nc_self["v"],
                         "xk": xk_c, "xv": xv_c}, jnp.zeros((), jnp.float32))
        return hh, (jnp.zeros(()), jnp.zeros((), jnp.float32))

    xs = (params["dec"], caches) if use_cache else params["dec"]
    h_dec, (ncs, _) = _maybe_scan(dec_body, h_dec, xs, pol)
    h_dec = rms_norm(h_dec, params["ln_f"], cfg.norm_eps)
    logits = unembed(params["embed"], h_dec, cfg, pol)
    return logits, (ncs if use_cache else None), jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------

def lm_loss(logits, labels, pol=NULL_POLICY):
    """Next-token cross entropy in fp32; labels -100 are masked."""
    V = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, jnp.clip(labels, 0)[..., None],
                               axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, cfg: ArchConfig, batch, pol=NULL_POLICY,
            aux_weight: float = 0.01):
    inputs = batch.get("embeds", batch.get("tokens"))
    positions = batch.get("positions")
    if positions is None:
        B, T = inputs.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    enc_inputs = batch.get("enc_embeds")
    logits, _, aux = forward(params, cfg, inputs, positions, pol=pol,
                             enc_inputs=enc_inputs)
    return lm_loss(logits, batch["labels"], pol) + aux_weight * aux
