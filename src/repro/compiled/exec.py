"""Compiled-tier execution: kernel-backed hooks + the splicing interpreter.

A :class:`~repro.compiled.lower.CompiledLoop` executes through
:func:`repro.core.vectorize.exec_loop_plan` — the same statement walk the
fast interpreter uses, which owns ALL simulated-time charging — but with
:class:`~repro.core.vectorize.LoopHooks` that move the data differently:

  * **navigation / cache-lookup probes** run against an epoch-cached
    :class:`_ProbeIndex` (host key columns, argsort order, materialized
    column arrays, and — under Pallas — a direct-address table), probed by
    ``kernels.join_probe`` / ``kernels.ops`` on the ``"kernels"`` backend
    or ``kernels.ref.join_probe_np`` on the ``"numpy"`` backend. The index
    is keyed by the SAME (stats version, data version, instance) epoch the
    serving :class:`~repro.runtime.sitecache.SiteCache` uses, so an
    ``analyze()`` or a write landing mid-stream rebuilds it instead of
    serving stale gathers — compiled results stay bit-identical to
    interpreted ones under concurrent stats/data movement;
  * **accumulator folds** go through ``segment_reduce`` only for the
    accumulators lowering proved fold-safe AND whose runtime values pass
    the exactness gate (integer deltas within fp32's exact range);
    everything else takes the default float64 sequential-equivalent path.

The :class:`SplicingInterpreter` is the tiered fallback: a plain
:class:`~repro.core.regions.Interpreter` that, on reaching a loop bound by
the lowering, executes the compiled segment and, everywhere else (``while``
guards, early-exit loops, update-carrying bodies, non-table or empty
sources at run time), defers to the exact row-at-a-time semantics.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional

import numpy as np

from ..core.regions import Interpreter, IVar, LoopRegion
from ..core.vectorize import (LoopHooks, _broadcast, _eval_vec,
                              _vec_accumulate, exec_loop_plan)
from ..kernels import ref as kref
from ..relational.table import Table

__all__ = ["SplicingInterpreter", "make_hooks"]

# fp32 holds integers exactly up to 2**24: the kernel fold (which
# accumulates in float32 on the MXU path) is only taken below this bound
_EXACT_FP32 = float(1 << 24)

# bounded memos: a serving process sees unbounded distinct query-result
# tables; the hooks only ever pin this many
_ROW_SOURCE_CAP = 32
_PROBE_INDEX_CAP = 64


def _pallas_probe_ok() -> bool:
    from ..kernels import ops
    return ops.pallas_state()[0]


class _RowSourceCache:
    """Memoized columnar materialization of loop-source tables.

    Keyed by object identity WITH a strong reference to the keyed table
    (``id`` alone could be recycled). In the serving path the site cache
    returns the same Table object for an unchanged site, so repeated
    batches hit this memo instead of re-converting columns."""

    def __init__(self, cap: int = _ROW_SOURCE_CAP):
        self.cap = cap
        self._memo: "OrderedDict[int, tuple]" = OrderedDict()

    def __call__(self, src: Table) -> Dict[str, np.ndarray]:
        k = id(src)
        hit = self._memo.get(k)
        if hit is not None and hit[0] is src:
            self._memo.move_to_end(k)
            return hit[1]
        cols = {c: np.asarray(src.column(c)) for c in src.schema.names}
        self._memo[k] = (src, cols)
        while len(self._memo) > self.cap:
            self._memo.popitem(last=False)
        return cols


class _ProbeIndex:
    """Per-(table, key column) probe state, rebuilt when the epoch moves."""

    __slots__ = ("epoch", "table", "tkeys", "order", "sorted_keys", "cols",
                 "direct")

    def __init__(self, epoch, t: Table, key_col: str):
        self.epoch = epoch
        self.table = t
        self.tkeys = np.asarray(t.column(key_col))
        self.order = np.argsort(self.tkeys, kind="stable")
        self.sorted_keys = self.tkeys[self.order]
        self.cols = {c: np.asarray(t.column(c)) for c in t.schema.names}
        self.direct = None   # lazily-built Pallas direct-address table

    def key_space(self) -> Optional[int]:
        if self.tkeys.size == 0 \
                or not np.issubdtype(self.tkeys.dtype, np.integer):
            return None
        lo, hi = int(self.tkeys.min()), int(self.tkeys.max())
        if lo < 0 or hi + 1 > (1 << 22):
            return None
        return hi + 1


class _ProbeIndexCache:
    def __init__(self, owner, cap: int = _PROBE_INDEX_CAP):
        self.owner = owner          # CompiledLoop (telemetry)
        self._memo: "OrderedDict[tuple, _ProbeIndex]" = OrderedDict()
        self.cap = cap

    def get(self, env, table_name: str, key_col: str) -> _ProbeIndex:
        epoch = (env.db.instance_token,) + tuple(
            env.db.site_epoch((table_name,)))
        k = (table_name, key_col)
        idx = self._memo.get(k)
        if idx is not None and idx.epoch == epoch:
            self._memo.move_to_end(k)
            return idx
        idx = _ProbeIndex(epoch, env.db.table(table_name), key_col)
        self._memo[k] = idx
        self.owner.index_rebuilds += 1
        while len(self._memo) > self.cap:
            self._memo.popitem(last=False)
        return idx


def _probe(cl, idx: _ProbeIndex, keys: np.ndarray) -> np.ndarray:
    """Row index in ``idx.table`` for each key, -1 on miss.

    ``"kernels"`` backend with Pallas dispatch on and an addressable key
    space: the ``join_probe`` kernel against an epoch-cached direct-address
    table (built once per epoch, not per call like ``ops.equi_probe``).
    Everywhere else: searchsorted against the index's cached stable sort —
    value-identical to ``kernels.ref.join_probe_np`` on the same inputs,
    without re-sorting the build side on every probe."""
    if cl.backend == "kernels" and _pallas_probe_ok():
        ks = idx.key_space()
        if ks is not None:
            from ..kernels import ops
            from ..kernels.join_probe import build_direct_table, join_probe
            import jax.numpy as jnp
            if idx.direct is None:
                idx.direct = build_direct_table(
                    jnp.asarray(idx.tkeys, jnp.int32), ks)
            cl.kernel_probes += 1
            return np.asarray(join_probe(jnp.asarray(keys, jnp.int32),
                                         idx.direct,
                                         interpret=ops.pallas_state()[1]))
    n = keys.shape[0]
    if n == 0:
        return np.zeros((0,), np.int32)
    if idx.tkeys.shape[0] == 0:
        return np.full((n,), -1, np.int32)
    pos = np.clip(np.searchsorted(idx.sorted_keys, keys), 0,
                  len(idx.order) - 1)
    gidx = idx.order[pos]
    found = idx.tkeys[gidx] == keys
    return np.where(found, gidx, -1).astype(np.int32)


def make_hooks(cl) -> LoopHooks:
    """Bind kernel-backed hooks for one :class:`CompiledLoop`.

    Every hook is observationally identical to the vectorize defaults —
    same values, same ORM-cache mutations, same failure behavior — only
    the gather/fold machinery differs (epoch-cached indices + kernels)."""
    probe_cache = _ProbeIndexCache(cl)
    row_source = _RowSourceCache()

    # ------------------------------------------------------------------ nav
    def nav(env, ce, target, e, n):
        base = ce.rows[e.base.name]
        keys = np.asarray(base[e.fk_field])
        idx = probe_cache.get(env, e.target, e.target_key)
        gidx = _probe(cl, idx, keys)
        if (gidx < 0).any():
            raise KeyError(f"navigation {e!r}: missing keys (FK violation)")
        ce.rows[target] = {c: idx.cols[c][gidx] for c in idx.table.schema.names}
        # ORM cache accounting — identical to core.vectorize._vec_nav:
        # first occurrence of an uncached key = point query, every other
        # occurrence = cache hit (1 statement)
        t = idx.table
        uniq = np.unique(keys)
        new_keys = [k for k in uniq.tolist()
                    if (e.target, k) not in env._orm_cache]
        n_misses = len(new_keys)
        env.charge_statement(n - n_misses)
        m = env.db.model
        bulk = getattr(env, "bulk_nav_charge", None)
        if bulk is not None and n_misses:
            bulk(t, n_misses)
        else:
            for _ in range(n_misses):
                env._charge_query(
                    1, t.row_bytes,
                    m.startup_s + m.index_lookup_s,
                    m.startup_s + m.index_lookup_s + 1 / m.emit_rows_per_s)
        if env.orm_cache_enabled and n_misses:
            pos = np.searchsorted(idx.sorted_keys, np.asarray(new_keys))
            rows_idx = idx.order[pos]
            for k, i in zip(new_keys, rows_idx.tolist()):
                env._orm_cache[(e.target, k)] = t.row(int(i))

    # --------------------------------------------------------- cache_lookup
    def cache_lookup(env, ce, target, e, n):
        entry = env._prefetch_cache.get((e.table, e.col))
        if entry is None:
            raise KeyError(f"no prefetch cache for ({e.table}, {e.col})")
        keys = _broadcast(_eval_vec(e.keyexpr, ce), n)
        ckeys, corder = entry["keys"], entry["order"]
        if cl.backend == "kernels":
            from ..kernels import ops
            import jax.numpy as jnp
            pos = np.asarray(ops.equi_probe(jnp.asarray(keys),
                                            jnp.asarray(ckeys)))
            cl.kernel_probes += 1
        else:
            pos = kref.join_probe_np(keys, ckeys)
        if (pos < 0).any():
            raise KeyError(f"cache lookup {e!r}: missing keys")
        gidx = corder[pos]
        t = entry["table"]
        cols = row_source(t)
        ce.rows[target] = {c: cols[c][gidx] for c in t.schema.names}

    # ----------------------------------------------------------- accumulate
    def accumulate(ce, stmt, e, mask, state):
        acc = stmt.target
        # a kernel-foldable acc has exactly one defining update and is never
        # read elsewhere in the body (lowering proved this), so it can have
        # no running column yet; its initial value lives in `state`
        if acc in cl.kernel_fold_accs and e.op == "+" and acc not in ce.cols:
            l_is_acc = isinstance(e.left, IVar) and e.left.name == acc
            other = e.right if l_is_acc else e.left
            delta = _broadcast(_eval_vec(other, ce), ce.n).astype(np.float64)
            if mask is not None:
                delta = np.where(mask, delta, 0.0)
            # exactness gate: the kernel accumulates in fp32, so it is only
            # taken for integer deltas whose running total stays within
            # fp32's exact integer range; then `a0 + total` is the same
            # single float64 add the cumsum path performs on its last
            # element — bit-identical. Anything else takes the default
            # sequential-equivalent float64 path.
            if np.all(delta == np.floor(delta)) \
                    and float(np.abs(delta).sum()) < _EXACT_FP32:
                total = _fold_sum(cl, delta)
                if total is not None:
                    # the interpreted tier exports col[-1].item() — a float
                    state[acc] = float(state.get(acc, 0.0)) + total
                    cl.kernel_folds += 1
                    return
        _vec_accumulate(ce, stmt, e, mask, state)

    return LoopHooks(nav=nav, cache_lookup=cache_lookup,
                     accumulate=accumulate, row_source=row_source)


def _fold_sum(cl, delta: np.ndarray) -> Optional[float]:
    """Total of ``delta`` via the segment-reduce kernel (one segment)."""
    if cl.backend == "kernels":
        from ..kernels import ops
        import jax.numpy as jnp
        out = ops.segment_reduce(jnp.asarray(delta, jnp.float32),
                                 jnp.zeros(delta.shape[0], jnp.int32), 1,
                                 op="sum")
        return float(np.asarray(out)[0])
    out = kref.segment_reduce_np(delta, np.zeros(delta.shape[0], np.int64), 1,
                                 op="sum")
    return float(out[0])


class SplicingInterpreter(Interpreter):
    """Interpreter that splices compiled columnar segments into the walk.

    Loops the lowering bound execute through
    :func:`~repro.core.vectorize.exec_loop_plan` with the compiled hooks;
    every other region — and any bound loop whose run-time source is not a
    non-empty Table — takes the inherited exact path. ``mode`` governs only
    the UNBOUND loops (default ``"fast"``, like the interpreted tier), so
    the two tiers stay clock-identical statement for statement."""

    def __init__(self, env, lowered, mode: str = "fast"):
        super().__init__(env, mode)
        self.lowered = lowered

    def exec_region(self, r, state) -> None:
        if isinstance(r, LoopRegion):
            cl = self.lowered.loop_for(r)
            if cl is not None:
                src = self.eval(r.source, state)
                if isinstance(src, Table) and src.nrows > 0:
                    exec_loop_plan(self.env, r, src, state, cl.plan,
                                   hooks=cl.hooks)
                    cl.executions += 1
                    self.lowered.columnar_execs += 1
                    tracer = getattr(self.env, "tracer", None)
                    if tracer is not None and tracer.enabled:
                        tracer.event(
                            "kernel-invoke", sim=self.env.clock,
                            loop_var=r.var, rows=src.nrows,
                            backend=self.lowered.backend)
                    return
                # run-time fallback (empty or non-table source): the exact
                # path also records collection-loop iteration observations
                self.lowered.fallback_execs += 1
                self._exec_loop_exact(r, src, state)
                return
        super().exec_region(r, state)
