"""Heat-based promotion of hot (program, plan, context) pairs to the
compiled tier.

The :class:`CompileManager` is owned by a
:class:`~repro.runtime.serving.ServingRuntime` (or any caller of
``run_batch(..., compiler=...)``). Every batch served on the interpreter
tier warms a heat counter keyed by (source-program fingerprint, chosen-plan
fingerprint, execution-context fingerprint, backend); once a pair crosses
``threshold`` invocations it is lowered
(:func:`repro.compiled.lower.lower_program`) and the resulting
:class:`~repro.compiled.lower.LoweredProgram` is cached in an
:class:`~repro.api.cache.ArtifactCache`, content-addressed with the same
scheme the disk :class:`~repro.runtime.store.PlanStore` uses
(:func:`~repro.runtime.store.content_address`) so the two tiers' artifacts
correlate in telemetry.

Correctness under statistics/data movement does NOT depend on this cache:
the compiled hooks re-check the (instance, stats version, data version)
epoch per probe index on every execution (see ``compiled.exec``). The
manager's :meth:`~CompileManager.invalidate_tables` — driven by the same
drift events that invalidate the serving SiteCache — is hygiene: it drops
artifacts (and their heat) for drifted tables so a recompiled plan starts
cold rather than inheriting stale promotion state.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, FrozenSet, Optional, Tuple

from ..api.cache import (ArtifactCache, program_fingerprint, program_sites,
                         program_tables)
from ..obs.metrics import MetricsRegistry, registry_counter
from ..obs.trace import NOOP_TRACER
from ..runtime.store import content_address
from .lower import LoweredProgram, lower_program, resolve_backend

__all__ = ["CompileManager", "CompiledArtifact"]

DEFAULT_COMPILE_THRESHOLD = 3


@dataclasses.dataclass
class CompiledArtifact:
    """One cached lowering. ``lowered`` is None when the plan had no
    columnar region — remembered so the manager never re-lowers a
    plan that cannot benefit."""

    key: Tuple
    address: str                      # content address (PlanStore vocabulary)
    lowered: Optional[LoweredProgram]
    compile_s: float
    tables: FrozenSet[str]            # base tables the plan touches


class CompileManager:
    """Promote hot (program, plan, context) pairs to compiled executables."""

    # registry-backed telemetry counters (repro.obs.metrics)
    compiles = registry_counter()
    noop_lowerings = registry_counter()  # plans lowered to 0 columnar loops
    compile_s_total = registry_counter()
    compiled_batches = registry_counter()
    interpreted_batches = registry_counter()

    def __init__(self, session, threshold: int = DEFAULT_COMPILE_THRESHOLD,
                 backend: Optional[str] = None, max_artifacts: int = 64):
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.session = session
        # must exist before the registry_counter descriptors are written
        self.metrics = MetricsRegistry()
        self.threshold = int(threshold)
        self.backend = resolve_backend(backend)
        self.artifacts = ArtifactCache(max_artifacts)
        self._heat: Dict[Tuple, int] = {}
        # zero the registry-backed counters
        self.compiles = 0
        self.noop_lowerings = 0
        self.compile_s_total = 0.0
        self.compiled_batches = 0
        self.interpreted_batches = 0

    # -------------------------------------------------------------- identity
    def key_for(self, exe) -> Tuple:
        """(source fp, plan fp, context fp address, backend) — the promotion
        unit. The plan fingerprint makes a feedback-driven plan swap start
        cold; the context fingerprint keeps a serving-context plan's heat
        separate from a one-shot compile of the same program."""
        ctx_fp = exe.context.fingerprint(sites=program_sites(exe.source))
        return (program_fingerprint(exe.source),
                program_fingerprint(exe.program),
                content_address(ctx_fp),
                self.backend)

    # ------------------------------------------------------------- promotion
    def lowered_for(self, exe, n_invocations: int = 1
                    ) -> Optional[LoweredProgram]:
        """The compiled executable for ``exe`` if it is hot (compiling it on
        first promotion), else None — the caller stays on the interpreter
        tier. ``n_invocations`` is how many invocations this batch carries;
        heat accumulates per invocation, not per batch."""
        key = self.key_for(exe)
        art = self.artifacts.get(key)
        if art is None:
            heat = self._heat.get(key, 0) + max(1, int(n_invocations))
            self._heat[key] = heat
            if heat < self.threshold:
                self.interpreted_batches += 1
                return None
            tracer = getattr(self.session, "tracer", NOOP_TRACER)
            t0 = time.perf_counter()
            with tracer.span("lowering", program=exe.program.name,
                             backend=self.backend):
                lowered = lower_program(exe.program, self.backend)
            dt = time.perf_counter() - t0
            if lowered.n_columnar == 0:
                # nothing data-parallel to run: remember the verdict so the
                # plan is never re-lowered, and stay on the interpreter
                lowered = None
                self.noop_lowerings += 1
            else:
                self.compiles += 1
                self.compile_s_total += dt
            art = CompiledArtifact(
                key=key, address=content_address(key), lowered=lowered,
                compile_s=dt, tables=frozenset(program_tables(exe.program)))
            self.artifacts.put(key, art)
        if art.lowered is None:
            self.interpreted_batches += 1
        else:
            self.compiled_batches += 1
        return art.lowered

    # ----------------------------------------------------------- maintenance
    def invalidate_tables(self, tables) -> int:
        """Drop artifacts (and promotion heat) touching ``tables`` — called
        on the same drift events that invalidate the serving SiteCache."""
        ts = set(tables)
        dropped = []

        def pred(key, art):
            if art.tables & ts:
                dropped.append(key)
                return True
            return False

        n = self.artifacts.invalidate(pred)
        for k in dropped:
            self._heat.pop(k, None)
        return n

    # ------------------------------------------------------------- telemetry
    def telemetry(self) -> Dict[str, object]:
        t = {"backend": self.backend,
             "threshold": self.threshold,
             "compiles": self.compiles,
             "noop_lowerings": self.noop_lowerings,
             "compile_s_total": self.compile_s_total,
             "compiled_batches": self.compiled_batches,
             "interpreted_batches": self.interpreted_batches,
             "hot_candidates": len(self._heat)}
        t.update({f"artifact_{k}": v
                  for k, v in self.artifacts.stats().items()})
        return t
