"""Lowering: turn a winning plan's data-parallel regions into columnar
vectorized executables.

``lower_program`` walks a (rewritten) :class:`~repro.core.regions.Program`,
asks :func:`~repro.core.regions.compilability` for the per-region verdict,
and binds every ``"columnar"`` loop to a :class:`CompiledLoop` — the loop's
precomputed :class:`~repro.core.vectorize.LoopPlan` plus kernel-backed
:class:`~repro.core.vectorize.LoopHooks` (epoch-cached probe indices, the
``join_probe``/``segment_reduce`` kernels through ``kernels.ops``, or the
``kernels.ref`` numpy reference path when jax is not importable). Regions
the analysis rejects — ``while`` guards, early exits, nested loops, update
bodies — carry no binding and stay on the row-at-a-time interpreter; the
:class:`~repro.compiled.exec.SplicingInterpreter` splices the compiled
segments around them at run time.

The lowering is *semantically checked* against F-IR: an accumulator is only
eligible for a kernel fold when :func:`repro.core.fir.fold_accumulators`
derives the same operator for it that the loop plan matched — two
independent analyses must agree before a fold leaves the (bit-exact)
sequential float64 path. Even then the fold runs behind a runtime exactness
gate (integer deltas within fp32's exact range); anything else falls back
to the default accumulate, which is itself columnar.

Simulated-time charging is NOT part of this module: every compiled loop
executes through :func:`repro.core.vectorize.exec_loop_plan`, the one code
path the fast interpreter also runs, so compiled and interpreted executions
agree on the clock by construction.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Optional, Tuple

from ..core.fir import fold_accumulators
from ..core.regions import (CompileNote, LoopRegion, Program, Region,
                            compilability)
from ..core.vectorize import LoopHooks, LoopPlan, analyze_loop

__all__ = ["CompiledLoop", "LoweredProgram", "lower_program",
           "resolve_backend", "available_backends"]


def available_backends() -> Tuple[str, ...]:
    """Backends this process can lower to, preferred first."""
    from .. import kernels
    return ("kernels", "numpy") if kernels.HAS_JAX else ("numpy",)


def resolve_backend(requested: Optional[str] = None) -> str:
    """Pick the execution backend: ``"kernels"`` (jnp dispatch through
    ``kernels.ops``, Pallas when ``ops.use_pallas`` is on) when jax is
    importable, the ``kernels.ref`` numpy path otherwise. The
    ``REPRO_COMPILED_BACKEND`` environment variable overrides the default;
    an explicit ``requested`` overrides both."""
    avail = available_backends()
    choice = requested or os.environ.get("REPRO_COMPILED_BACKEND") or avail[0]
    if choice not in ("kernels", "numpy"):
        raise ValueError(f"unknown compiled backend {choice!r}; "
                         f"expected 'kernels' or 'numpy'")
    if choice not in avail:
        raise RuntimeError(f"backend {choice!r} unavailable "
                           f"(jax not importable); available: {avail}")
    return choice


def _stmt_free_vars(stmt) -> set:
    """Free variables of one planned statement (guards included)."""
    if isinstance(stmt, tuple) and stmt[0] == "__guard__":
        return set(stmt[1].free_vars())
    out = set()
    for attr in ("expr", "val", "keyexpr", "valexpr"):
        e = getattr(stmt, attr, None)
        if e is not None:
            out |= set(e.free_vars())
    return out


def _kernel_foldable_accs(plan: LoopPlan,
                          fold_ops: Optional[Dict[str, str]]) -> frozenset:
    """Accumulators eligible for a ``segment_reduce`` kernel fold.

    Requirements (all conservative — a miss only means the default
    float64-cumsum accumulate, which is already columnar and bit-exact):

      * the F-IR cross-check agrees the accumulator is ``acc = acc + e``
        (``fold_accumulators`` derives ``"+"`` independently of the loop
        plan's pattern match);
      * no OTHER planned statement (guard predicates included) references
        the accumulator — a kernel fold produces only the final scalar, so
        a body read of the running value has nowhere to come from.
    """
    if fold_ops is None:
        return frozenset()
    out = set()
    for acc in plan.accumulators:
        if fold_ops.get(acc) != "+":
            continue
        referenced_elsewhere = False
        skipped_own_update = False
        for stmt, _guard in plan.stmts:
            # skip exactly ONE statement: the accumulator's defining update
            # (a later re-assign of the same name reads the running column,
            # which a kernel fold does not produce — that counts as a ref)
            if not skipped_own_update and not isinstance(stmt, tuple) \
                    and getattr(stmt, "target", None) == acc:
                skipped_own_update = True
                continue
            if acc in _stmt_free_vars(stmt):
                referenced_elsewhere = True
                break
        if not referenced_elsewhere:
            out.add(acc)
    return frozenset(out)


@dataclasses.dataclass
class CompiledLoop:
    """One columnar loop binding: plan + kernel-backed hooks + telemetry."""

    region: LoopRegion
    plan: LoopPlan
    hooks: LoopHooks
    backend: str
    fold_ops: Dict[str, str]          # F-IR cross-check result per accumulator
    kernel_fold_accs: frozenset       # accs eligible for a kernel fold
    # execution telemetry (filled by the hooks in compiled.exec)
    executions: int = 0
    kernel_probes: int = 0
    kernel_folds: int = 0
    index_rebuilds: int = 0


class LoweredProgram:
    """A program with its columnar loops bound to compiled executables.

    The binding is by region *identity* (``id``) against THIS program
    object's tree — a ``LoweredProgram`` always runs its own ``program``,
    so content-addressed artifact reuse across Executables is safe."""

    def __init__(self, program: Program, backend: str,
                 loops: Dict[int, CompiledLoop],
                 notes: Dict[Tuple, CompileNote], lower_s: float):
        self.program = program
        self.backend = backend
        self._loops = loops
        self.notes = notes
        self.lower_s = lower_s
        # tier telemetry
        self.columnar_execs = 0       # loops served by a compiled segment
        self.fallback_execs = 0       # lowered loops that fell back at run
        self.interpreter_regions = sum(
            1 for n in notes.values() if n.verdict == "interpreter")

    def loop_for(self, r: Region) -> Optional[CompiledLoop]:
        return self._loops.get(id(r))

    @property
    def n_columnar(self) -> int:
        return len(self._loops)

    def run(self, env, params=None):
        """Execute on ``env`` through the splicing interpreter."""
        from .exec import SplicingInterpreter
        return SplicingInterpreter(env, self).run(self.program, params)

    def describe(self) -> str:
        return (f"LoweredProgram[{self.program.name}] backend={self.backend}: "
                f"{self.n_columnar} columnar loop(s), "
                f"{self.interpreter_regions} interpreter region(s)")


def lower_program(program: Program,
                  backend: Optional[str] = None) -> LoweredProgram:
    """Lower every columnar-verdict loop of ``program``; regions outside the
    columnar vocabulary keep their interpreter binding (tiered fallback)."""
    from .exec import make_hooks
    backend = resolve_backend(backend)
    t0 = time.perf_counter()
    notes = compilability(program)
    loops: Dict[int, CompiledLoop] = {}

    def walk(r: Region) -> None:
        if isinstance(r, LoopRegion):
            note = notes.get(r.key())
            # note lookup is by structural key; two identically-shaped loops
            # share a verdict but each gets its own binding (identity map)
            if note is not None and note.verdict == "columnar":
                plan = analyze_loop(r, {})
                if plan is not None:
                    fold_ops = fold_accumulators(r) or {}
                    cl = CompiledLoop(
                        region=r, plan=plan, hooks=LoopHooks(),
                        backend=backend, fold_ops=fold_ops,
                        kernel_fold_accs=_kernel_foldable_accs(plan, fold_ops
                                                               or None))
                    cl.hooks = make_hooks(cl)
                    loops[id(r)] = cl
        for c in r.children():
            walk(c)

    walk(program.body)
    return LoweredProgram(program, backend, loops, notes,
                          lower_s=time.perf_counter() - t0)
