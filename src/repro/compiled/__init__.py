"""Compiled plan execution tier.

Lowers a winning plan's data-parallel regions — cursor loops with slot
queries, prefetch+lookup joins, fold/aggregation bodies — to columnar
vectorized executables backed by the ``repro.kernels`` JAX/Pallas kernels
(``join_probe``, ``segment_reduce``) when jax is importable, or the
``kernels.ref`` numpy reference paths otherwise. Regions outside the
columnar vocabulary (``while`` guards, early exits, update-carrying
bodies) keep their interpreter binding; the :class:`SplicingInterpreter`
splices compiled segments around them, so every program runs end to end
on whichever mix of tiers its regions support.

Execution is **bit- and clock-identical** to the interpreted tier by
construction: compiled loops run through the same
:func:`repro.core.vectorize.exec_loop_plan` statement walk (which owns all
simulated-time charging), and the kernel-backed probe indices are keyed by
the same (instance, stats version, data version) epochs the serving
SiteCache tracks, so mid-stream ``analyze()``/writes rebuild them instead
of serving stale gathers.

  lower   — compilability-driven lowering: ``lower_program`` ->
            :class:`LoweredProgram` (bound :class:`CompiledLoop` s)
  exec    — kernel-backed :class:`~repro.core.vectorize.LoopHooks` and the
            :class:`SplicingInterpreter` tiered fallback
  manager — :class:`CompileManager`: heat-based promotion of hot
            (program, plan, context) pairs, content-addressed artifact
            cache, drift-driven invalidation
"""

from .exec import SplicingInterpreter
from .lower import (CompiledLoop, LoweredProgram, available_backends,
                    lower_program, resolve_backend)
from .manager import CompiledArtifact, CompileManager

__all__ = [
    "CompiledLoop", "LoweredProgram", "lower_program",
    "available_backends", "resolve_backend",
    "SplicingInterpreter",
    "CompileManager", "CompiledArtifact",
]
