"""Fault-tolerant checkpointing: atomic, async, per-shard, elastic.

Layout:  <dir>/step_<N>/
             manifest.json        (step, tree structure, shapes, dtypes)
             arrays.npz           (flat param/opt arrays, host-gathered)
             extras.json          (data-pipeline state, rng, metrics)
         <dir>/LATEST             (atomic pointer, written last)

Guarantees:
  * atomic commit — a checkpoint is visible only after its directory is
    fully written and LATEST is renamed into place; a crash mid-write leaves
    the previous checkpoint intact;
  * async save — arrays are device_get'd synchronously (cheap vs. a step)
    then written on a background thread, off the step critical path;
  * elastic restore — arrays are stored UNSHARDED (canonical form); on load
    they are re-placed under the CURRENT mesh/spec, so restarting on a
    different topology (e.g. 256 → 512 chips) re-shards transparently;
  * retention — keep the last `keep` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Dict[str, Any], extras: Optional[Dict] = None,
             block: bool = False) -> None:
        """Snapshot to host, then write asynchronously."""
        self.wait()  # one in-flight save at a time
        host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                      tree)
        extras = dict(extras or {})

        def _write():
            tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
            try:
                flat, _ = _flatten_with_paths(host)
                # npz has no bf16: store widened to f32 (lossless), restore
                # casts back to the template dtype
                storable = {k: (v.astype(np.float32)
                                if str(v.dtype) == "bfloat16" else v)
                            for k, v in flat.items()}
                np.savez(os.path.join(tmp, "arrays.npz"), **storable)
                manifest = {
                    "step": step,
                    "keys": sorted(flat),
                    "shapes": {k: list(np.shape(v)) for k, v in flat.items()},
                    "dtypes": {k: str(np.asarray(v).dtype) for k, v in flat.items()},
                }
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                with open(os.path.join(tmp, "extras.json"), "w") as f:
                    json.dump(extras, f)
                final = os.path.join(self.dir, f"step_{step:08d}")
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                # atomic pointer
                ptr_tmp = os.path.join(self.dir, ".LATEST.tmp")
                with open(ptr_tmp, "w") as f:
                    f.write(f"step_{step:08d}")
                os.replace(ptr_tmp, os.path.join(self.dir, "LATEST"))
                self._gc()
            except BaseException:
                shutil.rmtree(tmp, ignore_errors=True)
                raise

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir) if d.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, template: Dict[str, Any], step: Optional[int] = None,
                shardings=None) -> Tuple[int, Dict[str, Any], Dict]:
        """Restore into the structure of `template`; if `shardings` (a
        matching tree of NamedShardings) is given, arrays are placed sharded
        under the CURRENT mesh — elastic re-sharding for free."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(d, "arrays.npz"))
        with open(os.path.join(d, "extras.json")) as f:
            extras = json.load(f)

        flat_t, treedef = _flatten_with_paths(template)
        missing = [k for k in flat_t if k not in data.files]
        if missing:
            raise KeyError(f"checkpoint missing arrays: {missing[:5]} ...")

        flat_s = None
        if shardings is not None:
            flat_s, _ = _flatten_with_paths(shardings)

        restored = {}
        for k, tmpl in flat_t.items():
            arr = data[k]
            want = tuple(np.shape(tmpl))
            if tuple(arr.shape) != want:
                raise ValueError(f"{k}: shape {arr.shape} != template {want}")
            if hasattr(tmpl, "dtype"):
                arr = jax.numpy.asarray(arr).astype(tmpl.dtype)
            else:
                arr = jax.numpy.asarray(arr)
            if flat_s is not None and k in flat_s:
                restored[k] = jax.device_put(arr, flat_s[k])
            else:
                restored[k] = arr

        leaves = [restored[k] for k in sorted(flat_t)]
        order = {k: i for i, k in enumerate(sorted(flat_t))}
        # rebuild in treedef order
        keys_in_order = list(flat_t)
        tree = jax.tree_util.tree_unflatten(
            treedef, [restored[k] for k in keys_in_order])
        return step, tree, extras
