"""Disk-backed, content-addressed plan store: reuse plans ACROSS sessions.

The in-memory :class:`repro.api.cache.PlanCache` dies with its session; a
serving deployment re-pays the memo search on every process start. The
``PlanStore`` persists compiled :class:`~repro.core.search.OptimizationResult`
objects under a directory, addressed by the same content-stable key
vocabulary the in-memory cache uses:

  * **logical key** — SHA-256 of (program fingerprint, cost-catalog key,
    optimizer-config key). One file per logical key: a new compilation of
    the same program under fresh statistics supersedes the stale entry.
  * **stats fingerprint** — a CONTENT hash of the per-table statistics the
    plan was costed against, stored WITH the entry. A lookup whose
    fingerprint differs is a *stale* hit (counted separately from cold
    misses): the data moved, the plan must be recompiled. Content hashes —
    not the in-memory cache's process-local version counters — are what let
    a restarted server (whose counters reset) still warm-start from the
    store when its statistics are byte-equal.

Entries are pickled (plans embed Region/F-IR/Query trees); a human-readable
``index.json`` sidecar carries per-entry metadata (fingerprint, estimated
cost, stats token) for inspection and the example scripts. Writes are
atomic (tempfile + ``os.replace``) so concurrent sessions sharing a store
directory never observe torn entries.

Codegen alpha-normalization (``core.fir.NameGen``) is what makes this
dedupe possible: two sessions compiling the same program emit byte-identical
IR, so the stored artifact is canonical rather than run-specific.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import tempfile
from typing import Dict, Optional

__all__ = ["PlanStore"]

_FORMAT_VERSION = 1


class PlanStore:
    """A directory of compiled plans shared by many sessions."""

    def __init__(self, root: str):
        self.root = os.fspath(root)
        os.makedirs(self.root, exist_ok=True)
        self.hits = 0
        self.misses = 0
        self.stale = 0
        self.puts = 0
        self.errors = 0

    # ----------------------------------------------------------- addressing
    @staticmethod
    def logical_key(key) -> str:
        """Content hash of the plan's identity minus its stats token."""
        ident = (key.program_fp, key.catalog_key, key.config_key)
        return hashlib.sha256(repr(ident).encode()).hexdigest()[:32]

    def _path(self, lk: str) -> str:
        return os.path.join(self.root, f"{lk}.plan")

    @classmethod
    def coerce(cls, store) -> "PlanStore":
        """Accept a PlanStore instance or a directory path (the shared
        coercion used by CobraSession and ServingRuntime)."""
        return store if isinstance(store, cls) else cls(store)

    # -------------------------------------------------------------- get/put
    def get(self, key, stats_fp=None) -> Optional[object]:
        """Return the stored OptimizationResult for ``key``, or None.

        ``stats_fp`` is the content fingerprint of the caller's CURRENT
        statistics for the plan's tables; when provided, entry validity is
        judged by it (restart-stable). Without it, the key's version token
        is compared instead. Misses distinguish *cold* (no entry for the
        program at all) from *stale* (an entry exists but was compiled
        against different table statistics)."""
        path = self._path(self.logical_key(key))
        if not os.path.exists(path):
            self.misses += 1
            return None
        try:
            with open(path, "rb") as f:
                payload = pickle.load(f)
        except Exception:
            self.errors += 1
            return None
        if payload.get("format") != _FORMAT_VERSION:
            self.errors += 1
            return None
        if stats_fp is not None:
            valid = payload.get("stats_fp") == stats_fp
        else:
            valid = payload["stats_token"] == key.stats_version
        if not valid:
            self.stale += 1
            return None
        self.hits += 1
        return payload["result"]

    def put(self, key, result, stats_fp=None) -> None:
        lk = self.logical_key(key)
        payload = {
            "format": _FORMAT_VERSION,
            "program_fp": key.program_fp,
            "stats_token": key.stats_version,
            "stats_fp": stats_fp,
            "result": result,
        }
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, self._path(lk))
        except Exception:
            self.errors += 1
            if os.path.exists(tmp):
                os.unlink(tmp)
            return
        self.puts += 1
        try:
            # best-effort metadata sidecar: concurrent writers may lose an
            # index record to the read-modify-write race, but never a plan —
            # entry validity comes from the .plan payload alone
            self._index_add(lk, key, result)
        except Exception:
            self.errors += 1

    # ----------------------------------------------------------- inspection
    def _index_path(self) -> str:
        return os.path.join(self.root, "index.json")

    def _index_add(self, lk: str, key, result) -> None:
        index = self.index()
        index[lk] = {
            "program_fp": key.program_fp,
            "stats_token": [list(tv) for tv in key.stats_version]
            if isinstance(key.stats_version, tuple) else key.stats_version,
            "est_cost_s": float(getattr(result, "est_cost", 0.0)),
            "program": getattr(getattr(result, "program", None), "name", "?"),
        }
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(index, f, indent=1, sort_keys=True)
        os.replace(tmp, self._index_path())

    def index(self) -> Dict[str, Dict]:
        try:
            with open(self._index_path()) as f:
                return json.load(f)
        except Exception:
            return {}

    def __len__(self) -> int:
        return sum(1 for n in os.listdir(self.root) if n.endswith(".plan"))

    def clear(self) -> None:
        for n in os.listdir(self.root):
            if n.endswith(".plan") or n == "index.json":
                os.unlink(os.path.join(self.root, n))

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self), "hits": self.hits,
                "misses": self.misses, "stale": self.stale,
                "puts": self.puts, "errors": self.errors}
