"""Disk-backed, content-addressed plan store: reuse plans ACROSS sessions.

The in-memory :class:`repro.api.cache.PlanCache` dies with its session; a
serving deployment re-pays the memo search on every process start. The
``PlanStore`` persists compiled :class:`~repro.core.search.OptimizationResult`
objects under a directory, addressed by the same content-stable key
vocabulary the in-memory cache uses:

  * **logical key** — SHA-256 of (program fingerprint, cost-catalog key,
    optimizer-config key). One file per logical key: a new compilation of
    the same program under fresh statistics supersedes the stale entry.
  * **stats fingerprint** — a CONTENT hash of the per-table statistics the
    plan was costed against, stored WITH the entry. A lookup whose
    fingerprint differs is a *stale* hit (counted separately from cold
    misses): the data moved, the plan must be recompiled. Content hashes —
    not the in-memory cache's process-local version counters — are what let
    a restarted server (whose counters reset) still warm-start from the
    store when its statistics are byte-equal.

Entries are pickled (plans embed Region/F-IR/Query trees); a human-readable
``index.json`` sidecar carries per-entry metadata (fingerprint, estimated
cost, stats token) for inspection and the example scripts. Writes are
atomic (tempfile + ``os.replace``) so concurrent sessions sharing a store
directory never observe torn entries.

**Cold-compile races** resolve first-writer-wins: two sessions compiling
the same cold program both run the memo search, but :meth:`put` re-reads
before writing — when a valid entry for the same statistics already landed,
the second writer DISCARDS its own result and returns the stored one, so
every session serves the one canonical plan (``races`` counts these). A
racer that slips between the re-read and the replace merely overwrites with
an equivalent artifact: alpha-normalized codegen (``core.fir.NameGen``)
makes two compilations of the same program under the same statistics
byte-identical, which is also what makes the dedupe meaningful at all.

``max_entries`` bounds the directory: stores past the bound GC their
least-recently-used plans (access order approximated by file mtime, which
:meth:`get` refreshes on every hit).
"""

from __future__ import annotations

import hashlib
import json
import os
import pickle
import tempfile
from typing import Dict, Optional

__all__ = ["PlanStore", "content_address"]

_FORMAT_VERSION = 1


def content_address(ident) -> str:
    """Stable short content hash of a repr-stable identity tuple — the
    addressing scheme shared by the plan store and the compiled-artifact
    cache (:mod:`repro.compiled.manager`), so the two tiers' artifacts can
    be correlated in telemetry and on disk."""
    return hashlib.sha256(repr(ident).encode()).hexdigest()[:32]


class _Corrupt:
    """Sentinel: an entry file exists but cannot be trusted."""


_CORRUPT = _Corrupt()


class PlanStore:
    """A directory of compiled plans shared by many sessions."""

    def __init__(self, root: str, max_entries: Optional[int] = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError("max_entries must be >= 1 (or None: unbounded)")
        self.root = os.fspath(root)
        os.makedirs(self.root, exist_ok=True)
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.stale = 0
        self.puts = 0
        self.races = 0
        self.gc_evictions = 0
        self.errors = 0

    # ----------------------------------------------------------- addressing
    @staticmethod
    def logical_key(key) -> str:
        """Content hash of the plan's identity minus its stats token. The
        execution-context fingerprint is part of the identity: a plan
        compiled for serving (batch_size=64) and one compiled one-shot are
        different artifacts and coexist in the store."""
        ident = (key.program_fp, key.catalog_key, key.config_key,
                 getattr(key, "context_key", ()))
        return content_address(ident)

    def _path(self, lk: str) -> str:
        return os.path.join(self.root, f"{lk}.plan")

    @classmethod
    def coerce(cls, store) -> "PlanStore":
        """Accept a PlanStore instance or a directory path (the shared
        coercion used by CobraSession and ServingRuntime)."""
        return store if isinstance(store, cls) else cls(store)

    # -------------------------------------------------------------- get/put
    def get(self, key, stats_fp=None) -> Optional[object]:
        """Return the stored OptimizationResult for ``key``, or None.

        ``stats_fp`` is the content fingerprint of the caller's CURRENT
        statistics for the plan's tables; when provided, entry validity is
        judged by it (restart-stable). Without it, the key's version token
        is compared instead. Misses distinguish *cold* (no entry for the
        program at all) from *stale* (an entry exists but was compiled
        against different table statistics)."""
        path = self._path(self.logical_key(key))
        payload = self._load(path)
        if payload is None:
            self.misses += 1
            return None
        if payload is _CORRUPT:
            self.errors += 1
            return None
        if not self._valid(payload, key, stats_fp):
            self.stale += 1
            return None
        self.hits += 1
        try:
            os.utime(path)  # refresh LRU recency for the GC bound
        except OSError:
            pass
        return payload["result"]

    def _load(self, path: str):
        """None = no entry; _CORRUPT = unreadable/wrong format."""
        if not os.path.exists(path):
            return None
        try:
            with open(path, "rb") as f:
                payload = pickle.load(f)
        except FileNotFoundError:
            return None  # GC'd between the exists check and the open
        except Exception:
            return _CORRUPT
        if not isinstance(payload, dict) \
                or payload.get("format") != _FORMAT_VERSION:
            return _CORRUPT
        return payload

    @staticmethod
    def _valid(payload, key, stats_fp) -> bool:
        if stats_fp is not None:
            return payload.get("stats_fp") == stats_fp
        return payload["stats_token"] == key.stats_version

    def put(self, key, result, stats_fp=None):
        """Persist ``result``; returns the CANONICAL stored result.

        First-writer-wins with re-read: when another session already stored
        a plan for this key that is valid for the same statistics, this
        session's freshly-compiled result is discarded and the stored one
        returned — callers should serve the return value, so racing
        sessions converge on one canonical plan. A stale existing entry
        (different statistics) is superseded as before."""
        lk = self.logical_key(key)
        path = self._path(lk)
        existing = self._load(path)
        if isinstance(existing, dict) and self._valid(existing, key, stats_fp):
            self.races += 1
            return existing["result"]
        payload = {
            "format": _FORMAT_VERSION,
            "program_fp": key.program_fp,
            "stats_token": key.stats_version,
            "stats_fp": stats_fp,
            "result": result,
        }
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, path)
        except Exception:
            self.errors += 1
            if os.path.exists(tmp):
                os.unlink(tmp)
            return result
        self.puts += 1
        try:
            # best-effort metadata sidecar: concurrent writers may lose an
            # index record to the read-modify-write race, but never a plan —
            # entry validity comes from the .plan payload alone
            self._index_add(lk, key, result)
        except Exception:
            self.errors += 1
        self._gc()
        return result

    # -------------------------------------------------------------------- GC
    def _gc(self) -> None:
        """Drop least-recently-used plans beyond ``max_entries``."""
        if self.max_entries is None:
            return
        try:
            entries = []
            for n in os.listdir(self.root):
                if not n.endswith(".plan"):
                    continue
                p = os.path.join(self.root, n)
                try:
                    entries.append((os.path.getmtime(p), p, n[:-5]))
                except OSError:
                    continue  # concurrently removed
            excess = len(entries) - self.max_entries
            if excess <= 0:
                return
            entries.sort()  # oldest mtime (= least recently used) first
            dropped = []
            for _, p, lk in entries[:excess]:
                try:
                    os.unlink(p)
                    dropped.append(lk)
                    self.gc_evictions += 1
                except OSError:
                    pass
            if dropped:
                self._index_drop(dropped)
        except Exception:
            self.errors += 1

    # ----------------------------------------------------------- inspection
    def _index_path(self) -> str:
        return os.path.join(self.root, "index.json")

    def _index_add(self, lk: str, key, result) -> None:
        index = self.index()
        index[lk] = {
            "program_fp": key.program_fp,
            "stats_token": [list(tv) for tv in key.stats_version]
            if isinstance(key.stats_version, tuple) else key.stats_version,
            "context": repr(getattr(key, "context_key", ())),
            "est_cost_s": float(getattr(result, "est_cost", 0.0)),
            "program": getattr(getattr(result, "program", None), "name", "?"),
        }
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(index, f, indent=1, sort_keys=True)
        os.replace(tmp, self._index_path())

    def _index_drop(self, keys) -> None:
        try:
            index = self.index()
            for lk in keys:
                index.pop(lk, None)
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(index, f, indent=1, sort_keys=True)
            os.replace(tmp, self._index_path())
        except Exception:
            pass  # sidecar only; the .plan files are the source of truth

    def index(self) -> Dict[str, Dict]:
        try:
            with open(self._index_path()) as f:
                return json.load(f)
        except Exception:
            return {}

    def __len__(self) -> int:
        return sum(1 for n in os.listdir(self.root) if n.endswith(".plan"))

    def clear(self) -> None:
        for n in os.listdir(self.root):
            if n.endswith(".plan") or n == "index.json":
                os.unlink(os.path.join(self.root, n))

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self), "hits": self.hits,
                "misses": self.misses, "stale": self.stale,
                "puts": self.puts, "races": self.races,
                "gc_evictions": self.gc_evictions, "errors": self.errors}
