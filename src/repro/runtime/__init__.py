"""Serving runtime: batched execution, persistent plans, feedback re-planning.

The subsystem that fronts :class:`~repro.api.session.CobraSession` for
production-shaped workloads:

  * :mod:`repro.runtime.batch` — ``run_batch`` / ``BatchClientEnv``: one
    server round trip per query site per batch of parameter bindings
    (``C_NRT`` amortization, the paper's batching transformation applied at
    the serving layer);
  * :mod:`repro.runtime.store` — ``PlanStore``: disk-backed,
    content-addressed plan cache shared across sessions/processes;
  * :mod:`repro.runtime.feedback` — ``FeedbackController``: observed-vs-
    estimated cardinality drift triggers per-table re-analyze + recompile;
  * :mod:`repro.runtime.serving` — ``ServingRuntime`` / ``serve()``: the
    request loop wiring the three together.

See ``examples/serve_programs.py`` for the end-to-end walkthrough and
``benchmarks/bench_runtime.py`` for the batch-size/throughput crossover.
"""

from .batch import BatchClientEnv, BatchResult, program_has_updates, run_batch
from .feedback import DriftEvent, FeedbackController
from .serving import ServingRuntime, serve
from .store import PlanStore

__all__ = [
    "BatchClientEnv", "BatchResult", "run_batch", "program_has_updates",
    "PlanStore", "DriftEvent", "FeedbackController",
    "ServingRuntime", "serve",
]
