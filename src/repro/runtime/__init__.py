"""Serving runtime: batched execution, persistent plans, feedback re-planning.

The subsystem that fronts :class:`~repro.api.session.CobraSession` for
production-shaped workloads:

  * :mod:`repro.runtime.batch` — ``run_batch`` / ``BatchClientEnv``: one
    server round trip per query site per batch of parameter bindings
    (``C_NRT`` amortization, the paper's batching transformation applied at
    the serving layer), write-set-aware for mutating programs;
  * :mod:`repro.runtime.sitecache` — ``SiteCache``: the serving-scoped,
    epoch-keyed query-result cache shared across batches AND programs
    (serving-layer MQO), with TTL + analyze()/write invalidation and
    per-site binding-diversity observation;
  * :mod:`repro.runtime.store` — ``PlanStore``: disk-backed,
    content-addressed plan cache shared across sessions/processes;
  * :mod:`repro.runtime.feedback` — ``FeedbackController``: observed-vs-
    estimated cardinality drift triggers per-table re-analyze + recompile;
    observed iteration counts and binding-diversity fractions publish into
    the serving ExecutionContext;
  * :mod:`repro.runtime.serving` — ``ServingRuntime`` / ``serve()``: the
    request loop wiring them together, including the compiled execution
    tier (:mod:`repro.compiled`): a ``CompileManager`` promotes hot
    (program, plan, context) pairs to kernel-backed columnar executables
    after ``compile_hot_plans`` interpreted invocations.

See ``examples/serve_programs.py`` for the end-to-end walkthrough and
``benchmarks/bench_runtime.py`` for the batch-size/throughput crossover.
"""

from .batch import BatchClientEnv, BatchResult, program_has_updates, run_batch
from .feedback import DriftEvent, FeedbackController
from .serving import ServingRuntime, serve
from .sitecache import SiteCache, Uncacheable
from .store import PlanStore

__all__ = [
    "BatchClientEnv", "BatchResult", "run_batch", "program_has_updates",
    "SiteCache", "Uncacheable",
    "PlanStore", "DriftEvent", "FeedbackController",
    "ServingRuntime", "serve",
]
