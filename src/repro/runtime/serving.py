"""The serving request loop: batch, execute, observe, re-optimize.

``ServingRuntime`` fronts a :class:`~repro.api.session.CobraSession` for
high-throughput workloads::

    rt = ServingRuntime(session, store="plans/", batch_size=32)
    rt.register(make_p0())
    responses = rt.serve([("P0", {}), ("P0", {}), ("W_E", {"worklist": [1]})])

Request processing per cycle:

  1. requests are grouped by program and chunked into batches of at most
     ``batch_size``;
  2. each batch executes through :func:`repro.runtime.batch.run_batch`
     against the runtime's **shared site cache**
     (:class:`~repro.runtime.sitecache.SiteCache`) — one server round trip
     per query site per STATS EPOCH, shared across batches and across
     programs (serving-layer MQO); epoch keys + ``analyze()``/write
     invalidation keep every cached result bit-identical to an uncached
     fetch;
  3. the batch's observation log feeds the
     :class:`~repro.runtime.feedback.FeedbackController`; if observed
     cardinalities drifted past the threshold, the drifted tables are
     re-analyzed (per-table stats versions bump, their site-cache entries
     drop) and every registered program touching them is recompiled before
     the next batch — the memo search may pick a different winner under
     the fresh statistics;
  4. responses are returned in the original request order.

Every compile goes through the runtime's **serving context** — an
:class:`~repro.core.context.ExecutionContext` whose ``batch_size`` is the
runtime's and whose :class:`~repro.core.context.StatsProfile` is whatever
the feedback controller has published (observed while-loop and worklist-
loop iteration counts, plus per-site binding-diversity fractions measured
at the site cache). The memo search therefore costs plans for batched
execution — C_NRT of binding-free sites amortized across the batch, and
of parameterized sites by their OBSERVED distinct-binding fraction — and
may legitimately pick a different winner than a one-shot session would for
the very same program. When a batch's iteration or binding observations
move a published value, the context fingerprint changes and the affected
programs are recompiled under the new context (programs without that site
keep their keys, hence their plans, untouched).

The module-level :func:`serve` is the one-call convenience wrapper used by
``examples/serve_programs.py``.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from ..api.cache import program_fingerprint, program_tables
from ..core.context import ExecutionContext
from ..core.regions import Program
from ..obs.metrics import MetricsRegistry, merge_snapshots, registry_counter
from ..obs.trace import NOOP_TRACER
from .feedback import FeedbackController
from .sitecache import SiteCache

__all__ = ["ServingRuntime", "serve"]


class ServingRuntime:
    # registry-backed telemetry counters (repro.obs.metrics); the legacy
    # attribute reads/writes and telemetry() dict shape are unchanged views
    requests_served = registry_counter()
    batches_run = registry_counter()
    recompiles = registry_counter()
    context_recompiles = registry_counter()
    swaps_rejected = registry_counter()
    simulated_s = registry_counter()
    n_round_trips = registry_counter()

    def __init__(self, session, *, store=None, batch_size: int = 16,
                 drift_threshold: float = 3.0,
                 cost_drift_threshold: Optional[float] = 10.0,
                 feedback: bool = True,
                 context: Optional[ExecutionContext] = None,
                 site_cache: Optional[SiteCache] = None,
                 site_cache_ttl_s: Optional[float] = None,
                 site_cache_entries: int = 4096,
                 site_cache_max_bytes: Optional[int] = None,
                 compile_hot_plans: Optional[int] = None,
                 compile_backend: Optional[str] = None,
                 replay_window: int = 8,
                 tracer=None):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if replay_window < 0:
            raise ValueError("replay_window must be >= 0")
        self.session = session
        # observability: the runtime's OWN registry (sharing the session's
        # would collide when several runtimes front one session); the tracer
        # defaults to the session's so compile + serve spans share one tree
        self.metrics = MetricsRegistry()
        self.tracer = tracer if tracer is not None else \
            getattr(session, "tracer", NOOP_TRACER)
        if store is not None:
            from .store import PlanStore
            session.plan_store = PlanStore.coerce(store)
        self.batch_size = batch_size
        # the serving-scoped shared site cache: one fetch per identical
        # query site per stats epoch, across batches AND programs
        self.site_cache = site_cache if site_cache is not None else \
            SiteCache(ttl_s=site_cache_ttl_s, max_entries=site_cache_entries,
                      max_bytes=site_cache_max_bytes)
        # the base serving context; observed stats are layered onto it as
        # the feedback controller publishes them
        self._base_context = context if context is not None else \
            ExecutionContext(batch_size=batch_size)
        self.feedback: Optional[FeedbackController] = (
            FeedbackController(session, drift_threshold,
                               cost_drift_threshold=cost_drift_threshold)
            if feedback else None)
        # compiled execution tier: promote hot (program, plan, context)
        # pairs after `compile_hot_plans` interpreted invocations (argument
        # overrides the session config's knob; None/0 = tier off)
        threshold = compile_hot_plans if compile_hot_plans is not None \
            else getattr(session.config, "compile_hot_plans", None)
        if threshold:
            from ..compiled.manager import CompileManager
            self.compiler = CompileManager(session, threshold=threshold,
                                           backend=compile_backend)
        else:
            self.compiler = None
        self._programs: Dict[str, Program] = {}
        self._executables: Dict[str, object] = {}
        # last-K observed bindings per program — the anti-regression guard's
        # replay workload when a recompile proposes a different plan
        self.replay_window = replay_window
        self._recent: Dict[str, deque] = {}
        # zero the registry-backed telemetry counters (class descriptors)
        self.requests_served = 0
        self.batches_run = 0
        self.recompiles = 0
        self.context_recompiles = 0
        self.swaps_rejected = 0
        self.simulated_s = 0.0
        self.n_round_trips = 0
        # per-program request counts — the traffic shares triage() weights by
        self._requests_by_program: Dict[str, int] = {}

    # -------------------------------------------------------------- context
    def current_context(self) -> ExecutionContext:
        """The ExecutionContext serving compiles are costed for right now:
        the runtime's batch size + the feedback controller's published
        iteration statistics."""
        if self.feedback is None:
            return self._base_context
        return self._base_context.with_stats(self.feedback.stats_profile())

    # ---------------------------------------------------------- registration
    def register(self, program: Program, name: Optional[str] = None):
        """Register (and compile) a program for serving; returns its
        Executable. Compilation is costed under the serving context (batch
        size + observed stats) and goes through the session, so the plan
        cache/store make repeated registration cheap."""
        name = name or program.name
        self._programs[name] = program
        self._executables[name] = self.session.compile(
            program, context=self.current_context())
        return self._executables[name]

    def executable(self, name: str):
        exe = self._executables.get(name)
        if exe is None:
            raise KeyError(f"no program registered as {name!r}; "
                           f"known: {sorted(self._programs)}")
        return exe

    # --------------------------------------------------------------- serving
    def serve(self, requests: Iterable[Tuple[str, Mapping[str, object]]]
              ) -> List[object]:
        """Process a request stream; returns one ExecutionResult per request,
        in request order."""
        todo = list(requests)
        responses: List[Optional[object]] = [None] * len(todo)
        # group by program, preserving each request's original position
        by_program: Dict[str, List[int]] = {}
        for i, (name, _params) in enumerate(todo):
            self.executable(name)  # fail fast on unknown programs
            by_program.setdefault(name, []).append(i)

        with self.tracer.span("serve", n_requests=len(todo)):
            for name, indices in by_program.items():
                for lo in range(0, len(indices), self.batch_size):
                    chunk = indices[lo:lo + self.batch_size]
                    batch = self.serve_batch(name,
                                             [todo[i][1] for i in chunk])
                    for i, result in zip(chunk, batch.results):
                        responses[i] = result
        return responses

    def serve_batch(self, name: str,
                    params: Sequence[Mapping[str, object]]):
        """Execute ONE already-formed batch of same-program requests through
        the full serving path — site cache, compiled tier, replay capture,
        feedback/recompile — and return the BatchResult (``.results`` in
        request order). ``serve()`` forms fixed-size batches and calls this;
        a cluster's deadline-driven batch former calls it directly with the
        batches the router actually coalesced."""
        exe = self.executable(name)
        self._requests_by_program[name] = \
            self._requests_by_program.get(name, 0) + len(params)
        batch = exe.run_batch(params, site_cache=self.site_cache,
                              compiler=self.compiler)
        if self.replay_window:
            recent = self._recent.setdefault(
                name, deque(maxlen=self.replay_window))
            recent.extend(dict(p) for p in params)
        self.requests_served += len(params)
        self.batches_run += 1
        self.simulated_s += batch.simulated_s
        self.n_round_trips += batch.n_round_trips
        self._after_batch(batch)
        return batch

    def _after_batch(self, batch) -> None:
        if self.feedback is None:
            return
        stats_moved = False
        if batch.iteration_observations:
            stats_moved = self.feedback.observe_iterations(
                batch.iteration_observations)
        if batch.binding_observations:
            stats_moved |= self.feedback.observe_bindings(
                batch.binding_observations)
        drifted = self.feedback.observe(batch.observations) \
            if batch.observations else []
        if drifted:
            self.feedback.refresh(drifted)
            # the re-analyze moved the drifted tables' stats epoch, so
            # their site-cache entries are already unreachable; drop them
            # eagerly too
            self.site_cache.invalidate_tables(drifted)
            if self.compiler is not None:
                # same epoch discipline for compiled artifacts: drop the
                # lowerings (and promotion heat) of plans touching the
                # drifted tables — their replacements start cold
                self.compiler.invalidate_tables(drifted)
            self._recompile_touching(drifted)
        if stats_moved:
            # a published iteration count or binding-diversity fraction
            # moved: the serving context's fingerprint changed, so
            # recompile under the new context. The fingerprint is
            # restricted per program to its own sites — programs without
            # the moved site (and any the drift branch just recompiled
            # under this same context) hit the plan cache.
            self._recompile_for_context()

    def _guarded_swap(self, name: str, new_exe) -> None:
        """Install ``new_exe`` as the serving plan for ``name`` — unless the
        anti-regression guard, replaying the last observed bindings against
        both plans, finds the old plan actually cheaper on the workload just
        served (estimates proposed the swap; real executions veto it)."""
        old = self._executables.get(name)
        if old is None or self.feedback is None or program_fingerprint(
                new_exe.program) == program_fingerprint(old.program):
            # nothing running yet, guarding disabled, or the "new" plan is
            # the same program — no behavioral change to validate
            self._executables[name] = new_exe
            return
        if self.feedback.validate_swap(old, new_exe,
                                       list(self._recent.get(name, ()))):
            self._executables[name] = new_exe
        else:
            self.swaps_rejected += 1

    def _recompile_touching(self, tables: Sequence[str]) -> None:
        """Recompile registered programs whose table set intersects
        ``tables``; per-table stats versions keep the others' plans hot."""
        drifted = set(tables)
        ctx = self.current_context()
        for name, program in self._programs.items():
            if drifted & set(program_tables(program)):
                self._guarded_swap(name,
                                   self.session.compile(program, context=ctx))
                self.recompiles += 1

    def _recompile_for_context(self) -> None:
        """Recompile every registered program under the refreshed context;
        only those whose per-program context fingerprint actually changed
        miss the cache (and count as context recompiles)."""
        ctx = self.current_context()
        for name, program in self._programs.items():
            exe = self.session.compile(program, context=ctx)
            if not exe.from_cache:
                self.context_recompiles += 1
                self.recompiles += 1
            self._guarded_swap(name, exe)

    # --------------------------------------------------------- observability
    def explain(self, name: str) -> str:
        """EXPLAIN the named program's CURRENT serving plan, annotated with
        this runtime's observed statistics (feedback sites, site-cache
        binding diversity, compiled-tier status)."""
        return self.executable(name).explain(feedback=self.feedback,
                                             site_cache=self.site_cache,
                                             compiler=self.compiler)

    def scan(self, name: str):
        """Bad-plan signals still present in the named program's current
        serving plan (:func:`repro.obs.signals.scan_plan`)."""
        return self.executable(name).scan(feedback=self.feedback)

    def triage(self):
        """Rank every served program by traffic-weighted estimated win
        (observed drift × invocation share × signal severity) — the fleet
        view that routes re-optimization effort where the traffic is.
        Returns :class:`~repro.obs.triage.TriageRow`\\ s, highest first."""
        from ..obs.triage import triage_fleet
        return triage_fleet(self)

    def metrics_snapshot(self) -> Dict[str, object]:
        """One flat snapshot across every component registry (serving,
        session, feedback) plus the site-cache / compiler stats dicts
        ingested as gauges — diff two snapshots to see a serve cycle."""
        self.metrics.ingest(self.site_cache.stats(), prefix="site_cache_")
        if self.compiler is not None:
            self.metrics.ingest(self.compiler.metrics.snapshot(),
                                prefix="compiled_")
        parts = {"serving": self.metrics.snapshot(),
                 "session": self.session.metrics.snapshot()}
        if self.feedback is not None:
            parts["feedback"] = self.feedback.metrics.snapshot()
        return merge_snapshots(**parts)

    # ------------------------------------------------------------- telemetry
    def telemetry(self) -> Dict[str, object]:
        t = {"requests_served": self.requests_served,
             "batches_run": self.batches_run,
             "recompiles": self.recompiles,
             "context_recompiles": self.context_recompiles,
             "simulated_s": self.simulated_s,
             "round_trips": self.n_round_trips,
             "context": self.current_context().describe(),
             "programs": sorted(self._programs)}
        t["swaps_rejected"] = self.swaps_rejected
        t.update({f"session_{k}": v for k, v in self.session.telemetry.items()})
        t.update({f"site_cache_{k}": v
                  for k, v in self.site_cache.stats().items()})
        if self.feedback is not None:
            fb = self.feedback.telemetry()
            fb.pop("sites", None)  # keep the summary flat
            fb.pop("iteration_sites", None)
            fb.pop("binding_sites", None)
            fb.pop("swaps", None)
            t.update({f"feedback_{k}": v for k, v in fb.items()})
        if self.compiler is not None:
            t.update({f"compiled_{k}": v
                      for k, v in self.compiler.telemetry().items()})
        return t


def serve(session, programs: Sequence[Program],
          requests: Iterable[Tuple[str, Mapping[str, object]]],
          **runtime_kw) -> Tuple[List[object], ServingRuntime]:
    """One-call serving loop: register ``programs``, process ``requests``,
    return (responses, runtime) so callers can inspect telemetry."""
    rt = ServingRuntime(session, **runtime_kw)
    for p in programs:
        rt.register(p)
    return rt.serve(requests), rt
