"""Feedback-driven re-optimization: close the loop from observed runtimes
back into the cost model.

Cobra's premise is that the best rewrite depends on runtime parameters —
and those drift. A plan compiled when ``orders`` had 100 rows keeps being
served long after a bulk load grew it to 4000, because the optimizer only
ever consults table *statistics*, not the data. The controller watches the
serving path's true executions (``DatabaseServer.run()`` cardinalities and
wall-clock, logged by :class:`~repro.runtime.batch.BatchClientEnv`),
compares each against ``DatabaseServer.estimate()`` — the same numbers the
cost model consumed at compile time — and, when the ratio exceeds a
configurable threshold, re-analyzes exactly the drifted tables. Per-table
stats versions then invalidate exactly the plans that touch those tables;
everything else stays hot. The serving runtime recompiles the affected
executables, and the memo search may pick a different winner (e.g. P1 join
→ P2 prefetch) under the fresh statistics.

Two drift signals per query site:

  * **cardinality** (``kind="rows"``) — observed vs estimated row count;
  * **wall-clock** (``kind="wall_clock"``) — observed execution time vs the
    cost the planner would charge this query NOW (``CostModel.query_cost``).
    This catches shifts that leave row counts stable — wider payloads,
    selectivity moving between columns, server-side regressions — which the
    row signal is blind to. Wall-clock is noisier, so its threshold
    (``cost_drift_threshold``) defaults looser, and it only fires where the
    row signal did not (no double-counted events per site).

Besides the drift signals, the controller **records observed iteration
counts** per while-loop / collection-loop site (the counts the cost model
only ever estimated with ``while_iters_default`` / ``loop_iters_default``)
and **observed binding-diversity fractions** per parameterized-site group
(the serving site cache's measurement of how often bindings repeat across
a batch — the amortization the cost model's 0/1 binding-free rule cannot
see), and **publishes** both as a
:class:`~repro.core.context.StatsProfile` — the stats half of an
:class:`~repro.core.context.ExecutionContext`. A site's published value
only moves when the running mean drifts past ``iters_publish_threshold``
(ratio) / ``binding_publish_delta`` (absolute, fractions live in [0, 1]),
so context fingerprints — and hence plan-cache keys — stay stable under
observation noise, and a publish is precisely the event that triggers a
context-driven recompile in :class:`~repro.runtime.serving.ServingRuntime`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from ..api.cache import query_tables
from ..core.context import StatsProfile
from ..obs.metrics import MetricsRegistry, registry_counter
from ..stats.qerror import QErrorTracker

__all__ = ["DriftEvent", "FeedbackController"]


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """One query site whose observed behaviour left the trusted band."""

    sql: str
    tables: Tuple[str, ...]
    est_rows: float
    observed_rows: float
    ratio: float
    kind: str = "rows"          # "rows" | "wall_clock"
    est_s: float = 0.0          # wall_clock events: modeled query cost
    observed_s: float = 0.0     # wall_clock events: observed execution time

    def describe(self) -> str:
        if self.kind == "wall_clock":
            return (f"{self.sql!r}: est {self.est_s:.4g}s, observed "
                    f"{self.observed_s:.4g}s ({self.ratio:.1f}x wall-clock "
                    f"drift) -> tables {list(self.tables)}")
        return (f"{self.sql!r}: est {self.est_rows:.0f} rows, observed "
                f"{self.observed_rows:.0f} ({self.ratio:.1f}x drift) "
                f"-> tables {list(self.tables)}")


class FeedbackController:
    """Observes served executions; decides when statistics must be refreshed."""

    # registry-backed telemetry counters (see repro.obs.metrics): legacy
    # `controller.refreshes` reads/writes stay valid as views
    refreshes = registry_counter()
    observed_queries = registry_counter()
    observed_wall_s = registry_counter()
    iters_publishes = registry_counter()
    binding_publishes = registry_counter()
    swap_checks = registry_counter()
    swaps_accepted = registry_counter()
    swaps_rejected = registry_counter()
    analyzes_fired = registry_counter()
    analyzes_deduped = registry_counter()

    def __init__(self, session, drift_threshold: float = 3.0,
                 cost_drift_threshold: Optional[float] = 10.0,
                 iters_publish_threshold: float = 1.5,
                 binding_publish_delta: float = 0.15):
        if drift_threshold <= 1.0:
            raise ValueError("drift_threshold must be > 1 (a ratio)")
        if cost_drift_threshold is not None and cost_drift_threshold <= 1.0:
            raise ValueError("cost_drift_threshold must be > 1 (a ratio) "
                             "or None to disable wall-clock drift")
        if iters_publish_threshold <= 1.0:
            raise ValueError("iters_publish_threshold must be > 1 (a ratio)")
        if not 0.0 < binding_publish_delta < 1.0:
            raise ValueError("binding_publish_delta must be in (0, 1) "
                             "(an absolute delta on a fraction)")
        self.session = session
        # must exist before the registry_counter descriptors are written
        self.metrics = MetricsRegistry()
        self.drift_threshold = drift_threshold
        self.cost_drift_threshold = cost_drift_threshold
        self.iters_publish_threshold = iters_publish_threshold
        self.binding_publish_delta = binding_publish_delta
        self.events: List[DriftEvent] = []
        self.refreshes = 0
        self.observed_queries = 0
        self.observed_wall_s = 0.0
        # per-site aggregates: sql -> [count, total rows, total wall-clock]
        self._sites: Dict[str, List[float]] = {}
        # per-iteration-site aggregates: site_key -> [count, total iters]
        self._iter_sites: Dict[str, List[float]] = {}
        # published (hysteresis-stable) iteration counts per site — the
        # values a StatsProfile fingerprint is built from
        self._published_iters: Dict[str, float] = {}
        self.iters_publishes = 0
        # per-parameterized-group aggregates: group -> [n batches, Σ fraction]
        self._binding_sites: Dict[str, List[float]] = {}
        self._published_bindings: Dict[str, float] = {}
        self.binding_publishes = 0
        # anti-regression plan-swap guard (validate_swap)
        self.swap_checks = 0
        self.swaps_accepted = 0
        self.swaps_rejected = 0
        self.swap_log: List[Dict[str, object]] = []
        # per-site q-error accounting (the rows-drift ratio IS the q-error)
        self.qerrors = QErrorTracker()
        # table -> predicate columns of the sites whose q-error tripped,
        # consumed by refresh() as the targeted re-analyze column set
        self._pending_columns: Dict[str, set] = {}
        # single-fire guard: table -> data version it was last analyzed at.
        # The drift and q-error triggers may both request the same table in
        # one batch; analyze() must run once per (table, data epoch).
        self._analyzed_data_versions: Dict[str, int] = {}
        self.analyzes_fired = 0
        self.analyzes_deduped = 0

    # ------------------------------------------------------------- observing
    def _estimated_cost_s(self, q) -> float:
        """What the cost model would charge this query under CURRENT stats —
        the planner's promise the observed wall-clock is held against."""
        from ..core.cost import CostModel
        return CostModel(self.session.db, self.session.catalog).query_cost(q)

    def observe(self, observations: Sequence[Tuple[object, int, float]]
                ) -> List[str]:
        """Compare observed (query, rows, wall_s) against current estimates;
        return the sorted list of tables whose estimates have drifted."""
        db = self.session.db
        drifted = set()
        for q, n_rows, wall_s in observations:
            self.observed_queries += 1
            self.observed_wall_s += wall_s or 0.0
            sql = q.sql()
            agg = self._sites.setdefault(sql, [0, 0.0, 0.0])
            agg[0] += 1
            agg[1] += n_rows
            agg[2] += wall_s or 0.0
            est = db.estimate(q).n_rows
            # the per-site q-error: max((obs+1)/(est+1), (est+1)/(obs+1)).
            # +1 smoothing keeps empty results from dividing by zero while
            # still flagging est≈0 vs observed≫0
            ratio = self.qerrors.observe(sql, est, n_rows,
                                         tables=query_tables(q))
            if ratio > self.drift_threshold:
                tables = query_tables(q)
                drifted.update(tables)
                # targeted re-analyze: the site's estimate went bad, so
                # refresh() rebuilds histograms for exactly the columns its
                # predicates compare (scalars always recompute)
                from ..core.cost import query_pred_cols
                cols = query_pred_cols(q)
                if cols:
                    for t in tables:
                        self._pending_columns.setdefault(t, set()).update(cols)
                self.events.append(DriftEvent(
                    sql=sql, tables=tables, est_rows=est,
                    observed_rows=float(n_rows), ratio=float(ratio)))
                continue  # the row signal already flagged this site
            if self.cost_drift_threshold is None or not wall_s:
                continue
            est_s = self._estimated_cost_s(q)
            if est_s <= 0:
                continue
            cratio = max(wall_s / est_s, est_s / wall_s)
            if cratio > self.cost_drift_threshold:
                tables = query_tables(q)
                drifted.update(tables)
                self.events.append(DriftEvent(
                    sql=sql, tables=tables, est_rows=est,
                    observed_rows=float(n_rows), ratio=float(cratio),
                    kind="wall_clock", est_s=float(est_s),
                    observed_s=float(wall_s)))
        return sorted(drifted)

    def observe_iterations(self, observations: Sequence[Tuple[str, int]]
                           ) -> bool:
        """Fold (site_key, iteration_count) observations — the interpreter's
        per-while/per-collection-loop records — into the per-site running
        means, and re-publish any site whose mean left the hysteresis band
        around its published value. Returns True when at least one site's
        published value moved (the caller's recompile trigger)."""
        changed = False
        for site, count in observations:
            agg = self._iter_sites.setdefault(site, [0, 0.0])
            agg[0] += 1
            agg[1] += count
            mean = agg[1] / agg[0]
            published = self._published_iters.get(site)
            if published is None:
                self._published_iters[site] = mean
                self.iters_publishes += 1
                changed = True
                continue
            ratio = max((mean + 1.0) / (published + 1.0),
                        (published + 1.0) / (mean + 1.0))
            if ratio > self.iters_publish_threshold:
                self._published_iters[site] = mean
                self.iters_publishes += 1
                changed = True
        return changed

    def observe_bindings(self, observations: Sequence[Tuple[str, int, int]]
                         ) -> bool:
        """Fold per-batch (group_site, total_lookups, distinct_bindings)
        observations — the site cache's binding-diversity measurements —
        into per-group running means of the distinct fraction, and
        re-publish any group whose mean left the hysteresis band
        (``binding_publish_delta``, absolute) around its published value.
        Returns True when at least one published fraction moved (the
        caller's recompile trigger)."""
        changed = False
        for site, total, distinct in observations:
            if total <= 0:
                continue
            frac = min(1.0, distinct / total)
            agg = self._binding_sites.setdefault(site, [0, 0.0])
            agg[0] += 1
            agg[1] += frac
            mean = agg[1] / agg[0]
            published = self._published_bindings.get(site)
            if published is None or \
                    abs(mean - published) > self.binding_publish_delta:
                self._published_bindings[site] = mean
                self.binding_publishes += 1
                changed = True
        return changed

    def stats_profile(self) -> StatsProfile:
        """The published iteration counts and binding-diversity fractions
        (plus per-query-site mean wall-clock) as the StatsProfile an
        ExecutionContext carries into the cost model. Published — not raw —
        values keep context fingerprints, and with them plan-cache keys,
        stable between publish events."""
        wall = {sql: agg[2] / max(agg[0], 1)
                for sql, agg in self._sites.items() if agg[2]}
        return StatsProfile.of(iters=dict(self._published_iters),
                               site_wall_s=wall,
                               bindings=dict(self._published_bindings),
                               qerrors=self.qerrors.latest())

    # ----------------------------------------------------- plan-swap guarding
    def _replay_cost_s(self, program, bindings) -> float:
        """Simulated cost of ``program`` over ``bindings`` replayed BATCHED
        (one shared env, like the serving path runs it): a serving-context
        plan's win comes from cross-invocation amortization — prefetch and
        site-cache reuse pay off across a batch, not per invocation — so a
        one-shot replay would systematically mis-rank it."""
        from ..core.regions import Interpreter
        from .batch import BatchClientEnv
        env = BatchClientEnv(self.session.db, self.session.catalog.network,
                             c_z=self.session.catalog.c_z)
        interp = Interpreter(env, "fast")
        for p in bindings:
            interp.run(program, dict(p) or None)
        return env.clock

    def validate_swap(self, old_exe, new_exe, bindings) -> bool:
        """Anti-regression guard: before a drift-triggered recompile replaces
        a running plan, replay the last observed bindings against the old
        and the new plan and keep the OLD one unless the new is actually at
        least as cheap on the workload just served. Cost estimates triggered
        the recompile; real executions decide the swap.

        Accepts without replay when there is nothing to replay against, or
        when either program mutates tables (replaying writes against the
        live database would corrupt it). Returns True to swap."""
        from .batch import program_has_updates
        self.swap_checks += 1
        bindings = list(bindings)
        old_s = new_s = None
        if not bindings or program_has_updates(old_exe.program) \
                or program_has_updates(new_exe.program):
            accept = True
        else:
            old_s = self._replay_cost_s(old_exe.program, bindings)
            new_s = self._replay_cost_s(new_exe.program, bindings)
            # epsilon-tolerant: a bit-identical replan must never be
            # rejected over float noise
            accept = new_s <= old_s * (1.0 + 1e-6)
        if accept:
            self.swaps_accepted += 1
            self.session.plan_swaps_accepted = getattr(
                self.session, "plan_swaps_accepted", 0) + 1
        else:
            self.swaps_rejected += 1
            self.session.plan_swaps_rejected = getattr(
                self.session, "plan_swaps_rejected", 0) + 1
        outcome = {
            "program": getattr(old_exe.source, "name", "?"),
            "accepted": accept,
            "replayed": len(bindings) if old_s is not None else 0,
            "old_replay_s": old_s,
            "new_replay_s": new_s,
        }
        self.swap_log.append(outcome)
        # the judged executable carries its own verdict (PlanReport's
        # swap_checked/swap_accepted/swap_replayed fields read it)
        try:
            new_exe.swap_outcome = {"checked": True, **outcome}
        except AttributeError:
            pass  # stub executables in tests need not carry the field
        tracer = getattr(self.session, "tracer", None)
        if tracer is not None and tracer.enabled:
            tracer.event("swap-verdict", program=outcome["program"],
                         accepted=accept, replayed=outcome["replayed"])
        return accept

    # -------------------------------------------------------------- reacting
    def refresh(self, tables: Sequence[str]) -> None:
        """Re-analyze the drifted tables only: their stats versions bump, so
        exactly the plans touching them fall out of the caches.

        Targeted and deduplicated: a table whose drift came through the
        q-error path re-analyzes only the pending predicate columns'
        histograms (scalars always recompute), and a table already analyzed
        at its current DATA version is skipped entirely — the drift and
        q-error triggers may both name one table in a batch, but analyze()
        single-fires per (table, data epoch) (``analyzes_deduped`` counts
        the suppressions)."""
        if not tables:
            return
        db = self.session.db
        fired = False
        for t in tables:
            ver = db.data_version(t)
            if self._analyzed_data_versions.get(t) == ver:
                self.analyzes_deduped += 1
                continue
            cols = self._pending_columns.pop(t, None)
            db.analyze(t, columns=tuple(sorted(cols)) if cols else None)
            self._analyzed_data_versions[t] = ver
            self.analyzes_fired += 1
            fired = True
        if fired:
            self.refreshes += 1

    # ------------------------------------------------------------- telemetry
    def telemetry(self) -> Dict[str, object]:
        return {
            "observed_queries": self.observed_queries,
            "observed_wall_s": self.observed_wall_s,
            "drift_events": len(self.events),
            "drift_events_wall_clock": sum(
                1 for e in self.events if e.kind == "wall_clock"),
            "stats_refreshes": self.refreshes,
            "analyzes_fired": self.analyzes_fired,
            "analyzes_deduped": self.analyzes_deduped,
            "qerror_sites": {sql: {"n": s.n, "mean": s.mean,
                                   "worst": s.worst, "last": s.last}
                             for sql, s in self.qerrors.sites().items()},
            "iteration_sites": {site: {"n": int(n), "avg_iters": tot / max(n, 1),
                                       "published": self._published_iters.get(site)}
                                for site, (n, tot) in self._iter_sites.items()},
            "iters_publishes": self.iters_publishes,
            "binding_sites": {site: {"n": int(n), "avg_fraction": tot / max(n, 1),
                                     "published": self._published_bindings.get(site)}
                              for site, (n, tot) in self._binding_sites.items()},
            "binding_publishes": self.binding_publishes,
            "swap_checks": self.swap_checks,
            "swaps_accepted": self.swaps_accepted,
            "swaps_rejected": self.swaps_rejected,
            "swaps": list(self.swap_log),
            "sites": {sql: {"n": int(n), "avg_rows": rows / max(n, 1),
                            "wall_s": wall}
                      for sql, (n, rows, wall) in self._sites.items()},
        }
