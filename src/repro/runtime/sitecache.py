"""Serving-level shared site cache: cross-batch, cross-program result reuse.

The per-batch site cache in :mod:`repro.runtime.batch` dies with its batch:
the second batch of an identical workload re-fetches every query site, and
two programs sharing a site (multi-query optimization at the serving layer)
never share a fetch. The ``SiteCache`` lifts that cache to serving scope —
one instance owned by :class:`~repro.runtime.serving.ServingRuntime` and
threaded into every ``run_batch`` — so an identical site is fetched from
the server ONCE PER STATS EPOCH instead of once per batch.

**Keys are self-invalidating.** An entry is addressed by

    (query-tree key, normalized full-content binding key, epoch)

where the *epoch* is ``DatabaseServer.site_epoch(tables)`` — the (stats
version, data version) pair of every base table the query scans. Any
``analyze()`` bumps the stats version; any write (``add_table``,
``replace_table``, interpreter ``UPDATE``) bumps the data version; either
moves the epoch, so a lookup after the change simply misses and re-fetches.
A cached result can therefore never be served over rows (or under
statistics) it was not computed from — cached executions stay bit-identical
to uncached ones by construction, even when an ``analyze()`` or a table
write lands between (or inside) batches. ``invalidate_tables`` additionally
drops dead entries eagerly (memory hygiene; correctness never depends on
it), and an optional TTL expires entries whose epoch never moves.

**Binding-diversity observation.** Every lookup at a parameterized site is
also an observation: the cache tracks, per exact site
(:func:`~repro.core.context.query_site_key`) and per table group
(:func:`~repro.core.context.param_group_key`), how many lookups it saw and
how many DISTINCT bindings among them. The distinct fraction d is exactly
the amortization the cost model needs for parameterized sites — d·B of a
batch's B invocations pay a server fetch, the rest are local hits — and is
published (with hysteresis) by
:meth:`~repro.runtime.feedback.FeedbackController.observe_bindings` into
the serving :class:`~repro.core.context.ExecutionContext`, where
:meth:`~repro.core.cost.CostModel.param_site_amortization` consumes it.

Entries carry the *era* (batch sequence number) they were inserted in, so
``run_batch`` can tell in-batch reuse (``site_hits``) from cross-batch /
cross-program sharing (``shared_site_hits``) in its telemetry.

**Oversize spilling.** A result above ``entry_max_bytes`` would evict most
of the working set for at most one reuse, so the byte-budgeted cache never
admits it to memory. With a ``spill_dir`` configured, such results spill to
a content-addressed disk tier (the same addressing scheme as the plan
store, :func:`~repro.runtime.store.content_address`) instead of being
dropped: a later lookup at the same epoch-keyed key reloads the pickled
result from disk (``spill_hits``), still saving the server round trip. The
spill index lives in memory keyed identically to resident entries, so
epoch keys, TTL, and ``invalidate_tables`` govern spilled results exactly
like resident ones — a spilled result can never be served over rows it
was not computed from. Without a ``spill_dir`` the pre-existing bypass
behavior (count and drop) is unchanged.
"""

from __future__ import annotations

import os
import pickle
import tempfile
import time
from collections import OrderedDict
from typing import Dict, Optional, Tuple

from ..core.context import param_group_key, query_site_key
from ..relational.algebra import Query

__all__ = ["SiteCache", "Uncacheable", "approx_result_bytes", "freeze_value",
           "param_key"]

# a site's distinct-binding tracking stops growing here; at the cap the
# observed fraction is frozen (the estimate up to that point) instead of
# decaying toward 0 as total keeps climbing
_MAX_DISTINCT_TRACKED = 4096


class Uncacheable(Exception):
    """A query binding with no faithful hashable identity."""


def approx_result_bytes(value) -> int:
    """Approximate resident size of one cached result, in bytes.

    Tables report their wire size (nrows x row_bytes — the same number the
    cost model charges for fetching them, so a byte budget is commensurate
    with transfer cost); arrays their buffer size; everything else a cheap
    structural estimate. Exactness is NOT required — the budget bounds
    memory approximately, correctness never depends on it."""
    wb = getattr(value, "wire_bytes", None)
    if wb is not None:
        return int(wb() if callable(wb) else wb)
    nb = getattr(value, "nbytes", None)
    if nb is not None:
        return int(nb)
    if isinstance(value, (str, bytes, bytearray)):
        return len(value)
    if isinstance(value, (list, tuple, set, frozenset)):
        return 56 + 16 * len(value)
    return 64


def freeze_value(v):
    """Hashable FULL-CONTENT identity of one binding value."""
    if isinstance(v, (int, float, str, bool, bytes)) or v is None:
        return v
    if isinstance(v, (tuple, list)):
        return tuple(freeze_value(x) for x in v)
    item = getattr(v, "item", None)
    if item is not None and getattr(v, "ndim", None) == 0:
        return item()                      # numpy scalar
    tobytes = getattr(v, "tobytes", None)
    if tobytes is not None:
        return (getattr(v, "shape", None), str(getattr(v, "dtype", "")),
                tobytes())                 # full-content array identity
    raise Uncacheable(type(v).__name__)


def param_key(params) -> Tuple:
    """Hashable FULL-CONTENT identity of a parameter binding. Raises
    :class:`Uncacheable` for values it cannot represent faithfully — the
    caller then bypasses the cache rather than risk serving a stale result
    for a colliding key."""
    if not params:
        return ()
    return tuple((k, freeze_value(params[k])) for k in sorted(params))


class _Entry:
    __slots__ = ("value", "stamp", "era", "tables", "nbytes")

    def __init__(self, value, stamp: float, era: int,
                 tables: Tuple[str, ...], nbytes: int):
        self.value = value
        self.stamp = stamp
        self.era = era
        self.tables = tables
        self.nbytes = nbytes


class _SpillEntry:
    """Index record for one oversize result spilled to disk: everything a
    resident entry carries except the value itself, which lives at
    ``path``."""

    __slots__ = ("path", "stamp", "era", "tables", "nbytes")

    def __init__(self, path: str, stamp: float, era: int,
                 tables: Tuple[str, ...], nbytes: int):
        self.path = path
        self.stamp = stamp
        self.era = era
        self.tables = tables
        self.nbytes = nbytes


def _spill_encode(value):
    """Picklable form of a cached result. Tables decompose to host numpy
    columns (device arrays round-trip through host anyway; this keeps the
    on-disk format jax-version-independent)."""
    from ..relational.table import Table
    if isinstance(value, Table):
        import numpy as np
        return ("table", value.name, value.schema,
                {n: np.asarray(c) for n, c in value.columns.items()})
    return ("pickle", value)


def _spill_decode(obj):
    if obj[0] == "table":
        from ..relational.table import Table
        _, name, schema, cols = obj
        return Table(name, schema, cols)
    return obj[1]


class _SiteStats:
    """Per-site binding-diversity aggregate (one observation per lookup).

    Bindings are tracked by Python hash, not by payload — diversity needs a
    distinct COUNT, so retaining full frozen bindings (which for array
    parameters embed the whole ``tobytes()``) would pin dead payload for
    the cache's lifetime."""

    __slots__ = ("total", "distinct", "frozen_fraction")

    def __init__(self):
        self.total = 0
        self.distinct: set = set()
        self.frozen_fraction: float = -1.0   # <0: still tracking live

    def observe(self, pkey) -> None:
        self.total += 1
        if self.frozen_fraction < 0:
            self.distinct.add(hash(pkey))
            if len(self.distinct) >= _MAX_DISTINCT_TRACKED:
                # freeze the estimate at saturation: past the cap we can no
                # longer count distinct values, and letting total keep
                # dividing would make a fully diverse site read as ~0
                self.frozen_fraction = len(self.distinct) / self.total
                self.distinct.clear()

    @property
    def n_distinct(self) -> int:
        if self.frozen_fraction >= 0:
            return _MAX_DISTINCT_TRACKED
        return len(self.distinct)

    @property
    def fraction(self) -> float:
        if self.frozen_fraction >= 0:
            return self.frozen_fraction
        return len(self.distinct) / self.total if self.total else 0.0


class SiteCache:
    """Serving-scoped, epoch-keyed query-result cache with TTL."""

    def __init__(self, ttl_s: Optional[float] = None,
                 max_entries: int = 4096, clock=time.monotonic,
                 max_bytes: Optional[int] = None,
                 entry_max_bytes: Optional[int] = None,
                 spill_dir: Optional[str] = None):
        if ttl_s is not None and ttl_s <= 0:
            raise ValueError("ttl_s must be > 0 (or None: no TTL)")
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError("max_bytes must be >= 1 (or None: no byte bound)")
        if entry_max_bytes is not None and entry_max_bytes < 1:
            raise ValueError("entry_max_bytes must be >= 1 (or None)")
        self.ttl_s = ttl_s
        self.max_entries = max_entries
        # approximate resident-byte budget (None = entry count only); a
        # single result above entry_max_bytes (default: a quarter of the
        # budget) is never cached at all — one oversize value would
        # otherwise evict the whole working set for a single reuse
        self.max_bytes = max_bytes
        if entry_max_bytes is None and max_bytes is not None:
            entry_max_bytes = max(1, max_bytes // 4)
        self.entry_max_bytes = entry_max_bytes
        # oversize disk tier: None keeps the bypass behavior (drop + count)
        self.spill_dir = spill_dir
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
        self._spilled: "OrderedDict[Tuple, _SpillEntry]" = OrderedDict()
        self.bytes_used = 0
        self._clock = clock
        self._entries: "OrderedDict[Tuple, _Entry]" = OrderedDict()
        self.era = 0                    # batch sequence number (new_era())
        # telemetry
        self.hits = 0
        self.shared_hits = 0            # hit on an entry from an earlier era
        self.misses = 0
        self.expirations = 0
        self.evictions = 0
        self.invalidations = 0
        self.oversize_bypasses = 0
        self.spills = 0                 # oversize results written to disk
        self.spill_hits = 0             # lookups served from the disk tier
        # binding-diversity observation: exact site (telemetry) and table
        # group (what the feedback controller publishes into the context)
        self._site_stats: Dict[str, _SiteStats] = {}
        self._group_stats: Dict[str, _SiteStats] = {}
        self._group_tables: Dict[str, Tuple[str, ...]] = {}

    # --------------------------------------------------------------- keying
    @staticmethod
    def site_key(q: Query, pkey: Tuple, epoch: Tuple, origin: int = 0) -> Tuple:
        """``origin`` is the DatabaseServer's ``instance_token``: one cache
        serving executables over DIFFERENT databases must never collide on
        identically-named tables (epochs are per-server counters that start
        at the same values everywhere)."""
        return (origin, q.key(), pkey, epoch)

    def new_era(self) -> int:
        """Mark a batch boundary: hits on entries inserted before the
        current era count as cross-batch (shared) reuse."""
        self.era += 1
        return self.era

    # -------------------------------------------------------------- get/put
    def lookup(self, key: Tuple) -> Optional[Tuple[object, bool]]:
        """(result, crossed-era?) for ``key``, or None. An entry past its
        TTL is expired (a miss); a hit refreshes LRU recency. The boolean is
        True when the entry was inserted in an earlier era (a cross-batch /
        cross-program share)."""
        entry = self._entries.get(key)
        if entry is None:
            return self._lookup_spilled(key)
        if self.ttl_s is not None and self._clock() - entry.stamp > self.ttl_s:
            del self._entries[key]
            self.bytes_used -= entry.nbytes
            self.expirations += 1
            self.misses += 1
            return None
        self.hits += 1
        cross = entry.era < self.era
        if cross:
            self.shared_hits += 1
        self._entries.move_to_end(key)
        return entry.value, cross

    def get(self, key: Tuple):
        """The cached result for ``key``, or None (see :meth:`lookup`)."""
        found = self.lookup(key)
        return None if found is None else found[0]

    def _lookup_spilled(self, key: Tuple) -> Optional[Tuple[object, bool]]:
        """Disk-tier fallthrough for a key absent from memory. Same TTL and
        era semantics as resident entries; an unreadable spill file is a
        plain miss (the value is a cache, never the source of truth)."""
        sp = self._spilled.get(key)
        if sp is None:
            self.misses += 1
            return None
        if self.ttl_s is not None and self._clock() - sp.stamp > self.ttl_s:
            self._drop_spilled(key)
            self.expirations += 1
            self.misses += 1
            return None
        try:
            with open(sp.path, "rb") as f:
                value = _spill_decode(pickle.load(f))
        except (OSError, pickle.PickleError, EOFError):
            self._drop_spilled(key)
            self.misses += 1
            return None
        self.hits += 1
        self.spill_hits += 1
        cross = sp.era < self.era
        if cross:
            self.shared_hits += 1
        return value, cross

    def _drop_spilled(self, key: Tuple) -> None:
        sp = self._spilled.pop(key, None)
        if sp is not None:
            try:
                os.unlink(sp.path)
            except OSError:
                pass

    def _spill(self, key: Tuple, value, tables: Tuple[str, ...],
               nbytes: int) -> None:
        from .store import content_address
        path = os.path.join(self.spill_dir, content_address(key) + ".pkl")
        tmp = None
        try:
            fd, tmp = tempfile.mkstemp(dir=self.spill_dir, suffix=".tmp")
            with os.fdopen(fd, "wb") as f:
                pickle.dump(_spill_encode(value), f,
                            protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, path)
        except Exception:
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
            self.oversize_bypasses += 1   # spill failed: behave as a bypass
            return
        self._spilled[key] = _SpillEntry(path, self._clock(), self.era,
                                         tuple(tables), nbytes)
        self.spills += 1

    def put(self, key: Tuple, value, tables: Tuple[str, ...]) -> None:
        nbytes = approx_result_bytes(value) \
            if (self.max_bytes is not None
                or self.entry_max_bytes is not None
                or self.spill_dir is not None) else 0
        if self.entry_max_bytes is not None and nbytes > self.entry_max_bytes:
            if self.spill_dir is not None:
                # too big for memory, still worth a round trip: disk tier
                self._spill(key, value, tables, nbytes)
                return
            # bypass: caching this result would evict much of the working
            # set for at most one reuse; skipping it only costs a re-fetch
            self.oversize_bypasses += 1
            return
        old = self._entries.get(key)
        if old is not None:
            self.bytes_used -= old.nbytes
        self._entries[key] = _Entry(value, self._clock(), self.era,
                                    tuple(tables), nbytes)
        self._entries.move_to_end(key)
        self.bytes_used += nbytes
        while len(self._entries) > self.max_entries or (
                self.max_bytes is not None
                and self.bytes_used > self.max_bytes and self._entries):
            _, dropped = self._entries.popitem(last=False)
            self.bytes_used -= dropped.nbytes
            self.evictions += 1

    # --------------------------------------------------------- invalidation
    def invalidate_tables(self, tables) -> int:
        """Eagerly drop entries touching any of ``tables``. Epoch keys
        already make such entries unreachable (their epoch moved); this
        frees the memory and keeps telemetry honest."""
        drop = set(tables)
        stale = [k for k, e in self._entries.items() if drop & set(e.tables)]
        for k in stale:
            self.bytes_used -= self._entries[k].nbytes
            del self._entries[k]
        stale_spilled = [k for k, e in self._spilled.items()
                         if drop & set(e.tables)]
        for k in stale_spilled:
            self._drop_spilled(k)
        self.invalidations += len(stale) + len(stale_spilled)
        return len(stale) + len(stale_spilled)

    def clear(self) -> None:
        self._entries.clear()
        for k in list(self._spilled):
            self._drop_spilled(k)
        self.bytes_used = 0

    def __len__(self) -> int:
        return len(self._entries)

    # ---------------------------------------------- binding-diversity stats
    def observe_binding(self, q: Query, tables: Tuple[str, ...],
                        pkey: Tuple) -> None:
        """Record one lookup at a PARAMETERIZED site (``pkey`` non-empty):
        feeds the per-site and per-group distinct-binding fractions."""
        self._site_stats.setdefault(query_site_key(q),
                                    _SiteStats()).observe(pkey)
        from ..core.context import param_prov_key
        from ..core.cost import query_param_cols
        for gkey in (param_group_key(tables),
                     param_prov_key(tables, query_param_cols(q))):
            self._group_tables.setdefault(gkey, tuple(sorted(tables)))
            self._group_stats.setdefault(gkey, _SiteStats()).observe(pkey)

    def binding_fractions(self) -> Dict[str, float]:
        """Distinct-binding fraction per table group (``qdiv:…`` keys) and
        per provenance group (``qprov:…`` keys) — the publishable
        granularities (exact query trees change under rewriting; table
        sets and param-compared columns survive it)."""
        return {g: s.fraction for g, s in self._group_stats.items()}

    def site_binding_stats(self) -> Dict[str, Dict[str, float]]:
        """Per exact site (``qsite:…``): lookups, distinct bindings,
        fraction. Telemetry granularity."""
        return {site: {"lookups": s.total, "distinct": s.n_distinct,
                       "fraction": s.fraction}
                for site, s in self._site_stats.items()}

    def group_tables(self, gkey: str) -> Tuple[str, ...]:
        return self._group_tables.get(gkey, ())

    # ------------------------------------------------------------ telemetry
    def stats(self) -> Dict[str, object]:
        lookups = self.hits + self.misses
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "shared_hits": self.shared_hits,
            "misses": self.misses,
            "hit_rate": self.hits / lookups if lookups else 0.0,
            "expirations": self.expirations,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "bytes_used": self.bytes_used,
            "max_bytes": self.max_bytes,
            "oversize_bypasses": self.oversize_bypasses,
            "spills": self.spills,
            "spill_hits": self.spill_hits,
            "spilled_entries": len(self._spilled),
            "param_sites": len(self._site_stats),
        }

    def describe(self) -> str:
        s = self.stats()
        return (f"SiteCache: {s['entries']} entries, "
                f"{s['hits']} hit(s) ({s['shared_hits']} cross-batch), "
                f"{s['misses']} miss(es), "
                f"{s['invalidations']} invalidation(s)")
