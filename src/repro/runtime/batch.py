"""Batched execution: amortize server round-trips across parameter bindings.

``Executable.run(**params)`` opens a fresh :class:`ClientEnv` per
invocation — every query site pays its round trip every time. The paper's
batching transformation amortizes ``C_NRT`` by combining many parameter
bindings into one server interaction; this module applies the same idea at
the serving layer:

  * **site cache** — one :class:`BatchClientEnv` serves the whole batch;
    an ``executeQuery`` site with identical bindings is fetched from the
    server ONCE per batch, later invocations reuse the local result for a
    C_Z charge. The cache is a :class:`~repro.runtime.sitecache.SiteCache`:
    epoch-keyed (per-table stats + data versions), so an ``analyze()`` or a
    write landing mid-stream makes affected entries miss instead of serving
    stale rows. Pass a serving-scoped instance (``site_cache=``) and the
    sharing extends ACROSS batches and programs — an identical site is
    fetched once per stats epoch, not once per batch;
  * **bulk navigation fetch** — the vectorized interpreter's ORM-navigation
    path (``core.vectorize._vec_nav``) asks this env to fetch ALL missing
    keys of a navigation site in one combined round trip
    (``WHERE key IN (...)``-style) instead of one point query per key;
  * **write-set-aware mutating programs** — a program containing ``UPDATE``
    statements still executes each invocation on an isolated environment
    (sharing fetched state across invocations is unsound once the data the
    program WRITES mutates mid-batch), but sites over tables the program
    never updates (``program_write_tables``) keep site-cache sharing: the
    read-only part of a mutating workload amortizes like any other;
  * **observation log** — every true server execution records (query,
    observed cardinality, wall-clock), and every parameterized lookup
    records its binding, for the feedback controller (drift detection and
    binding-diversity amortization).

Outputs are bit-for-bit identical to per-invocation ``run()``: the caches
only avoid refetching data proven unchanged (epoch keys), never change
what is computed.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

from ..core.context import param_group_key, param_prov_key
from ..core.regions import (BasicBlock, Interpreter, Program, Region,
                            UpdateRow)
from ..obs.trace import NOOP_TRACER
from ..relational.algebra import scan_tables
from ..relational.database import ClientEnv, NetworkProfile
from .sitecache import SiteCache, Uncacheable, param_key

__all__ = ["BatchClientEnv", "BatchResult", "run_batch",
           "program_has_updates"]

# back-compat aliases (the canonical definitions moved to runtime.sitecache)
_Uncacheable = Uncacheable
_param_key = param_key


def program_has_updates(program: Program) -> bool:
    found = [False]

    def walk(r: Region):
        if isinstance(r, BasicBlock) and isinstance(r.stmt, UpdateRow):
            found[0] = True
        for c in r.children():
            walk(c)

    walk(program.body)
    return found[0]


# distinct sentinel per uncacheable binding: it counts as its own distinct
# value in the diversity statistics (conservative: looks fully diverse)
_unique_token = itertools.count()


class BatchClientEnv(ClientEnv):
    """A client environment sharing a :class:`SiteCache` — per batch by
    default, serving-scoped when one is passed in."""

    def __init__(self, db, network: NetworkProfile, c_z: float = 30e-9,
                 orm_cache: bool = True,
                 site_cache: Optional[SiteCache] = None,
                 write_set: Sequence[str] = (),
                 tracer=None):
        super().__init__(db, network, c_z=c_z, orm_cache=orm_cache)
        self.site_cache = site_cache if site_cache is not None else SiteCache()
        self.write_set: Set[str] = set(write_set)
        self.tracer = tracer if tracer is not None else NOOP_TRACER
        # id(query) -> [query, hits, shared_hits, fetches, fetched_rows];
        # flushed as ONE aggregated span event per site per batch
        # (flush_site_events) so per-invocation tracing cost stays at a
        # dict update, not a span allocation
        self._site_log: Dict[int, list] = {}
        self.site_hits = 0          # in-batch reuse
        self.shared_site_hits = 0   # cross-batch / cross-program reuse
        # (query, observed rows, observed wall-clock) per true execution —
        # consumed by runtime.feedback.FeedbackController
        self.observations: List[Tuple[object, int, float]] = []
        # per-batch binding-diversity log: group key -> set of binding keys
        # (+ total lookups) at PARAMETERIZED sites, merged by run_batch
        self.binding_sets: Dict[str, set] = {}
        self.binding_totals: Dict[str, int] = {}

    def _site_rec(self, q) -> list:
        rec = self._site_log.get(id(q))
        if rec is None:
            rec = self._site_log[id(q)] = [q, 0, 0, 0, 0]
        return rec

    def flush_site_events(self) -> None:
        """Emit one aggregated ``site-hit``/``site-fetch`` event per query
        site touched this batch (called by ``run_batch`` inside its batch
        span while the tracer is enabled)."""
        for q, hits, shared, fetches, rows in self._site_log.values():
            sql = q.sql()
            if fetches:
                self.tracer.event("site-fetch", sim=self.clock, sql=sql,
                                  n=fetches, rows=rows)
            if hits or shared:
                self.tracer.event("site-hit", sim=self.clock, sql=sql,
                                  n=hits + shared, shared=shared)
        self._site_log.clear()

    # ----------------------------------------------------------------- exec
    def _fetch(self, q, params):
        t = super().execute_query(q, params)
        self.observations.append((q, t.nrows, self.query_log[-1][2]))
        return t

    def _observe_binding(self, q, tables, pkey) -> None:
        self.site_cache.observe_binding(q, tables, pkey)
        from ..core.cost import query_param_cols
        # hash, not payload: diversity needs a distinct COUNT, and frozen
        # array bindings embed their full tobytes(). Record under both the
        # coarse per-table group and the finer provenance key (tables +
        # param-compared columns) so differently-diverse sites over one
        # table publish separate diversity fractions.
        h = hash(pkey)
        for gkey in (param_group_key(tables),
                     param_prov_key(tables, query_param_cols(q))):
            self.binding_sets.setdefault(gkey, set()).add(h)
            self.binding_totals[gkey] = self.binding_totals.get(gkey, 0) + 1

    def execute_query(self, q, params: Optional[Mapping[str, object]] = None):
        tables = scan_tables(q)
        if self.write_set and self.write_set & set(tables):
            # a site over tables this program UPDATES: never cached — each
            # invocation must observe its own (and earlier) writes. No
            # diversity observation either: publishing an amortization the
            # runtime can never deliver here would mis-price plans.
            return self._fetch(q, params)
        try:
            pkey = param_key(params)
        except Uncacheable:
            # no faithful key: bypass the cache, count the binding as its
            # own distinct value (conservative diversity)
            if params:
                self._observe_binding(
                    q, tables, ("__uncacheable__", next(_unique_token)))
            return self._fetch(q, params)
        if pkey:
            self._observe_binding(q, tables, pkey)
        cache = self.site_cache
        key = cache.site_key(q, pkey, self.db.site_epoch(tables),
                             origin=self.db.instance_token)
        found = cache.lookup(key)
        if found is not None:
            # local reuse: the result is already client-side; one C_Z to
            # hand the cursor over, no server round trip
            result, cross = found
            if cross:
                self.shared_site_hits += 1
            else:
                self.site_hits += 1
            self.charge_statement()
            if self.tracer.enabled:
                self._site_rec(q)[2 if cross else 1] += 1
            return result
        t = self._fetch(q, params)
        if self.tracer.enabled:
            rec = self._site_rec(q)
            rec[3] += 1
            rec[4] += t.nrows
        cache.put(key, t, tables)
        return t

    def bulk_nav_charge(self, table, n_misses: int) -> None:
        """Charge ONE combined fetch for all missing keys of a navigation
        site (called from ``core.vectorize._vec_nav``): a single round trip
        whose server time is ``n_misses`` index probes and whose payload is
        ``n_misses`` rows — instead of ``n_misses`` separate point queries."""
        m = self.db.model
        self._charge_query(
            n_misses, table.row_bytes,
            m.startup_s + m.index_lookup_s,
            m.startup_s + n_misses * m.index_lookup_s
            + n_misses / m.emit_rows_per_s)


@dataclasses.dataclass
class BatchResult(Sequence):
    """Per-invocation results plus batch-level telemetry."""

    results: List            # ExecutionResult per parameter set, in order
    simulated_s: float       # total simulated clock for the whole batch
    n_queries: int
    n_round_trips: int
    batched: bool            # False -> sequential fallback (program updates)
    site_hits: int = 0
    shared_site_hits: int = 0  # served by an EARLIER batch's / program's fetch
    observations: List = dataclasses.field(default_factory=list)
    # (site_key, iteration_count) per executed while / collection loop —
    # consumed by FeedbackController.observe_iterations into a StatsProfile
    iteration_observations: List = dataclasses.field(default_factory=list)
    # (group_site_key, total_lookups, distinct_bindings) per parameterized
    # site group — consumed by FeedbackController.observe_bindings
    binding_observations: List = dataclasses.field(default_factory=list)
    # which execution tier served the batch: "interpreter" or "compiled"
    # (the splicing interpreter with kernel-backed columnar loops)
    tier: str = "interpreter"

    def __getitem__(self, i):
        return self.results[i]

    def __len__(self):
        return len(self.results)

    @property
    def outputs(self) -> List[Dict[str, object]]:
        return [r.outputs for r in self.results]

    def describe(self) -> str:
        kind = "batched" if self.batched else "sequential-fallback"
        return (f"{len(self.results)} invocation(s) [{kind}]: "
                f"{self.simulated_s:.4g}s simulated, "
                f"{self.n_round_trips} round trip(s), "
                f"{self.site_hits} site reuse(s), "
                f"{self.shared_site_hits} shared site reuse(s)")


def _merge_binding_logs(envs) -> List[Tuple[str, int, int]]:
    sets: Dict[str, set] = {}
    totals: Dict[str, int] = {}
    for env in envs:
        for g, s in env.binding_sets.items():
            sets.setdefault(g, set()).update(s)
        for g, n in env.binding_totals.items():
            totals[g] = totals.get(g, 0) + n
    return [(g, totals[g], len(sets[g])) for g in sorted(totals)]


def _input_diversity_fallback(binding_obs, source_program,
                              param_sets) -> List[Tuple[str, int, int]]:
    """Attribute the batch's PROGRAM-INPUT diversity to parameterized site
    groups the running plan never executed (e.g. the prefetch form of W_E
    executes zero parameterized queries).

    Sound only for NON-mutating programs (the caller's batched branch): a
    read-only program is a pure function of its inputs, so identical
    inputs imply identical binding sequences at every site — the input
    distinct fraction UPPER-bounds any site's; distinct inputs may still
    repeat bindings, so this only ever over-estimates diversity (the
    conservative direction: less amortization). A mutating program's
    bindings can depend on rows earlier invocations wrote, so the
    sequential branch never applies this fallback. Cache-level
    observations, when present for a group, take precedence."""
    from ..api.cache import program_param_prov_sites, program_param_sites
    groups = [g for g in program_param_sites(source_program)
              if g.startswith("qdiv:")]
    groups += list(program_param_prov_sites(source_program))
    if not groups or not param_sets:
        return binding_obs
    seen = {g for g, _, _ in binding_obs}
    missing = [g for g in groups if g not in seen]
    if not missing:
        return binding_obs
    distinct = set()
    for p in param_sets:
        try:
            distinct.add(param_key(p))
        except Uncacheable:
            distinct.add(("__uncacheable__", next(_unique_token)))
    out = list(binding_obs)
    for g in missing:
        out.append((g, len(param_sets), len(distinct)))
    return out


def _resolve_lowered(program: Program, executable, tier: str, compiler,
                     n_invocations: int):
    """The :class:`~repro.compiled.lower.LoweredProgram` to run this batch
    on, or None for the interpreter tier.

    ``tier="compiled"`` forces a lowering (memoized on the executable when
    one is given); ``"interpreter"`` forces it off; ``"auto"`` (default)
    defers to the :class:`~repro.compiled.manager.CompileManager` — no
    compiler means no promotion, matching pre-compiled-tier behavior."""
    if tier not in ("auto", "interpreter", "compiled"):
        raise ValueError(f"tier must be 'auto', 'interpreter' or 'compiled', "
                         f"got {tier!r}")
    if tier == "interpreter":
        return None
    if tier == "compiled":
        if executable is not None:
            return executable.lower()
        from ..compiled.lower import lower_program
        return lower_program(program)
    if compiler is not None and executable is not None:
        return compiler.lowered_for(executable, n_invocations)
    return None


def _make_interp(env, mode: str, lowered):
    if lowered is None:
        return Interpreter(env, mode)
    from ..compiled.exec import SplicingInterpreter
    return SplicingInterpreter(env, lowered, mode)


def run_batch(session, program: Program,
              param_sets: Sequence[Mapping[str, object]], *,
              network: Optional[NetworkProfile] = None, mode: str = "fast",
              executable=None,
              site_cache: Optional[SiteCache] = None,
              tier: str = "auto", compiler=None) -> BatchResult:
    """Execute ``program`` once per parameter set on a shared batch env.

    ``site_cache`` plugs in a serving-scoped
    :class:`~repro.runtime.sitecache.SiteCache` so fetches are shared
    across batches and programs; without one, a private per-batch cache
    preserves the classic one-fetch-per-site-per-batch behavior.

    ``tier`` selects the execution tier: ``"auto"`` (compiled when the
    ``compiler`` — a :class:`~repro.compiled.manager.CompileManager` — says
    the pair is hot), ``"compiled"`` (force), ``"interpreter"`` (force
    off). Compiled batches are bit-identical to interpreted ones — same
    outputs, same simulated clock — only wall time differs."""
    from ..api.cache import program_write_tables as _write_tables
    from ..api.session import ExecutionResult

    param_sets = [dict(p) for p in param_sets]
    declared = {n for n, _ in program.inputs}
    for p in param_sets:
        unknown = set(p) - declared
        if unknown:
            raise TypeError(
                f"unknown program input(s) {sorted(unknown)}; "
                f"{program.name} declares {sorted(declared) or 'no inputs'}")

    cache = site_cache if site_cache is not None else SiteCache()
    cache.new_era()
    # binding diversity is a property of the SOURCE program's sites; the
    # executed (rewritten) program may have compiled them away entirely
    source = getattr(executable, "source", None) or program

    tracer = getattr(session, "tracer", NOOP_TRACER)
    lowered = _resolve_lowered(program, executable, tier, compiler,
                               len(param_sets))
    tier_used = "interpreter" if lowered is None else "compiled"
    if executable is not None:
        executable.last_tier = tier_used
    if lowered is not None:
        # run the lowering's OWN program tree: compiled-loop bindings are by
        # region identity, and the lowering was built from a program with
        # this exact fingerprint
        program = lowered.program
        session.compiled_executions = getattr(
            session, "compiled_executions", 0) + len(param_sets)

    if program_has_updates(program):
        # correctness first: a mutating program may change what later
        # invocations should observe, so each one gets an isolated env —
        # but sites over tables the program never WRITES are still shared
        # through the (epoch-keyed) site cache, and iteration/binding
        # observations are harvested per env, so mutating programs feed
        # the feedback loop's StatsProfile too
        write_set = _write_tables(program)
        envs, results, iteration_obs, observations = [], [], [], []
        with tracer.span("batch", program=program.name, n=len(param_sets),
                         tier=tier_used, batched=False) as bsp:
            for p in param_sets:
                env = BatchClientEnv(session.db,
                                     network or session.catalog.network,
                                     c_z=session.catalog.c_z,
                                     site_cache=cache,
                                     write_set=write_set, tracer=tracer)
                outputs = _make_interp(env, mode, lowered).run(program,
                                                               p or None)
                results.append(ExecutionResult(
                    outputs=outputs, simulated_s=env.clock,
                    n_queries=env.n_queries,
                    n_round_trips=env.n_round_trips))
                iteration_obs.extend(env.iteration_log)
                observations.extend(env.observations)
                envs.append(env)
            if tracer.enabled:
                for e in envs:
                    e.flush_site_events()
                bsp.attrs["simulated_s"] = sum(r.simulated_s
                                               for r in results)
        session.executions += len(param_sets)
        if executable is not None:
            executable.n_runs += len(param_sets)
        return BatchResult(
            results=results,
            simulated_s=sum(r.simulated_s for r in results),
            n_queries=sum(r.n_queries for r in results),
            n_round_trips=sum(r.n_round_trips for r in results),
            batched=False,
            site_hits=sum(e.site_hits for e in envs),
            shared_site_hits=sum(e.shared_site_hits for e in envs),
            observations=observations,
            iteration_observations=iteration_obs,
            # cache-level observations only: input diversity does not bound
            # a mutating program's binding sequences (they may depend on
            # rows earlier invocations wrote)
            binding_observations=_merge_binding_logs(envs),
            tier=tier_used)

    env = BatchClientEnv(session.db, network or session.catalog.network,
                         c_z=session.catalog.c_z, site_cache=cache,
                         tracer=tracer)
    interp = _make_interp(env, mode, lowered)
    results = []
    with tracer.span("batch", sim_clock=lambda: env.clock,
                     program=program.name, n=len(param_sets),
                     tier=tier_used, batched=True):
        clock0, q0, rt0 = 0.0, 0, 0
        for p in param_sets:
            outputs = interp.run(program, p or None)
            results.append(ExecutionResult(
                outputs=outputs, simulated_s=env.clock - clock0,
                n_queries=env.n_queries - q0,
                n_round_trips=env.n_round_trips - rt0))
            clock0, q0, rt0 = env.clock, env.n_queries, env.n_round_trips
        if tracer.enabled:
            env.flush_site_events()
    session.executions += len(param_sets)
    if executable is not None:
        executable.n_runs += len(param_sets)
    return BatchResult(results=results, simulated_s=env.clock,
                       n_queries=env.n_queries,
                       n_round_trips=env.n_round_trips, batched=True,
                       site_hits=env.site_hits,
                       shared_site_hits=env.shared_site_hits,
                       observations=list(env.observations),
                       iteration_observations=list(env.iteration_log),
                       binding_observations=_input_diversity_fallback(
                           _merge_binding_logs([env]), source, param_sets),
                       tier=tier_used)
