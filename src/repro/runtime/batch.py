"""Batched execution: amortize server round-trips across parameter bindings.

``Executable.run(**params)`` opens a fresh :class:`ClientEnv` per
invocation — every query site pays its round trip every time. The paper's
batching transformation amortizes ``C_NRT`` by combining many parameter
bindings into one server interaction; this module applies the same idea at
the serving layer:

  * **shared site cache** — one :class:`BatchClientEnv` serves the whole
    batch; an ``executeQuery`` site with identical bindings is fetched from
    the server ONCE per batch (one round trip per query site), later
    invocations reuse the local result for a C_Z charge;
  * **bulk navigation fetch** — the vectorized interpreter's ORM-navigation
    path (``core.vectorize._vec_nav``) asks this env to fetch ALL missing
    keys of a navigation site in one combined round trip
    (``WHERE key IN (...)``-style) instead of one point query per key;
  * **observation log** — every true server execution records (query,
    observed cardinality, wall-clock) for the feedback controller.

Outputs are bit-for-bit identical to per-invocation ``run()``: the caches
only avoid refetching immutable data, never change what is computed.
Programs containing ``UPDATE`` statements fall back to sequential isolated
execution — sharing fetched state across invocations is unsound once the
data mutates mid-batch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from ..core.regions import (BasicBlock, Interpreter, Program, Region,
                            UpdateRow)
from ..relational.database import ClientEnv, NetworkProfile

__all__ = ["BatchClientEnv", "BatchResult", "run_batch", "program_has_updates"]


def program_has_updates(program: Program) -> bool:
    found = [False]

    def walk(r: Region):
        if isinstance(r, BasicBlock) and isinstance(r.stmt, UpdateRow):
            found[0] = True
        for c in r.children():
            walk(c)

    walk(program.body)
    return found[0]


class _Uncacheable(Exception):
    """A query binding with no faithful hashable identity."""


def _freeze(v):
    if isinstance(v, (int, float, str, bool, bytes)) or v is None:
        return v
    if isinstance(v, (tuple, list)):
        return tuple(_freeze(x) for x in v)
    item = getattr(v, "item", None)
    if item is not None and getattr(v, "ndim", None) == 0:
        return item()                      # numpy scalar
    tobytes = getattr(v, "tobytes", None)
    if tobytes is not None:
        return (getattr(v, "shape", None), str(getattr(v, "dtype", "")),
                tobytes())                 # full-content array identity
    raise _Uncacheable(type(v).__name__)


def _param_key(params: Optional[Mapping[str, object]]) -> Tuple:
    """Hashable FULL-CONTENT identity of a parameter binding. Raises
    :class:`_Uncacheable` for values it cannot represent faithfully — the
    caller then bypasses the site cache rather than risk serving a stale
    result for a colliding key."""
    if not params:
        return ()
    return tuple((k, _freeze(params[k])) for k in sorted(params))


class BatchClientEnv(ClientEnv):
    """A client environment shared by every invocation of one batch."""

    def __init__(self, db, network: NetworkProfile, c_z: float = 30e-9,
                 orm_cache: bool = True):
        super().__init__(db, network, c_z=c_z, orm_cache=orm_cache)
        self._site_cache: Dict[Tuple, object] = {}
        self.site_hits = 0
        # (query, observed rows, observed wall-clock) per true execution —
        # consumed by runtime.feedback.FeedbackController
        self.observations: List[Tuple[object, int, float]] = []

    def execute_query(self, q, params: Optional[Mapping[str, object]] = None):
        try:
            key = (q.key(), _param_key(params))
        except _Uncacheable:
            t = super().execute_query(q, params)
            self.observations.append((q, t.nrows, self.query_log[-1][2]))
            return t
        hit = self._site_cache.get(key)
        if hit is not None:
            # local reuse: the result is already client-side; one C_Z to
            # hand the cursor over, no server round trip
            self.site_hits += 1
            self.charge_statement()
            return hit
        t = super().execute_query(q, params)
        self.observations.append((q, t.nrows, self.query_log[-1][2]))
        self._site_cache[key] = t
        return t

    def bulk_nav_charge(self, table, n_misses: int) -> None:
        """Charge ONE combined fetch for all missing keys of a navigation
        site (called from ``core.vectorize._vec_nav``): a single round trip
        whose server time is ``n_misses`` index probes and whose payload is
        ``n_misses`` rows — instead of ``n_misses`` separate point queries."""
        m = self.db.model
        self._charge_query(
            n_misses, table.row_bytes,
            m.startup_s + m.index_lookup_s,
            m.startup_s + n_misses * m.index_lookup_s
            + n_misses / m.emit_rows_per_s)


@dataclasses.dataclass
class BatchResult(Sequence):
    """Per-invocation results plus batch-level telemetry."""

    results: List            # ExecutionResult per parameter set, in order
    simulated_s: float       # total simulated clock for the whole batch
    n_queries: int
    n_round_trips: int
    batched: bool            # False -> sequential fallback (program updates)
    site_hits: int = 0
    observations: List = dataclasses.field(default_factory=list)
    # (site_key, iteration_count) per executed while / collection loop —
    # consumed by FeedbackController.observe_iterations into a StatsProfile
    iteration_observations: List = dataclasses.field(default_factory=list)

    def __getitem__(self, i):
        return self.results[i]

    def __len__(self):
        return len(self.results)

    @property
    def outputs(self) -> List[Dict[str, object]]:
        return [r.outputs for r in self.results]

    def describe(self) -> str:
        kind = "batched" if self.batched else "sequential-fallback"
        return (f"{len(self.results)} invocation(s) [{kind}]: "
                f"{self.simulated_s:.4g}s simulated, "
                f"{self.n_round_trips} round trip(s), "
                f"{self.site_hits} site reuse(s)")


def run_batch(session, program: Program,
              param_sets: Sequence[Mapping[str, object]], *,
              network: Optional[NetworkProfile] = None, mode: str = "fast",
              executable=None) -> BatchResult:
    """Execute ``program`` once per parameter set on a shared batch env."""
    from ..api.session import ExecutionResult

    param_sets = [dict(p) for p in param_sets]
    declared = {n for n, _ in program.inputs}
    for p in param_sets:
        unknown = set(p) - declared
        if unknown:
            raise TypeError(
                f"unknown program input(s) {sorted(unknown)}; "
                f"{program.name} declares {sorted(declared) or 'no inputs'}")

    if program_has_updates(program):
        # correctness first: a mutating program may change what later
        # invocations should observe, so each one gets an isolated env —
        # but iteration observations are still harvested per env, so
        # mutating programs feed the feedback loop's StatsProfile too
        results, iteration_obs = [], []
        for p in param_sets:
            env = ClientEnv(session.db, network or session.catalog.network,
                            c_z=session.catalog.c_z)
            outputs = Interpreter(env, mode).run(program, p or None)
            results.append(ExecutionResult(
                outputs=outputs, simulated_s=env.clock,
                n_queries=env.n_queries, n_round_trips=env.n_round_trips))
            iteration_obs.extend(env.iteration_log)
        session.executions += len(param_sets)
        if executable is not None:
            executable.n_runs += len(param_sets)
        return BatchResult(
            results=results,
            simulated_s=sum(r.simulated_s for r in results),
            n_queries=sum(r.n_queries for r in results),
            n_round_trips=sum(r.n_round_trips for r in results),
            batched=False,
            iteration_observations=iteration_obs)

    env = BatchClientEnv(session.db, network or session.catalog.network,
                         c_z=session.catalog.c_z)
    interp = Interpreter(env, mode)
    results = []
    clock0, q0, rt0 = 0.0, 0, 0
    for p in param_sets:
        outputs = interp.run(program, p or None)
        results.append(ExecutionResult(
            outputs=outputs, simulated_s=env.clock - clock0,
            n_queries=env.n_queries - q0,
            n_round_trips=env.n_round_trips - rt0))
        clock0, q0, rt0 = env.clock, env.n_queries, env.n_round_trips
    session.executions += len(param_sets)
    if executable is not None:
        executable.n_runs += len(param_sets)
    return BatchResult(results=results, simulated_s=env.clock,
                       n_queries=env.n_queries,
                       n_round_trips=env.n_round_trips, batched=True,
                       site_hits=env.site_hits,
                       observations=list(env.observations),
                       iteration_observations=list(env.iteration_log))
