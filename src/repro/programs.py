"""The paper's example programs and workloads, via the tracing frontend.

  * ``make_p0 / make_p1 / make_p2`` — Fig. 3 (Hibernate N+1 / SQL join /
    prefetch) over TPC-DS-sized ``orders`` / ``customer`` tables.
  * ``make_m0`` — Fig. 7 (dependent aggregations: sum + cumulative sum).
  * ``make_wilos_<X>`` — one representative program per Wilos pattern A–F
    (Fig. 14), matching the paper's descriptions.
  * data generators with configurable cardinalities, many-to-one ratio and
    predicate selectivity (Sec. VIII experiment setup).

All programs are written against ``repro.api.ProgramBuilder`` — straight-line
code with ``with``-scoped loops and conditionals — instead of hand-assembled
``LoopRegion``/``SeqRegion`` trees. The builder emits byte-identical Region
IR to the previous hand-built versions (asserted in tests/test_api.py).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from .api.builder import ProgramBuilder, col, param, q
from .core.regions import Program
from .relational.database import DatabaseServer
from .relational.table import Field, Schema, Table

__all__ = [
    "make_orders_customer_db", "make_sales_db", "make_wilos_db",
    "make_p0", "make_p1", "make_p2", "make_m0",
    "make_wilos_a", "make_wilos_b", "make_wilos_c", "make_wilos_d",
    "make_wilos_e", "make_wilos_f", "WILOS_PROGRAMS",
]

# make the programs' pure functions available to relational computed columns
# (rule T4 translates imperative calls into projected scalar expressions)
from .relational.algebra import register_scalar_func as _reg
from .core.regions import get_function as _getf

for _name in ("myFunc", "combine", "scale"):
    _reg(_name, _getf(_name))


# --------------------------------------------------------------------------
# Data generators
# --------------------------------------------------------------------------

def make_orders_customer_db(n_orders: int, n_customers: int,
                            seed: int = 0) -> DatabaseServer:
    """TPC-DS-sized rows: customer ≈ 132 B, orders (store_sales-ish) ≈ 100 B."""
    rng = np.random.default_rng(seed)
    customer = Table.from_columns(
        "customer",
        Schema.of(Field("c_customer_sk", "int64", 8),
                  Field("c_birth_year", "int32", 4),
                  Field("c_credit", "float32", 4),
                  Field("c_payload", "int32", 116)),  # varchar payload stand-in
        c_customer_sk=np.arange(n_customers, dtype=np.int64),
        c_birth_year=rng.integers(1930, 2005, n_customers),
        c_credit=rng.uniform(0, 1e4, n_customers).astype(np.float32),
        c_payload=rng.integers(0, 1 << 20, n_customers),
    )
    orders = Table.from_columns(
        "orders",
        Schema.of(Field("o_id", "int64", 8),
                  Field("o_customer_sk", "int64", 8),
                  Field("o_amt", "float32", 4),
                  Field("o_payload", "int32", 80)),
        o_id=np.arange(n_orders, dtype=np.int64),
        o_customer_sk=rng.integers(0, n_customers, n_orders),
        o_amt=rng.uniform(1, 500, n_orders).astype(np.float32),
        o_payload=rng.integers(0, 1 << 20, n_orders),
    )
    return DatabaseServer({"customer": customer, "orders": orders})


def make_sales_db(n_sales: int, n_months: int = 12, seed: int = 1) -> DatabaseServer:
    rng = np.random.default_rng(seed)
    sales = Table.from_columns(
        "sales",
        Schema.of(Field("month", "int32", 4), Field("sale_amt", "float32", 4),
                  Field("s_payload", "int32", 92)),
        month=rng.integers(1, n_months + 1, n_sales),
        sale_amt=rng.uniform(1, 100, n_sales).astype(np.float32),
        s_payload=rng.integers(0, 1 << 20, n_sales),
    )
    return DatabaseServer({"sales": sales})


def make_wilos_db(n_big: int, ratio: int = 10, seed: int = 2) -> DatabaseServer:
    """Two relations with a many-to-one FK (ratio:1), per the Exp-4 setup
    (mapping ratio 10:1, selectivity 20%)."""
    rng = np.random.default_rng(seed)
    n_small = max(1, n_big // ratio)
    small = Table.from_columns(
        "roles",
        Schema.of(Field("r_id", "int64", 8), Field("r_rank", "int32", 4),
                  Field("r_payload", "int32", 120)),
        r_id=np.arange(n_small, dtype=np.int64),
        r_rank=rng.integers(0, 5, n_small),  # 20% selectivity on == one rank
        r_payload=rng.integers(0, 1 << 20, n_small),
    )
    big = Table.from_columns(
        "tasks",
        Schema.of(Field("t_id", "int64", 8), Field("t_role_id", "int64", 8),
                  Field("t_state", "int32", 4), Field("t_hours", "float32", 4),
                  Field("t_payload", "int32", 76)),
        t_id=np.arange(n_big, dtype=np.int64),
        t_role_id=rng.integers(0, n_small, n_big),
        t_state=rng.integers(0, 5, n_big),
        t_hours=rng.uniform(0, 40, n_big).astype(np.float32),
        t_payload=rng.integers(0, 1 << 20, n_big),
    )
    return DatabaseServer({"roles": small, "tasks": big})


# --------------------------------------------------------------------------
# Fig. 3 — P0 / P1 / P2
# --------------------------------------------------------------------------

def make_p0() -> Program:
    """Hibernate ORM program: per-order navigation → N+1 selects."""
    b = ProgramBuilder("P0")
    b.relate("orders", "o_customer_sk", "customer", "c_customer_sk",
             name="customer")
    result = b.let("result", b.empty_list())
    with b.loop(b.load_all("orders"), var="o", label="L3-7") as o:
        cust = b.let("cust", o.customer)  # lazy relationship → point query
        val = b.let("val", b.call("myFunc", o.o_id, cust.c_birth_year))
        b.add(result, val)
    return b.build(outputs=(result,))


def make_p1() -> Program:
    """Rewritten to a single SQL join (Fig. 3b)."""
    b = ProgramBuilder("P1")
    join = q("orders").join("customer", "o_customer_sk", "c_customer_sk")
    result = b.let("result", b.empty_list())
    with b.loop(join, var="r") as r:
        val = b.let("val", b.call("myFunc", r.o_id, r.c_birth_year))
        b.add(result, val)
    return b.build(outputs=(result,))


def make_p2() -> Program:
    """Rewritten to prefetch + local cache lookups (Fig. 3c)."""
    b = ProgramBuilder("P2")
    result = b.let("result", b.empty_list())
    b.prefetch("customer", by="c_customer_sk")
    with b.loop(b.load_all("orders"), var="o") as o:
        cust = b.let("cust", b.cache_lookup("customer", "c_customer_sk",
                                            o.o_customer_sk))
        val = b.let("val", b.call("myFunc", o.o_id, cust.c_birth_year))
        b.add(result, val)
    return b.build(outputs=(result,))


# --------------------------------------------------------------------------
# Fig. 7 — M0 (dependent aggregations)
# --------------------------------------------------------------------------

def make_m0() -> Program:
    b = ProgramBuilder("M0")
    monthly = q("sales").select("month", "sale_amt").order_by("month")
    total = b.let("total", 0.0)
    csum = b.let("cSum", b.empty_map())
    with b.loop(monthly, var="t") as t:
        b.let("total", total + t.sale_amt)
        b.put(csum, t.month, total)
    return b.build(outputs=(total, csum))


# --------------------------------------------------------------------------
# Wilos patterns A–F (Fig. 14)
# --------------------------------------------------------------------------

def make_wilos_a() -> Program:
    """A: nested loops with intermittent updates. The inner loop filters an
    inner relation imperatively; the outer loop issues DB updates, so only
    the inner loop can move to SQL — or be prefetched (Cobra's choice)."""
    b = ProgramBuilder("W_A")
    with b.loop(b.load_all("roles"), var="x") as x:
        cnt = b.let("cnt", 0)
        with b.loop(b.load_all("tasks"), var="y") as y:
            with b.when(y.t_role_id == x.r_id):
                b.let("cnt", cnt + 1)
        b.update_row("roles", "r_rank", cnt, "r_id", x.r_id)
    return b.build(outputs=())


def make_wilos_b() -> Program:
    """B: multiple aggregations in one loop — a scalar count plus a collection
    touching every row. Extracting the count to SQL adds a query (heuristic);
    Cobra keeps the original single query."""
    b = ProgramBuilder("W_B")
    n = b.let("n", 0)
    items = b.let("items", b.empty_list())
    with b.loop(b.load_all("tasks"), var="t") as t:
        b.let("n", n + 1)
        b.add(items, b.call("scale", t.t_hours))
    return b.build(outputs=(n, items))


def make_wilos_c() -> Program:
    """C: nested-loops join implemented imperatively."""
    b = ProgramBuilder("W_C")
    result = b.let("result", b.empty_list())
    with b.loop(b.load_all("tasks"), var="x") as x:
        with b.loop(b.load_all("roles"), var="y") as y:
            with b.when(y.r_id == x.t_role_id):
                b.add(result, b.call("combine", x.t_hours, y.r_rank))
    return b.build(outputs=(result,))


def make_wilos_d() -> Program:
    """D: a per-row 'function' (inlined) aggregating a correlated query."""
    b = ProgramBuilder("W_D")
    result = b.let("result", b.empty_list())
    with b.loop(b.load_all("roles"), var="x") as x:
        s = b.let("s", 0.0)
        tasks_of_role = q("tasks").where(col("t_role_id").eq(param("rid"))) \
                                  .bind(rid=x.r_id)
        with b.loop(tasks_of_role, var="y") as y:
            b.let("s", s + y.t_hours)
        b.add(result, s)
    return b.build(outputs=(result,))


def make_wilos_e() -> Program:
    """E: the same relation filtered differently across (recursive) calls —
    modeled as a loop over a worklist issuing per-key σ queries."""
    b = ProgramBuilder("W_E")
    worklist = b.input("worklist", ())
    result = b.let("result", b.empty_list())
    with b.loop(worklist, var="wid") as wid:
        per_key = q("tasks").where(col("t_role_id").eq(param("rid"))) \
                            .bind(rid=wid)
        with b.loop(per_key, var="y") as y:
            b.add(result, y.t_hours)
    return b.build(outputs=(result,))


def make_wilos_f() -> Program:
    """F: different column subsets of one relation used by different callees —
    two narrow queries vs. one prefetch of the whole relation."""
    b = ProgramBuilder("W_F")
    hours = b.let("hours", 0.0)
    with b.loop(q("tasks").select("t_hours"), var="a") as a:
        b.let("hours", hours + a.t_hours)
    states = b.let("states", 0)
    with b.loop(q("tasks").select("t_state"), var="b") as row:
        b.let("states", states + row.t_state)
    return b.build(outputs=(hours, states))


WILOS_PROGRAMS = {
    "A": make_wilos_a, "B": make_wilos_b, "C": make_wilos_c,
    "D": make_wilos_d, "E": make_wilos_e, "F": make_wilos_f,
}
