"""The paper's example programs and workloads, as plain Python functions.

  * ``make_p0 / make_p1 / make_p2`` — Fig. 3 (Hibernate N+1 / SQL join /
    prefetch) over TPC-DS-sized ``orders`` / ``customer`` tables.
  * ``make_m0`` — Fig. 7 (dependent aggregations: sum + cumulative sum).
  * ``make_wilos_<X>`` — one representative program per Wilos pattern A–F
    (Fig. 14), matching the paper's descriptions.
  * ``make_scan`` — a while/early-exit worklist program (beyond the paper's
    Sec. V limitations): state-by-state triage with a data-dependent stop.
  * data generators with configurable cardinalities, many-to-one ratio and
    predicate selectivity (Sec. VIII experiment setup).

Every program is ordinary imperative Python — real ``for``/``if``/``while``
loops, ``break``, early ``return``, ``list.append`` — compiled to Region IR
by the AST lifter (``repro.api.lift``). The lifter lowers onto
``repro.api.ProgramBuilder`` (the documented escape hatch for programs
outside the liftable subset) and emits byte-identical IR to hand-built
region trees (asserted in tests/test_lift.py and tests/test_api.py).
"""

from __future__ import annotations


import numpy as np

from .api.builder import col, param, q
from .api.lift import (cache_lookup, lift_program, load_all, prefetch,
                       update_row)
from .core.regions import Program, get_function
from .relational.database import DatabaseServer
from .relational.table import Field, Schema, Table

__all__ = [
    "make_orders_customer_db", "make_sales_db", "make_wilos_db",
    "make_skew_db", "make_skew_probe",
    "make_p0", "make_p1", "make_p2", "make_m0", "make_scan",
    "make_wilos_a", "make_wilos_b", "make_wilos_c", "make_wilos_d",
    "make_wilos_e", "make_wilos_f", "WILOS_PROGRAMS",
    "make_synthetic", "synthetic_source",
]

# make the programs' pure functions available to relational computed columns
# (rule T4 translates imperative calls into projected scalar expressions);
# the module-level names also let the plain-Python programs below run as
# ordinary Python and are how the lifter traces the calls (by registry name)
from .relational.algebra import register_scalar_func as _reg

myFunc = get_function("myFunc")
combine = get_function("combine")
scale = get_function("scale")

for _name in ("myFunc", "combine", "scale"):
    _reg(_name, get_function(_name))

# ORM entity mapping for the Fig. 3 programs — the Hibernate-style
# relationship metadata that in a real application lives in annotations,
# passed to the lifter so ``o.customer`` traces to navigation
ORDERS_CUSTOMER_REL = ("orders", "o_customer_sk",
                       "customer", "c_customer_sk", "customer")


# --------------------------------------------------------------------------
# Data generators
# --------------------------------------------------------------------------

def make_orders_customer_db(n_orders: int, n_customers: int,
                            seed: int = 0) -> DatabaseServer:
    """TPC-DS-sized rows: customer ≈ 132 B, orders (store_sales-ish) ≈ 100 B."""
    rng = np.random.default_rng(seed)
    customer = Table.from_columns(
        "customer",
        Schema.of(Field("c_customer_sk", "int64", 8),
                  Field("c_birth_year", "int32", 4),
                  Field("c_credit", "float32", 4),
                  Field("c_payload", "int32", 116)),  # varchar payload stand-in
        c_customer_sk=np.arange(n_customers, dtype=np.int64),
        c_birth_year=rng.integers(1930, 2005, n_customers),
        c_credit=rng.uniform(0, 1e4, n_customers).astype(np.float32),
        c_payload=rng.integers(0, 1 << 20, n_customers),
    )
    orders = Table.from_columns(
        "orders",
        Schema.of(Field("o_id", "int64", 8),
                  Field("o_customer_sk", "int64", 8),
                  Field("o_amt", "float32", 4),
                  Field("o_payload", "int32", 80)),
        o_id=np.arange(n_orders, dtype=np.int64),
        o_customer_sk=rng.integers(0, n_customers, n_orders),
        o_amt=rng.uniform(1, 500, n_orders).astype(np.float32),
        o_payload=rng.integers(0, 1 << 20, n_orders),
    )
    return DatabaseServer({"customer": customer, "orders": orders})


def make_sales_db(n_sales: int, n_months: int = 12, seed: int = 1) -> DatabaseServer:
    rng = np.random.default_rng(seed)
    sales = Table.from_columns(
        "sales",
        Schema.of(Field("month", "int32", 4), Field("sale_amt", "float32", 4),
                  Field("s_payload", "int32", 92)),
        month=rng.integers(1, n_months + 1, n_sales),
        sale_amt=rng.uniform(1, 100, n_sales).astype(np.float32),
        s_payload=rng.integers(0, 1 << 20, n_sales),
    )
    return DatabaseServer({"sales": sales})


def make_wilos_db(n_big: int, ratio: int = 10, seed: int = 2) -> DatabaseServer:
    """Two relations with a many-to-one FK (ratio:1), per the Exp-4 setup
    (mapping ratio 10:1, selectivity 20%)."""
    rng = np.random.default_rng(seed)
    n_small = max(1, n_big // ratio)
    small = Table.from_columns(
        "roles",
        Schema.of(Field("r_id", "int64", 8), Field("r_rank", "int32", 4),
                  Field("r_payload", "int32", 120)),
        r_id=np.arange(n_small, dtype=np.int64),
        r_rank=rng.integers(0, 5, n_small),  # 20% selectivity on == one rank
        r_payload=rng.integers(0, 1 << 20, n_small),
    )
    big = Table.from_columns(
        "tasks",
        Schema.of(Field("t_id", "int64", 8), Field("t_role_id", "int64", 8),
                  Field("t_state", "int32", 4), Field("t_hours", "float32", 4),
                  Field("t_payload", "int32", 76)),
        t_id=np.arange(n_big, dtype=np.int64),
        t_role_id=rng.integers(0, n_small, n_big),
        t_state=rng.integers(0, 5, n_big),
        t_hours=rng.uniform(0, 40, n_big).astype(np.float32),
        t_payload=rng.integers(0, 1 << 20, n_big),
    )
    return DatabaseServer({"roles": small, "tasks": big})


def make_skew_db(n: int = 20000, ndv: int = 50, hot: float = 0.9,
                 seed: int = 7, stats_config=None) -> DatabaseServer:
    """Zipf-ish single-hot-key relation for the scalar-vs-histogram plan
    flip (the statistics subsystem's acceptance demo): ``hot`` of the
    ``events`` rows share key 0, the rest spread uniformly over the other
    ``ndv - 1`` keys. The scalar 1/NDV rule prices a per-key probe at
    N/NDV rows; the histogram's ``param_eq_fraction`` (Σ (f_v/N)², the
    key drawn from the data's own distribution) prices it near
    ``hot²·N`` — ~40× more under the defaults — which is what flips the
    per-key-query plan to a prefetch. ``e_units`` is integral so every
    plan's accumulation is exact and outputs stay bit-identical across
    the flip. ``stats_config`` selects the arm
    (``StatsConfig(histograms=False)`` = the scalar control)."""
    rng = np.random.default_rng(seed)
    n_hot = int(n * hot)
    keys = np.concatenate([
        np.zeros(n_hot, dtype=np.int64),
        rng.integers(1, max(ndv, 2), n - n_hot).astype(np.int64)])
    rng.shuffle(keys)
    events = Table.from_columns(
        "events",
        Schema.of(Field("e_id", "int64", 8), Field("e_key", "int64", 8),
                  Field("e_units", "int32", 4),
                  Field("e_payload", "int32", 104)),
        e_id=np.arange(n, dtype=np.int64),
        e_key=keys,
        e_units=rng.integers(0, 100, n),
        e_payload=rng.integers(0, 1 << 20, n),
    )
    return DatabaseServer({"events": events}, stats_config=stats_config)


def make_skew_probe() -> Program:
    """Per-key probe over the skewed ``events`` relation (W_E-shaped): for
    each worklist key, fetch its rows and accumulate the integral
    ``e_units``. The optimizer's choice — correlated per-key queries vs
    one prefetch served locally — hinges entirely on the expected rows per
    key, i.e. on which statistics arm the database was built with."""
    def W_S(worklist=()):
        result = []
        for wid in worklist:
            per_key = q("events").where(col("e_key")
                                        .eq(param("kid"))).bind(kid=wid)
            for y in per_key:
                result.append(y.e_units)
        return result

    return lift_program(W_S)


# --------------------------------------------------------------------------
# Fig. 3 — P0 / P1 / P2
# --------------------------------------------------------------------------

def make_p0() -> Program:
    """Hibernate ORM program: per-order navigation → N+1 selects."""
    def P0():
        result = []
        for o in load_all("orders"):
            cust = o.customer  # lazy relationship → point query
            val = myFunc(o.o_id, cust.c_birth_year)
            result.append(val)
        return result

    return lift_program(P0, relations=[ORDERS_CUSTOMER_REL])


def make_p1() -> Program:
    """Rewritten to a single SQL join (Fig. 3b)."""
    def P1():
        result = []
        for r in q("orders").join("customer", "o_customer_sk",
                                  "c_customer_sk"):
            val = myFunc(r.o_id, r.c_birth_year)
            result.append(val)
        return result

    return lift_program(P1)


def make_p2() -> Program:
    """Rewritten to prefetch + local cache lookups (Fig. 3c)."""
    def P2():
        result = []
        prefetch("customer", by="c_customer_sk")
        for o in load_all("orders"):
            cust = cache_lookup("customer", "c_customer_sk", o.o_customer_sk)
            val = myFunc(o.o_id, cust.c_birth_year)
            result.append(val)
        return result

    return lift_program(P2)


# --------------------------------------------------------------------------
# Fig. 7 — M0 (dependent aggregations)
# --------------------------------------------------------------------------

def make_m0() -> Program:
    def M0():
        monthly = q("sales").select("month", "sale_amt").order_by("month")
        total = 0.0
        cSum = {}
        for t in monthly:
            total = total + t.sale_amt
            cSum[t.month] = total
        return total, cSum

    return lift_program(M0)


# --------------------------------------------------------------------------
# Wilos patterns A–F (Fig. 14)
# --------------------------------------------------------------------------

def make_wilos_a() -> Program:
    """A: nested loops with intermittent updates. The inner loop filters an
    inner relation imperatively; the outer loop issues DB updates, so only
    the inner loop can move to SQL — or be prefetched (Cobra's choice)."""
    def W_A():
        for x in load_all("roles"):
            cnt = 0
            for y in load_all("tasks"):
                if y.t_role_id == x.r_id:
                    cnt = cnt + 1
            update_row("roles", "r_rank", cnt, "r_id", x.r_id)

    return lift_program(W_A)


def make_wilos_b() -> Program:
    """B: multiple aggregations in one loop — a scalar count plus a collection
    touching every row. Extracting the count to SQL adds a query (heuristic);
    Cobra keeps the original single query."""
    def W_B():
        n = 0
        items = []
        for t in load_all("tasks"):
            n = n + 1
            items.append(scale(t.t_hours))
        return n, items

    return lift_program(W_B)


def make_wilos_c() -> Program:
    """C: nested-loops join implemented imperatively."""
    def W_C():
        result = []
        for x in load_all("tasks"):
            for y in load_all("roles"):
                if y.r_id == x.t_role_id:
                    result.append(combine(x.t_hours, y.r_rank))
        return result

    return lift_program(W_C)


def make_wilos_d() -> Program:
    """D: a per-row 'function' (inlined) aggregating a correlated query."""
    def W_D():
        result = []
        for x in load_all("roles"):
            s = 0.0
            tasks_of_role = q("tasks").where(col("t_role_id")
                                             .eq(param("rid"))).bind(rid=x.r_id)
            for y in tasks_of_role:
                s = s + y.t_hours
            result.append(s)
        return result

    return lift_program(W_D)


def make_wilos_e() -> Program:
    """E: the same relation filtered differently across (recursive) calls —
    modeled as a loop over a worklist issuing per-key σ queries."""
    def W_E(worklist=()):
        result = []
        for wid in worklist:
            per_key = q("tasks").where(col("t_role_id")
                                       .eq(param("rid"))).bind(rid=wid)
            for y in per_key:
                result.append(y.t_hours)
        return result

    return lift_program(W_E)


def make_wilos_f() -> Program:
    """F: different column subsets of one relation used by different callees —
    two narrow queries vs. one prefetch of the whole relation."""
    def W_F():
        hours = 0.0
        for a in q("tasks").select("t_hours"):
            hours = hours + a.t_hours
        states = 0
        for b in q("tasks").select("t_state"):
            states = states + b.t_state
        return hours, states

    return lift_program(W_F)


WILOS_PROGRAMS = {
    "A": make_wilos_a, "B": make_wilos_b, "C": make_wilos_c,
    "D": make_wilos_d, "E": make_wilos_e, "F": make_wilos_f,
}


# --------------------------------------------------------------------------
# SYN — synthetic compile-throughput stress program (scale knob)
# --------------------------------------------------------------------------

def synthetic_source(scale: int = 10, stmts_per_loop: int = 700) -> str:
    """Source text of a batch-application-sized program: ``scale + 2``
    query loops (rotating the T5 scalar-sum / T1 collection / guarded-sum
    shapes, plus one fixed correlated nested join) buried in
    ``stmts_per_loop`` straight-line scalar statements per loop — the shape
    of real ORM business logic, where rewritable query sites are a sliver
    of the region tree. Scaling ``scale`` scales program size ~linearly
    while the rewrite surface stays a handful of loops, which is exactly
    the regime where delta-driven rule scheduling beats rescan-everything
    saturation: the exhaustive loop re-visits every block/cond skeleton
    node every round, the applicability index never enqueues them at all.

    Deterministic text (no randomness), so the lifted IR — and therefore
    the memo fingerprint and execution outputs — are reproducible."""
    lines = ["def SYN():", "    z0 = 0.0"]
    rets: list = []
    zc = 0
    n_loops = scale + 2
    for i in range(n_loops):
        for j in range(stmts_per_loop):
            zc += 1
            k = i * stmts_per_loop + j
            if j % 7 == 3:
                lines.append(f"    if z{zc - 1} > {k}:")
                lines.append(f"        z{zc} = z{zc - 1} + {2 * k + 1}")
                lines.append("    else:")
                lines.append(f"        z{zc} = z{zc - 1} - {k + 1}")
            else:
                lines.append(f"    z{zc} = z{zc - 1} + {k + 1}")
        acc = f"acc{i}"
        rets.append(acc)
        lines.append(f"    {acc} = 0.0")
        kind = i % 3
        if kind == 0:  # scalar aggregation -> T5
            lines.append(f"    for t{i} in load_all('tasks'):")
            lines.append(f"        {acc} = {acc} + t{i}.t_hours")
        elif kind == 1:  # whole-row collection -> T1
            lines.append(f"    res{i} = []")
            lines.append(f"    for t{i} in load_all('roles'):")
            lines.append(f"        res{i}.append(t{i}.r_rank)")
            lines.append(f"    {acc} = {acc} + len(res{i})")
        else:  # guarded aggregation -> T2/T5
            lines.append(f"    for t{i} in load_all('tasks'):")
            lines.append(f"        if t{i}.t_state == {i % 5}:")
            lines.append(f"            {acc} = {acc} + t{i}.t_hours")
    # one fixed (unscaled) correlated nested join for rule-chain depth
    lines.append("    deep0 = 0.0")
    lines.append("    for ra in load_all('roles'):")
    lines.append("        for tb in load_all('tasks'):")
    lines.append("            if tb.t_role_id == ra.r_id:")
    lines.append("                deep0 = deep0 + tb.t_hours")
    rets.append("deep0")
    lines.append("    return " + ", ".join(rets + [f"z{zc}"]))
    return "\n".join(lines)


def make_synthetic(scale: int = 10, stmts_per_loop: int = 700) -> Program:
    """Lift :func:`synthetic_source` (runs against :func:`make_wilos_db`
    tables). The program returns every accumulator plus the final scalar
    chain value, so batch outputs expose any plan-divergence bit-for-bit."""
    from .api.lift import lift_source
    return lift_source(
        synthetic_source(scale, stmts_per_loop),
        env={"load_all": load_all, "q": q, "col": col, "param": param,
             "len": len})


# --------------------------------------------------------------------------
# SCAN — while + early exit (beyond the paper's Sec. V limitations)
# --------------------------------------------------------------------------

def make_scan() -> Program:
    """While-loop triage with a data-dependent stop: walk task states in
    priority order, accumulating per-state hours via a correlated query,
    until the running total crosses the threshold (``break``).

    The ``while`` itself and the early exit stay imperative — no F-IR form
    exists for a guard whose iteration count is data dependent — but the
    inner aggregation loop is still rewritten by T5 into a correlated
    ``SELECT SUM(t_hours) WHERE t_state = :k`` whose binding re-evaluates
    each round, so the cost-based win survives inside the guarded region.

    SCAN is also the canonical context-flip program: compiled one-shot the
    T5 aggregate wins (one round trip per round), while under
    ``ExecutionContext(batch_size>=8)`` the binding-free prefetch site
    inside the while body amortizes across the batch and wins instead —
    and observed iteration counts published by the feedback loop (instead
    of ``while_iters_default``) move the flip point (tests/test_context.py,
    ``make bench-batch``)."""
    def SCAN(threshold=100.0, max_state=5):
        state = 0
        total = 0.0
        while state < max_state:
            s = 0.0
            for t in q("tasks").where(col("t_state").eq(param("k"))) \
                               .bind(k=state):
                s = s + t.t_hours
            total = total + s
            state = state + 1
            if total > threshold:
                break
        return total, state

    return lift_program(SCAN)
