"""The paper's example programs and workloads, as executable region IR.

  * ``make_p0 / make_p1 / make_p2`` — Fig. 3 (Hibernate N+1 / SQL join /
    prefetch) over TPC-DS-sized ``orders`` / ``customer`` tables.
  * ``make_m0`` — Fig. 7 (dependent aggregations: sum + cumulative sum).
  * ``make_wilos_<X>`` — one representative program per Wilos pattern A–F
    (Fig. 14), matching the paper's descriptions.
  * data generators with configurable cardinalities, many-to-one ratio and
    predicate selectivity (Sec. VIII experiment setup).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from .relational.algebra import (AggSpec, Aggregate, Cmp, Col, Join, Lit,
                                 OrderBy, Param, Project, Scan, Select)
from .relational.database import DatabaseServer
from .relational.table import Field, Schema, Table
from .core.regions import (Assign, BasicBlock, CacheByColumn, CollectionAdd,
                           CondRegion, IBin, ICacheLookup, ICall, IConst,
                           IEmptyList, IEmptyMap, IField, ILoadAll, INav,
                           IQuery, IVar, LoopRegion, MapPut, Prefetch, Program,
                           SeqRegion, UpdateRow, seq)

__all__ = [
    "make_orders_customer_db", "make_sales_db", "make_wilos_db",
    "make_p0", "make_p1", "make_p2", "make_m0",
    "make_wilos_a", "make_wilos_b", "make_wilos_c", "make_wilos_d",
    "make_wilos_e", "make_wilos_f", "WILOS_PROGRAMS",
]

# make the programs' pure functions available to relational computed columns
# (rule T4 translates imperative calls into projected scalar expressions)
from .relational.algebra import register_scalar_func as _reg
from .core.regions import get_function as _getf

for _name in ("myFunc", "combine", "scale"):
    _reg(_name, _getf(_name))


# --------------------------------------------------------------------------
# Data generators
# --------------------------------------------------------------------------

def make_orders_customer_db(n_orders: int, n_customers: int,
                            seed: int = 0) -> DatabaseServer:
    """TPC-DS-sized rows: customer ≈ 132 B, orders (store_sales-ish) ≈ 100 B."""
    rng = np.random.default_rng(seed)
    customer = Table.from_columns(
        "customer",
        Schema.of(Field("c_customer_sk", "int64", 8),
                  Field("c_birth_year", "int32", 4),
                  Field("c_credit", "float32", 4),
                  Field("c_payload", "int32", 116)),  # varchar payload stand-in
        c_customer_sk=np.arange(n_customers, dtype=np.int64),
        c_birth_year=rng.integers(1930, 2005, n_customers),
        c_credit=rng.uniform(0, 1e4, n_customers).astype(np.float32),
        c_payload=rng.integers(0, 1 << 20, n_customers),
    )
    orders = Table.from_columns(
        "orders",
        Schema.of(Field("o_id", "int64", 8),
                  Field("o_customer_sk", "int64", 8),
                  Field("o_amt", "float32", 4),
                  Field("o_payload", "int32", 80)),
        o_id=np.arange(n_orders, dtype=np.int64),
        o_customer_sk=rng.integers(0, n_customers, n_orders),
        o_amt=rng.uniform(1, 500, n_orders).astype(np.float32),
        o_payload=rng.integers(0, 1 << 20, n_orders),
    )
    return DatabaseServer({"customer": customer, "orders": orders})


def make_sales_db(n_sales: int, n_months: int = 12, seed: int = 1) -> DatabaseServer:
    rng = np.random.default_rng(seed)
    sales = Table.from_columns(
        "sales",
        Schema.of(Field("month", "int32", 4), Field("sale_amt", "float32", 4),
                  Field("s_payload", "int32", 92)),
        month=rng.integers(1, n_months + 1, n_sales),
        sale_amt=rng.uniform(1, 100, n_sales).astype(np.float32),
        s_payload=rng.integers(0, 1 << 20, n_sales),
    )
    return DatabaseServer({"sales": sales})


def make_wilos_db(n_big: int, ratio: int = 10, seed: int = 2) -> DatabaseServer:
    """Two relations with a many-to-one FK (ratio:1), per the Exp-4 setup
    (mapping ratio 10:1, selectivity 20%)."""
    rng = np.random.default_rng(seed)
    n_small = max(1, n_big // ratio)
    small = Table.from_columns(
        "roles",
        Schema.of(Field("r_id", "int64", 8), Field("r_rank", "int32", 4),
                  Field("r_payload", "int32", 120)),
        r_id=np.arange(n_small, dtype=np.int64),
        r_rank=rng.integers(0, 5, n_small),  # 20% selectivity on == one rank
        r_payload=rng.integers(0, 1 << 20, n_small),
    )
    big = Table.from_columns(
        "tasks",
        Schema.of(Field("t_id", "int64", 8), Field("t_role_id", "int64", 8),
                  Field("t_state", "int32", 4), Field("t_hours", "float32", 4),
                  Field("t_payload", "int32", 76)),
        t_id=np.arange(n_big, dtype=np.int64),
        t_role_id=rng.integers(0, n_small, n_big),
        t_state=rng.integers(0, 5, n_big),
        t_hours=rng.uniform(0, 40, n_big).astype(np.float32),
        t_payload=rng.integers(0, 1 << 20, n_big),
    )
    return DatabaseServer({"roles": small, "tasks": big})


# --------------------------------------------------------------------------
# Fig. 3 — P0 / P1 / P2
# --------------------------------------------------------------------------

def make_p0() -> Program:
    """Hibernate ORM program: per-order navigation → N+1 selects."""
    body = seq(
        Assign("cust", INav(IVar("o"), "o_customer_sk", "customer", "c_customer_sk")),
        Assign("val", ICall("myFunc", (IField(IVar("o"), "o_id"),
                                       IField(IVar("cust"), "c_birth_year")))),
        CollectionAdd("result", IVar("val")),
    )
    return Program(
        "P0",
        seq(Assign("result", IEmptyList()),
            LoopRegion("o", ILoadAll("orders"), body, label="L3-7")),
        outputs=("result",),
    )


def make_p1() -> Program:
    """Rewritten to a single SQL join (Fig. 3b)."""
    join = Join(Scan("orders"), Scan("customer"), "o_customer_sk", "c_customer_sk")
    body = seq(
        Assign("val", ICall("myFunc", (IField(IVar("r"), "o_id"),
                                       IField(IVar("r"), "c_birth_year")))),
        CollectionAdd("result", IVar("val")),
    )
    return Program(
        "P1",
        seq(Assign("result", IEmptyList()),
            LoopRegion("r", IQuery(join), body)),
        outputs=("result",),
    )


def make_p2() -> Program:
    """Rewritten to prefetch + local cache lookups (Fig. 3c)."""
    body = seq(
        Assign("cust", ICacheLookup("customer", "c_customer_sk",
                                    IField(IVar("o"), "o_customer_sk"))),
        Assign("val", ICall("myFunc", (IField(IVar("o"), "o_id"),
                                       IField(IVar("cust"), "c_birth_year")))),
        CollectionAdd("result", IVar("val")),
    )
    return Program(
        "P2",
        seq(Assign("result", IEmptyList()),
            BasicBlock(Prefetch(Scan("customer"), "c_customer_sk")),
            LoopRegion("o", ILoadAll("orders"), body)),
        outputs=("result",),
    )


# --------------------------------------------------------------------------
# Fig. 7 — M0 (dependent aggregations)
# --------------------------------------------------------------------------

def make_m0() -> Program:
    q = OrderBy(("month",), Project(("month", "sale_amt"), Scan("sales")))
    body = seq(
        Assign("total", IBin("+", IVar("total"), IField(IVar("t"), "sale_amt"))),
        MapPut("cSum", IField(IVar("t"), "month"), IVar("total")),
    )
    return Program(
        "M0",
        seq(Assign("total", IConst(0.0)),
            Assign("cSum", IEmptyMap()),
            LoopRegion("t", IQuery(q), body)),
        outputs=("total", "cSum"),
    )


# --------------------------------------------------------------------------
# Wilos patterns A–F (Fig. 14)
# --------------------------------------------------------------------------

def make_wilos_a() -> Program:
    """A: nested loops with intermittent updates. The inner loop filters an
    inner relation imperatively; the outer loop issues DB updates, so only
    the inner loop can move to SQL — or be prefetched (Cobra's choice)."""
    inner = LoopRegion(
        "y", ILoadAll("tasks"),
        CondRegion(IBin("==", IField(IVar("y"), "t_role_id"),
                        IField(IVar("x"), "r_id")),
                   BasicBlock(Assign("cnt", IBin("+", IVar("cnt"), IConst(1))))))
    outer_body = seq(
        Assign("cnt", IConst(0)),
        inner,
        UpdateRow("roles", "r_rank", IVar("cnt"), "r_id", IField(IVar("x"), "r_id")),
    )
    return Program(
        "W_A",
        seq(LoopRegion("x", ILoadAll("roles"), outer_body)),
        outputs=(),
    )


def make_wilos_b() -> Program:
    """B: multiple aggregations in one loop — a scalar count plus a collection
    touching every row. Extracting the count to SQL adds a query (heuristic);
    Cobra keeps the original single query."""
    body = seq(
        Assign("n", IBin("+", IVar("n"), IConst(1))),
        CollectionAdd("items", ICall("scale", (IField(IVar("t"), "t_hours"),))),
    )
    return Program(
        "W_B",
        seq(Assign("n", IConst(0)),
            Assign("items", IEmptyList()),
            LoopRegion("t", ILoadAll("tasks"), body)),
        outputs=("n", "items"),
    )


def make_wilos_c() -> Program:
    """C: nested-loops join implemented imperatively."""
    inner = LoopRegion(
        "y", ILoadAll("roles"),
        CondRegion(IBin("==", IField(IVar("y"), "r_id"),
                        IField(IVar("x"), "t_role_id")),
                   BasicBlock(CollectionAdd(
                       "result", ICall("combine", (IField(IVar("x"), "t_hours"),
                                                   IField(IVar("y"), "r_rank")))))))
    return Program(
        "W_C",
        seq(Assign("result", IEmptyList()),
            LoopRegion("x", ILoadAll("tasks"), inner)),
        outputs=("result",),
    )


def make_wilos_d() -> Program:
    """D: a per-row 'function' (inlined) aggregating a correlated query."""
    inner_q = IQuery(Select(Cmp("==", Col("t_role_id"), Param("rid")), Scan("tasks")),
                     (("rid", IField(IVar("x"), "r_id")),))
    inner = LoopRegion("y", inner_q,
                       BasicBlock(Assign("s", IBin("+", IVar("s"),
                                                   IField(IVar("y"), "t_hours")))))
    body = seq(Assign("s", IConst(0.0)), inner,
               CollectionAdd("result", IVar("s")))
    return Program(
        "W_D",
        seq(Assign("result", IEmptyList()),
            LoopRegion("x", ILoadAll("roles"), body)),
        outputs=("result",),
    )


def make_wilos_e() -> Program:
    """E: the same relation filtered differently across (recursive) calls —
    modeled as a loop over a worklist issuing per-key σ queries."""
    inner_q = IQuery(Select(Cmp("==", Col("t_role_id"), Param("rid")), Scan("tasks")),
                     (("rid", IVar("wid")),))
    inner = LoopRegion("y", inner_q,
                       BasicBlock(CollectionAdd("result",
                                                IField(IVar("y"), "t_hours"))))
    return Program(
        "W_E",
        seq(Assign("result", IEmptyList()),
            LoopRegion("wid", IVar("worklist"), inner)),
        outputs=("result",),
        inputs=(("worklist", ()),),
    )


def make_wilos_f() -> Program:
    """F: different column subsets of one relation used by different callees —
    two narrow queries vs. one prefetch of the whole relation."""
    q1 = Project(("t_hours",), Scan("tasks"))
    q2 = Project(("t_state",), Scan("tasks"))
    l1 = LoopRegion("a", IQuery(q1),
                    BasicBlock(Assign("hours", IBin("+", IVar("hours"),
                                                    IField(IVar("a"), "t_hours")))))
    l2 = LoopRegion("b", IQuery(q2),
                    BasicBlock(Assign("states", IBin("+", IVar("states"),
                                                     IField(IVar("b"), "t_state")))))
    return Program(
        "W_F",
        seq(Assign("hours", IConst(0.0)), l1,
            Assign("states", IConst(0)), l2),
        outputs=("hours", "states"),
    )


WILOS_PROGRAMS = {
    "A": make_wilos_a, "B": make_wilos_b, "C": make_wilos_c,
    "D": make_wilos_d, "E": make_wilos_e, "F": make_wilos_f,
}
