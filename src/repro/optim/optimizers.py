"""Optimizers (pure pytree implementations — no external deps).

  adamw      — fp32 moments; default for ≤100B-param archs.
  adafactor  — factored second moment, no momentum: ~4 bytes/param of state
               versus 12 for AdamW. The 1T-param MoE (kimi-k2) only fits the
               v5e 16 GB HBM budget with this (see EXPERIMENTS.md §Dry-run).
  schedules  — linear warmup + cosine decay.
  compression — int8 per-tensor-scaled gradient quantization with error
               feedback, applied at microbatch-accumulation boundaries
               (the cross-replica reduction then moves 4× fewer bytes).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["adamw", "adafactor", "warmup_cosine", "clip_by_global_norm",
           "compress_int8", "decompress_int8", "Optimizer"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step) -> (updates, new_state)


# --------------------------------------------------------------------------
# schedules / clipping
# --------------------------------------------------------------------------

def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, peak_lr * cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def adamw(lr: Callable, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = lr(step)
        bc1 = 1 - b1 ** stepf
        bc2 = 1 - b2 ** stepf

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * gf
            v_new = b2 * v + (1 - b2) * jnp.square(gf)
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
                p.astype(jnp.float32)
            return (-lr_t * delta).astype(p.dtype), m_new, v_new

        flat = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree_util.tree_map(lambda t: t[0], flat,
                                         is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# Adafactor (factored second moment, no momentum)
# --------------------------------------------------------------------------

def adafactor(lr: Callable, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0
              ) -> Optimizer:
    def factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"slots": jax.tree_util.tree_map(one, params)}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - stepf ** (-decay)
        lr_t = lr(step)

        def one(g, slot, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if factored(p):
                vr = beta * slot["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * slot["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                     eps))[..., None]
                cfac = jax.lax.rsqrt(vc)[..., None, :]
                u = gf * rfac * cfac
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = beta * slot["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(v)
                new_slot = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), new_slot

        flat = jax.tree_util.tree_map(one, grads, state["slots"], params,
                                      is_leaf=lambda x: isinstance(x, dict)
                                      and ("v" in x or "vr" in x))
        updates = jax.tree_util.tree_map(lambda t: t[0], flat,
                                         is_leaf=lambda x: isinstance(x, tuple))
        slots = jax.tree_util.tree_map(lambda t: t[1], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"slots": slots}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# Gradient compression (int8 + error feedback)
# --------------------------------------------------------------------------

def compress_int8(g):
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_accumulate(acc, g, err):
    """One microbatch contribution through the int8 channel with error
    feedback: returns (new_acc, new_err)."""
    gf = g.astype(jnp.float32) + err
    q, s = compress_int8(gf)
    deq = decompress_int8(q, s)
    return acc + deq, gf - deq
