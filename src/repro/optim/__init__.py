from .optimizers import (Optimizer, adafactor, adamw, clip_by_global_norm,
                         compress_int8, compressed_accumulate,
                         decompress_int8, warmup_cosine)
__all__ = ["Optimizer", "adafactor", "adamw", "clip_by_global_norm",
           "compress_int8", "compressed_accumulate", "decompress_int8",
           "warmup_cosine"]
