"""rwkv6-3b (Finch) — attention-free RNN with data-dependent decay
[arXiv:2404.05892; hf]. head size 64 -> 40 heads."""
from ..models.arch import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab_size=65536, head_dim=64,
    attn_kind="none", rope_kind="none", ssm_kind="rwkv6", ssm_state=64,
))
