"""Assigned architecture configs (--arch <id>). Importing this package
registers all 10 architectures with the registry in repro.models.arch."""

from . import (h2o_danube_1_8b, internlm2_20b, kimi_k2_1t_a32b,
               llama4_scout_17b_a16e, minicpm3_4b, qwen2_vl_72b, rwkv6_3b,
               seamless_m4t_large_v2, stablelm_12b, zamba2_1_2b)
from ..models.arch import get_arch, list_archs

# the submodule imports above are side-effecting (each registers its arch);
# re-export them so the bindings are part of the package surface
__all__ = [
    "h2o_danube_1_8b", "internlm2_20b", "kimi_k2_1t_a32b",
    "llama4_scout_17b_a16e", "minicpm3_4b", "qwen2_vl_72b", "rwkv6_3b",
    "seamless_m4t_large_v2", "stablelm_12b", "zamba2_1_2b",
    "get_arch", "list_archs", "ALL_ARCHS",
]

ALL_ARCHS = [
    "stablelm-12b", "minicpm3-4b", "h2o-danube-1.8b", "internlm2-20b",
    "rwkv6-3b", "zamba2-1.2b", "qwen2-vl-72b", "seamless-m4t-large-v2",
    "llama4-scout-17b-a16e", "kimi-k2-1t-a32b",
]

# (shape name, seq_len, global_batch, step kind)
SHAPES = {
    "train_4k":    dict(seq_len=4096,   global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768,  global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32768,  global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524288, global_batch=1,   kind="decode"),
}

__all__ = ["ALL_ARCHS", "SHAPES", "get_arch", "list_archs"]
