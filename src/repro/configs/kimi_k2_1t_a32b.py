"""kimi-k2-1t-a32b — trillion-parameter MoE: 384 experts top-8 + 1 shared,
per-expert d_ff=2048 (the assigned spec), GQA kv=8, first layer dense
[arXiv:2501.kimi2; unverified, paper-table]."""
from ..models.arch import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=18432,              # dense layers use the wide MLP
    vocab_size=163840, head_dim=128,
    attn_kind="gqa", rope_kind="rope",
    moe=True, n_experts=384, top_k=8, moe_d_ff=2048,
    n_shared_experts=1, n_dense_layers=1,
))
