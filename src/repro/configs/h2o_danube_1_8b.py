"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf]."""
from ..models.arch import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab_size=32000, head_dim=80,
    attn_kind="gqa", rope_kind="rope", window=4096,
))
