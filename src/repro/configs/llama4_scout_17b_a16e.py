"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert, chunked
local attention (iRoPE-style) [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from ..models.arch import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    attn_kind="gqa", rope_kind="rope", chunk_size=8192,
    moe=True, n_experts=16, top_k=1, moe_d_ff=8192,
    n_shared_experts=1, n_dense_layers=0,
))
