"""zamba2-1.2b — Mamba2 backbone with ONE shared attention block applied
periodically [arXiv:2411.15242; hf]."""
from ..models.arch import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    attn_kind="gqa", rope_kind="rope", ssm_kind="mamba2", ssm_state=64,
    hybrid_every=6, shared_attn=True,
))
