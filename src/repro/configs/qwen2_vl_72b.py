"""qwen2-vl-72b — VLM backbone with M-RoPE; vision frontend is a STUB
(input_specs provides precomputed patch embeddings) [arXiv:2409.12191; hf]."""
from ..models.arch import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064, head_dim=128,
    attn_kind="gqa", rope_kind="mrope", frontend="vision",
))
