"""stablelm-12b — dense GQA transformer [hf:stabilityai/stablelm-2-1_6b family; hf]."""
from ..models.arch import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab_size=100352, head_dim=160,
    attn_kind="gqa", rope_kind="rope",
))
