"""seamless-m4t-large-v2 — audio enc-dec; speech frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2308.11596; hf]."""
from ..models.arch import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206, head_dim=64,
    attn_kind="gqa", rope_kind="rope", frontend="audio",
    enc_dec=True, n_enc_layers=24, n_dec_layers=24,
))
