"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), TPU v5e constants:

    compute    = HLO_FLOPs_per_device / peak_FLOPs        (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
    collective = collective_bytes_per_device / ICI_bw     (~50 GB/s/link)

``cost_analysis`` gives per-device FLOPs/bytes (post-SPMD module);
collective bytes are parsed from the compiled HLO text — the sum of
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.

Also reported: MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the
usefulness ratio MODEL_FLOPS / (HLO_FLOPs × devices) — catching
remat/redundancy waste.
"""

from __future__ import annotations

import re
from typing import Dict


__all__ = ["HW", "collective_bytes_from_hlo", "roofline_terms"]

# TPU v5e per chip
HW = {
    "peak_flops": 197e12,      # bf16
    "hbm_bw": 819e9,           # bytes/s
    "ici_bw": 50e9,            # bytes/s/link (per direction)
    "hbm_bytes": 16e9,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# match the op only once per collective: plain form or its async -start
# (never -done, whose result repeats the buffer and would double-count)
_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|([a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict:
    """Sum result-shape bytes per collective type (per-device program)."""
    by_type: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str = m.group(1) or m.group(2)
        op = m.group(3)
        b = _shape_bytes(shape_str)
        by_type[op] = by_type.get(op, 0.0) + b
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_per_device": float(sum(by_type.values())),
            "by_type": by_type, "counts": counts}


def model_flops(cfg, spec) -> float:
    """6·N·D with N = active params; decode counts one token per sequence."""
    n_active = cfg.n_active_params()
    if spec["kind"] == "train":
        tokens = spec["seq_len"] * spec["global_batch"]
        return 6.0 * n_active * tokens
    if spec["kind"] == "prefill":
        tokens = spec["seq_len"] * spec["global_batch"]
        return 2.0 * n_active * tokens
    tokens = spec["global_batch"]          # one new token per sequence
    return 2.0 * n_active * tokens


def roofline_terms(cfg, spec, cell: Dict) -> Dict:
    n_dev = cell["n_devices"]
    flops_dev = cell["flops_per_device"]
    bytes_dev = cell["bytes_per_device"]
    coll_dev = cell["collectives"]["bytes_per_device"]

    t_compute = flops_dev / HW["peak_flops"]
    t_memory = bytes_dev / HW["hbm_bw"]
    t_coll = coll_dev / HW["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, spec)
    hlo_total = flops_dev * n_dev
    useful = mf / hlo_total if hlo_total else 0.0
    bound = max(t_compute, t_memory, t_coll)
    # roofline fraction: useful-model-compute time vs. achievable step time
    t_model_ideal = mf / (n_dev * HW["peak_flops"])
    frac = t_model_ideal / bound if bound > 0 else 0.0
    return {
        **{k: float(v) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": float(mf),
        "useful_flops_ratio": float(useful),
        "roofline_fraction": float(frac),
        # memory term from XLA:CPU bytes-accessed overstates TPU HBM traffic
        # (fusion differences) — per-term fractions let both views be read
        "fraction_vs_compute": float(t_model_ideal / t_compute)
        if t_compute > 0 else 0.0,
        "fraction_vs_collective": float(
            t_model_ideal / max(t_compute, t_coll))
        if max(t_compute, t_coll) > 0 else 0.0,
    }
