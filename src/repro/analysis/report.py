"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from artifacts."""

from __future__ import annotations

import glob
import json
import os
from typing import List

from ..obs.render import fmt_seconds as _fmt_t

__all__ = ["roofline_table", "dryrun_summary"]

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str = "reports/dryrun") -> List[dict]:
    cells = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def roofline_table(out_dir: str = "reports/dryrun", mesh: str = "16x16") -> str:
    cells = [c for c in load(out_dir) if c.get("mesh") == mesh]
    cells.sort(key=lambda c: (c["arch"], SHAPE_ORDER.index(c["shape"])
                              if c["shape"] in SHAPE_ORDER else 9))
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "useful-FLOPs | roofline frac | HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("status") == "skipped":
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                         f"skip (full attn @512k) | — | — | — |")
            continue
        if c.get("status") != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | ERROR | | | | | | |")
            continue
        r = c.get("roofline", {})
        mem = c.get("full_compile", {}).get("memory", {})
        hbm = mem.get("total_hbm_bytes")
        hbm_s = f"{hbm/1e9:.1f}GB" if hbm else "—"
        lines.append(
            f"| {c['arch']} | {c['shape']} | {_fmt_t(r.get('compute_s'))} | "
            f"{_fmt_t(r.get('memory_s'))} | {_fmt_t(r.get('collective_s'))} | "
            f"{r.get('dominant','—').replace('_s','')} | "
            f"{r.get('useful_flops_ratio',0):.2f} | "
            f"{r.get('roofline_fraction',0):.3f} | {hbm_s} |")
    return "\n".join(lines)


def dryrun_summary(out_dir: str = "reports/dryrun") -> str:
    cells = load(out_dir)
    by_mesh = {}
    for c in cells:
        m = c.get("mesh", "?")
        by_mesh.setdefault(m, {"ok": 0, "skipped": 0, "error": 0})
        by_mesh[m][c.get("status", "error")] = \
            by_mesh[m].get(c.get("status", "error"), 0) + 1
    lines = []
    for m, st in sorted(by_mesh.items()):
        lines.append(f"- mesh {m}: {st.get('ok',0)} compiled ok, "
                     f"{st.get('skipped',0)} documented skips, "
                     f"{st.get('error',0)} errors")
    # collective structure examples
    for c in cells:
        if c.get("status") == "ok" and c["shape"] == "train_4k":
            counts = c.get("full_collective_counts", {})
            lines.append(f"- {c['arch']} train_4k {c['mesh']}: "
                         f"collectives {counts}")
            break
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print(dryrun_summary())
    print()
    print(roofline_table(mesh=mesh))
