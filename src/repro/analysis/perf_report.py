"""Render reports/perf/*.json into the EXPERIMENTS.md §Perf log."""

from __future__ import annotations

import glob
import json
import os

from ..obs.render import fmt_seconds as _fmt

__all__ = ["perf_section"]


def perf_section(out_dir: str = "reports/perf") -> str:
    parts = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        its = [i for i in rec["iterations"] if i.get("status") == "ok"]
        if not its:
            continue
        base = its[0]
        best = min(its, key=lambda i: max(i["terms"].values()))
        dom0 = max(base["terms"], key=base["terms"].get)
        gain = base["terms"][dom0] / max(best["terms"][dom0], 1e-12)
        frac_gain = best["roofline_fraction"] / max(
            base["roofline_fraction"], 1e-12)
        parts.append(f"### {rec['pair']} — {rec['arch']} × {rec['shape']}\n")
        parts.append(
            f"Baseline dominant term: **{dom0.replace('_s','')}** "
            f"({_fmt(base['terms'][dom0])}); best variant "
            f"**{best['variant']}** drives it to "
            f"{_fmt(best['terms'][dom0])} (**{gain:.2f}×**), roofline "
            f"fraction {base['roofline_fraction']:.4f} → "
            f"{best['roofline_fraction']:.4f} ({frac_gain:.1f}×).\n")
        parts.append("| iteration | hypothesis (napkin) | compute | memory | "
                     "collective | Δ dominant | verdict |")
        parts.append("|---|---|---|---|---|---|---|")
        for it in its:
            t = it["terms"]
            delta = it.get("delta_on_baseline_dominant")
            d = f"{delta*100:+.0f}%" if delta is not None else "—"
            hyp = it["hypothesis"].replace("|", "/")[:120]
            parts.append(
                f"| {it['variant']} | {hyp} | {_fmt(t['compute_s'])} | "
                f"{_fmt(t['memory_s'])} | {_fmt(t['collective_s'])} | {d} | "
                f"{it['verdict']} |")
        parts.append("")
    return "\n".join(parts)


if __name__ == "__main__":
    print(perf_section())
