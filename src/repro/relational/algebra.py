"""Relational algebra over columnar JAX tables.

Query trees are what Cobra's F-IR relational leaves (σ, π, ⋈, γ — Fig. 11)
denote. Every node can:

  * ``execute(db)``   — produce a concrete ``Table`` (vectorized jnp compute)
  * ``sql()``         — render as SQL text (for logs / EXPERIMENTS.md)
  * structural hash / equality — required by the Region DAG's duplicate
    detection (Volcano/Cascades memoization).

Scalar expressions (``Col``, ``Lit``, arithmetic, comparisons, boolean
combinators, ``Func``) evaluate column-vectorized over a table.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .table import Field, Schema, Table

__all__ = [
    "Scalar", "Col", "Lit", "Arith", "Cmp", "BoolOp", "Not", "Func", "Param",
    "Query", "Scan", "Select", "Project", "Join", "Aggregate", "OrderBy", "Limit",
    "AggSpec", "equi_join_indices", "register_scalar_func", "scan_tables",
]

# --------------------------------------------------------------------------
# Scalar expressions
# --------------------------------------------------------------------------

_SCALAR_FUNCS: Dict[str, Callable] = {
    "abs": jnp.abs,
    "sqrt": jnp.sqrt,
    "exp": jnp.exp,
    "log": jnp.log,
    "floor": jnp.floor,
    "ceil": jnp.ceil,
    "neg": jnp.negative,
    "square": jnp.square,
    "mod100": lambda x: jnp.mod(x, 100),
}


def register_scalar_func(name: str, fn: Callable) -> None:
    _SCALAR_FUNCS[name] = fn


class Scalar:
    """Base class for scalar (per-row) expressions."""

    def eval(self, table: Table, params: Optional[Mapping[str, object]] = None):
        raise NotImplementedError

    def key(self) -> Tuple:
        raise NotImplementedError

    def columns(self) -> Tuple[str, ...]:
        return ()

    def __hash__(self):
        return hash(self.key())

    def __eq__(self, other):
        return isinstance(other, Scalar) and self.key() == other.key()

    # sugar
    def __add__(self, o):  return Arith("+", self, _wrap(o))
    def __radd__(self, o): return Arith("+", _wrap(o), self)
    def __sub__(self, o):  return Arith("-", self, _wrap(o))
    def __mul__(self, o):  return Arith("*", self, _wrap(o))
    def __truediv__(self, o): return Arith("/", self, _wrap(o))
    def eq(self, o):  return Cmp("==", self, _wrap(o))
    def ne(self, o):  return Cmp("!=", self, _wrap(o))
    def lt(self, o):  return Cmp("<", self, _wrap(o))
    def le(self, o):  return Cmp("<=", self, _wrap(o))
    def gt(self, o):  return Cmp(">", self, _wrap(o))
    def ge(self, o):  return Cmp(">=", self, _wrap(o))
    def and_(self, o): return BoolOp("and", self, _wrap(o))
    def or_(self, o):  return BoolOp("or", self, _wrap(o))


def _wrap(v) -> "Scalar":
    if isinstance(v, Scalar):
        return v
    return Lit(v)


@dataclasses.dataclass(frozen=True, eq=False)
class Col(Scalar):
    name: str

    def eval(self, table, params=None):
        return table.column(self.name)

    def key(self):
        return ("col", self.name)

    def columns(self):
        return (self.name,)

    def sql(self):
        return self.name


@dataclasses.dataclass(frozen=True, eq=False)
class Lit(Scalar):
    value: object

    def eval(self, table, params=None):
        return jnp.full((table.nrows,), self.value)

    def key(self):
        return ("lit", self.value)

    def sql(self):
        return repr(self.value)


@dataclasses.dataclass(frozen=True, eq=False)
class Param(Scalar):
    """A runtime parameter (e.g. the loop variable's field in a correlated query)."""

    name: str

    def eval(self, table, params=None):
        if params is None or self.name not in params:
            raise KeyError(f"unbound query parameter {self.name!r}")
        return jnp.full((table.nrows,), params[self.name])

    def key(self):
        return ("param", self.name)

    def sql(self):
        return f":{self.name}"


_ARITH = {
    "+": jnp.add, "-": jnp.subtract, "*": jnp.multiply, "/": jnp.divide,
    "min": jnp.minimum, "max": jnp.maximum,
}
_CMP = {
    "==": jnp.equal, "!=": jnp.not_equal, "<": jnp.less, "<=": jnp.less_equal,
    ">": jnp.greater, ">=": jnp.greater_equal,
}


@dataclasses.dataclass(frozen=True, eq=False)
class Arith(Scalar):
    op: str
    left: Scalar
    right: Scalar

    def eval(self, table, params=None):
        return _ARITH[self.op](self.left.eval(table, params), self.right.eval(table, params))

    def key(self):
        return ("arith", self.op, self.left.key(), self.right.key())

    def columns(self):
        return self.left.columns() + self.right.columns()

    def sql(self):
        return f"({_sql(self.left)} {self.op} {_sql(self.right)})"


@dataclasses.dataclass(frozen=True, eq=False)
class Cmp(Scalar):
    op: str
    left: Scalar
    right: Scalar

    def eval(self, table, params=None):
        return _CMP[self.op](self.left.eval(table, params), self.right.eval(table, params))

    def key(self):
        return ("cmp", self.op, self.left.key(), self.right.key())

    def columns(self):
        return self.left.columns() + self.right.columns()

    def sql(self):
        op = {"==": "=", "!=": "<>"}.get(self.op, self.op)
        return f"{_sql(self.left)} {op} {_sql(self.right)}"


@dataclasses.dataclass(frozen=True, eq=False)
class BoolOp(Scalar):
    op: str  # "and" | "or"
    left: Scalar
    right: Scalar

    def eval(self, table, params=None):
        l = self.left.eval(table, params)
        r = self.right.eval(table, params)
        return jnp.logical_and(l, r) if self.op == "and" else jnp.logical_or(l, r)

    def key(self):
        return ("bool", self.op, self.left.key(), self.right.key())

    def columns(self):
        return self.left.columns() + self.right.columns()

    def sql(self):
        return f"({_sql(self.left)} {self.op.upper()} {_sql(self.right)})"


@dataclasses.dataclass(frozen=True, eq=False)
class Not(Scalar):
    child: Scalar

    def eval(self, table, params=None):
        return jnp.logical_not(self.child.eval(table, params))

    def key(self):
        return ("not", self.child.key())

    def columns(self):
        return self.child.columns()

    def sql(self):
        return f"NOT ({_sql(self.child)})"


@dataclasses.dataclass(frozen=True, eq=False)
class Func(Scalar):
    name: str
    args: Tuple[Scalar, ...]

    def eval(self, table, params=None):
        fn = _SCALAR_FUNCS[self.name]
        return fn(*[a.eval(table, params) for a in self.args])

    def key(self):
        return ("func", self.name, tuple(a.key() for a in self.args))

    def columns(self):
        out: Tuple[str, ...] = ()
        for a in self.args:
            out += a.columns()
        return out

    def sql(self):
        return f"{self.name}({', '.join(_sql(a) for a in self.args)})"


def _sql(e: Scalar) -> str:
    return e.sql() if hasattr(e, "sql") else repr(e)


# --------------------------------------------------------------------------
# Join index machinery (host-side; bulk gathers stay in jnp)
# --------------------------------------------------------------------------

def equi_join_indices(lk: np.ndarray, rk: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """All (li, ri) pairs with lk[li] == rk[ri], via sort+searchsorted."""
    lk = np.asarray(lk)
    rk = np.asarray(rk)
    order = np.argsort(rk, kind="stable")
    rk_sorted = rk[order]
    lo = np.searchsorted(rk_sorted, lk, side="left")
    hi = np.searchsorted(rk_sorted, lk, side="right")
    counts = hi - lo
    li = np.repeat(np.arange(len(lk)), counts)
    starts = np.repeat(lo, counts)
    base = np.repeat(np.cumsum(counts) - counts, counts)
    run_off = np.arange(len(li)) - base
    ri = order[starts + run_off]
    return li, ri


# --------------------------------------------------------------------------
# Query algebra
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AggSpec:
    func: str  # sum | count | min | max | avg
    col: Optional[str]  # None for count(*)
    out: str

    def key(self):
        return ("agg", self.func, self.col, self.out)

    def sql(self):
        arg = self.col if self.col is not None else "*"
        return f"{self.func}({arg}) AS {self.out}"


class Query:
    """Base class for relational algebra nodes."""

    def execute(self, db, params: Optional[Mapping[str, object]] = None) -> Table:
        raise NotImplementedError

    def key(self) -> Tuple:
        raise NotImplementedError

    def sql(self) -> str:
        raise NotImplementedError

    def children(self) -> Tuple["Query", ...]:
        return ()

    def output_schema(self, db) -> Schema:
        raise NotImplementedError

    def __hash__(self):
        return hash(self.key())

    def __eq__(self, other):
        return isinstance(other, Query) and self.key() == other.key()

    def __repr__(self):
        return f"{type(self).__name__}[{self.sql()}]"


@dataclasses.dataclass(frozen=True, eq=False)
class Scan(Query):
    table: str

    def execute(self, db, params=None):
        return db.table(self.table)

    def key(self):
        return ("scan", self.table)

    def sql(self):
        return f"SELECT * FROM {self.table}"

    def output_schema(self, db):
        return db.table(self.table).schema


@dataclasses.dataclass(frozen=True, eq=False)
class Select(Query):
    pred: Scalar
    child: Query

    def execute(self, db, params=None):
        t = self.child.execute(db, params)
        if t.nrows == 0:
            return t
        mask = self.pred.eval(t, params)
        return t.filter_mask(np.asarray(mask))

    def key(self):
        return ("select", self.pred.key(), self.child.key())

    def children(self):
        return (self.child,)

    def sql(self):
        return f"SELECT * FROM ({self.child.sql()}) WHERE {_sql(self.pred)}"

    def output_schema(self, db):
        return self.child.output_schema(db)


@dataclasses.dataclass(frozen=True, eq=False)
class Project(Query):
    """π — keeps `cols` and adds computed columns {name: scalar expr}."""

    cols: Tuple[str, ...]
    child: Query
    computed: Tuple[Tuple[str, Scalar], ...] = ()

    def execute(self, db, params=None):
        t = self.child.execute(db, params)
        out = t.select_columns([c for c in self.cols]) if self.cols else t.select_columns([])
        for name, expr in self.computed:
            vals = expr.eval(t, params)
            out = out.with_column(Field(name, str(np.asarray(vals).dtype)), vals)
        return out

    def key(self):
        return ("project", self.cols, tuple((n, e.key()) for n, e in self.computed), self.child.key())

    def children(self):
        return (self.child,)

    def sql(self):
        items = list(self.cols) + [f"{_sql(e)} AS {n}" for n, e in self.computed]
        return f"SELECT {', '.join(items) or '*'} FROM ({self.child.sql()})"

    def output_schema(self, db):
        base = self.child.output_schema(db).subset(self.cols)
        for name, _ in self.computed:
            base = base.concat(Schema.of(Field(name, "float64")))
        return base


@dataclasses.dataclass(frozen=True, eq=False)
class Join(Query):
    """Inner equi-join on left.left_key == right.right_key."""

    left: Query
    right: Query
    left_key: str
    right_key: str

    def execute(self, db, params=None):
        lt = self.left.execute(db, params)
        rt = self.right.execute(db, params)
        li, ri = equi_join_indices(np.asarray(lt.column(self.left_key)),
                                   np.asarray(rt.column(self.right_key)))
        lsel = lt.take(li)
        rsel = rt.take(ri)
        # disambiguate duplicate names by prefixing right side
        lnames = set(lsel.schema.names)
        ren = {n: f"{rt.name}_{n}" for n in rsel.schema.names if n in lnames}
        rsel = rsel.rename(ren)
        cols = dict(lsel.columns)
        cols.update(rsel.columns)
        return Table(f"{lt.name}_join_{rt.name}", lsel.schema.concat(rsel.schema), cols)

    def key(self):
        return ("join", self.left_key, self.right_key, self.left.key(), self.right.key())

    def children(self):
        return (self.left, self.right)

    def sql(self):
        return (f"SELECT * FROM ({self.left.sql()}) l JOIN ({self.right.sql()}) r "
                f"ON l.{self.left_key} = r.{self.right_key}")

    def output_schema(self, db):
        ls = self.left.output_schema(db)
        rs = self.right.output_schema(db)
        lnames = set(ls.names)
        rf = []
        rprefix = self.right.table if isinstance(self.right, Scan) else "r"
        for f in rs.fields:
            rf.append(dataclasses.replace(f, name=f"{rprefix}_{f.name}") if f.name in lnames else f)
        return ls.concat(Schema(tuple(rf)))


_AGG_FUNCS = {
    "sum": lambda x: jnp.sum(x),
    "min": lambda x: jnp.min(x),
    "max": lambda x: jnp.max(x),
    "avg": lambda x: jnp.mean(x),
}


@dataclasses.dataclass(frozen=True, eq=False)
class Aggregate(Query):
    """γ — group-by aggregation. Empty group_by = single global group."""

    group_by: Tuple[str, ...]
    aggs: Tuple[AggSpec, ...]
    child: Query

    def execute(self, db, params=None):
        t = self.child.execute(db, params)
        if not self.group_by:
            return self._global(t)
        return self._grouped(t)

    def _global(self, t: Table) -> Table:
        fields, cols = [], {}
        for a in self.aggs:
            if a.func == "count":
                val, dt = t.nrows, "int32"
            else:
                arr = t.column(a.col)
                if t.nrows == 0:
                    val, dt = 0, "float32"
                else:
                    val = _AGG_FUNCS[a.func](arr)
                    dt = "float32" if a.func == "avg" else str(np.asarray(val).dtype)
            fields.append(Field(a.out, dt))
            cols[a.out] = np.asarray([val], dtype=np.dtype(dt) if np.dtype(dt).itemsize<8 else np.dtype(dt.replace("64","32")))
        return Table("agg", Schema(tuple(fields)), cols)

    def _grouped(self, t: Table) -> Table:
        keys = [np.asarray(t.column(g)) for g in self.group_by]
        if t.nrows == 0:
            uniq_idx = np.asarray([], dtype=np.int64)
            inv = np.asarray([], dtype=np.int64)
            ngroups = 0
        else:
            stacked = np.stack(keys, axis=1)
            _, uniq_idx, inv = np.unique(stacked, axis=0, return_index=True, return_inverse=True)
            inv = inv.reshape(-1)
            ngroups = int(inv.max()) + 1 if len(inv) else 0
        fields, cols = [], {}
        for g in self.group_by:
            f = None
            for tf in t.schema.fields:
                if tf.name == g:
                    f = tf
            fields.append(f)
            cols[g] = np.asarray(t.column(g))[uniq_idx]
        seg = jnp.asarray(inv)
        for a in self.aggs:
            if a.func == "count":
                vals = jax.ops.segment_sum(jnp.ones((t.nrows,), jnp.int32), seg, ngroups)
                dt = "int32"
            else:
                arr = t.column(a.col)
                if a.func == "sum":
                    vals = jax.ops.segment_sum(arr, seg, ngroups)
                elif a.func == "min":
                    vals = jax.ops.segment_min(arr, seg, ngroups)
                elif a.func == "max":
                    vals = jax.ops.segment_max(arr, seg, ngroups)
                elif a.func == "avg":
                    s = jax.ops.segment_sum(arr.astype(jnp.float32), seg, ngroups)
                    c = jax.ops.segment_sum(jnp.ones((t.nrows,), jnp.float32), seg, ngroups)
                    vals = s / jnp.maximum(c, 1.0)
                else:
                    raise ValueError(a.func)
                dt = "float32" if a.func == "avg" else str(np.asarray(vals).dtype)
            fields.append(Field(a.out, dt))
            cols[a.out] = vals
        return Table("agg", Schema(tuple(fields)), cols)

    def key(self):
        return ("aggregate", self.group_by, tuple(a.key() for a in self.aggs), self.child.key())

    def children(self):
        return (self.child,)

    def sql(self):
        items = list(self.group_by) + [a.sql() for a in self.aggs]
        gb = f" GROUP BY {', '.join(self.group_by)}" if self.group_by else ""
        return f"SELECT {', '.join(items)} FROM ({self.child.sql()}){gb}"

    def output_schema(self, db):
        base = self.child.output_schema(db).subset(self.group_by) if self.group_by else Schema(())
        for a in self.aggs:
            base = base.concat(Schema.of(Field(a.out, "float64")))
        return base


@dataclasses.dataclass(frozen=True, eq=False)
class OrderBy(Query):
    keys: Tuple[str, ...]
    child: Query
    descending: bool = False

    def execute(self, db, params=None):
        return self.child.execute(db, params).sort_by(self.keys, self.descending)

    def key(self):
        return ("orderby", self.keys, self.descending, self.child.key())

    def children(self):
        return (self.child,)

    def sql(self):
        d = " DESC" if self.descending else ""
        return f"{self.child.sql()} ORDER BY {', '.join(self.keys)}{d}"

    def output_schema(self, db):
        return self.child.output_schema(db)


@dataclasses.dataclass(frozen=True, eq=False)
class Limit(Query):
    k: int
    child: Query

    def execute(self, db, params=None):
        return self.child.execute(db, params).head(self.k)

    def key(self):
        return ("limit", self.k, self.child.key())

    def children(self):
        return (self.child,)

    def sql(self):
        return f"{self.child.sql()} LIMIT {self.k}"

    def output_schema(self, db):
        return self.child.output_schema(db)


def scan_tables(q: Query) -> Tuple[str, ...]:
    """All base tables a relational ``Query`` tree scans (sorted).

    The canonical table-extraction walk: plan-cache stats tokens
    (``repro.api.cache.query_tables``), the serving-level site cache's
    invalidation epochs, and the cost model's binding-diversity group keys
    all share this identity so a table name means the same thing in every
    layer."""
    out = set()

    def walk(node: Query):
        if isinstance(node, Scan):
            out.add(node.table)
        for c in node.children():
            walk(c)

    walk(q)
    return tuple(sorted(out))
