"""Relational substrate: columnar JAX tables, algebra, simulated DB env."""

from .table import Field, Schema, Table
from .algebra import (
    AggSpec, Aggregate, Arith, BoolOp, Cmp, Col, Func, Join, Limit, Lit, Not,
    OrderBy, Param, Project, Query, Scalar, Scan, Select, equi_join_indices,
    register_scalar_func,
)
from .database import (
    ClientEnv, DatabaseServer, FAST_LOCAL, NetworkProfile, QueryEstimate,
    SLOW_REMOTE, ServerModel, TableStats,
)

__all__ = [
    "Field", "Schema", "Table",
    "AggSpec", "Aggregate", "Arith", "BoolOp", "Cmp", "Col", "Func", "Join",
    "Limit", "Lit", "Not", "OrderBy", "Param", "Project", "Query", "Scalar",
    "Scan", "Select", "equi_join_indices", "register_scalar_func",
    "ClientEnv", "DatabaseServer", "FAST_LOCAL", "NetworkProfile",
    "QueryEstimate", "SLOW_REMOTE", "ServerModel", "TableStats",
]
