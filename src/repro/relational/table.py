"""Columnar tables backed by JAX arrays.

The relational substrate of the Cobra reproduction. Tables are columnar
(dict of 1-D ``jnp`` arrays); all bulk compute (filters, gathers, joins,
aggregations) runs through ``jax.numpy`` so the data path is real JAX
compute. Index machinery that is inherently dynamic-shape (sort/unique/
searchsorted on concrete row counts) uses numpy on host — this mirrors a
database runtime, where the executor is not a compiled graph.

Wire sizes are modeled separately from storage dtype: a ``varchar(100)``
column is stored as an int32 surrogate key but declares 100 wire bytes,
so that the simulated network-transfer costs match the paper's TPC-DS
row sizing (Sec. VIII).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Field", "Schema", "Table"]


def _storage_dtype(dtype: str) -> np.dtype:
    """Storage dtype; 64-bit narrows to 32-bit unless jax_enable_x64 is set.

    Wire sizes (cost model) always honor the declared Field dtype/wire_bytes;
    only in-memory storage narrows.
    """
    dt = np.dtype(dtype)
    if dt.itemsize == 8 and not jax.config.jax_enable_x64:
        return np.dtype("int32") if dt.kind in "iu" else np.dtype("float32")
    return dt


@dataclasses.dataclass(frozen=True)
class Field:
    """One column: storage dtype + simulated wire width in bytes."""

    name: str
    dtype: str = "int32"  # numpy dtype string: int32/int64/float32/float64
    wire_bytes: Optional[int] = None  # defaults to dtype itemsize

    @property
    def itemsize(self) -> int:
        return int(np.dtype(self.dtype).itemsize)

    @property
    def bytes_on_wire(self) -> int:
        return self.wire_bytes if self.wire_bytes is not None else self.itemsize


@dataclasses.dataclass(frozen=True)
class Schema:
    fields: Tuple[Field, ...]

    def __post_init__(self):
        names = [f.name for f in self.fields]
        if len(names) != len(set(names)):
            raise ValueError(f"duplicate column names in schema: {names}")

    @staticmethod
    def of(*fields: Field) -> "Schema":
        return Schema(tuple(fields))

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(f.name for f in self.fields)

    def field(self, name: str) -> Field:
        for f in self.fields:
            if f.name == name:
                return f
        raise KeyError(f"no column {name!r}; have {self.names}")

    def has(self, name: str) -> bool:
        return any(f.name == name for f in self.fields)

    @property
    def row_bytes(self) -> int:
        """Simulated size of one row on the wire."""
        return sum(f.bytes_on_wire for f in self.fields)

    def subset(self, names: Sequence[str]) -> "Schema":
        return Schema(tuple(self.field(n) for n in names))

    def rename_prefixed(self, prefix: str) -> "Schema":
        return Schema(tuple(dataclasses.replace(f, name=prefix + f.name) for f in self.fields))

    def concat(self, other: "Schema") -> "Schema":
        return Schema(self.fields + other.fields)


class Table:
    """An immutable columnar table. Columns are 1-D jnp arrays of equal length."""

    def __init__(self, name: str, schema: Schema, columns: Mapping[str, jnp.ndarray]):
        self.name = name
        self.schema = schema
        cols: Dict[str, jnp.ndarray] = {}
        n = None
        for f in schema.fields:
            if f.name not in columns:
                raise KeyError(f"missing column {f.name!r} for table {name!r}")
            arr = jnp.asarray(columns[f.name], dtype=_storage_dtype(f.dtype))
            if arr.ndim != 1:
                raise ValueError(f"column {f.name!r} must be 1-D, got shape {arr.shape}")
            if n is None:
                n = int(arr.shape[0])
            elif int(arr.shape[0]) != n:
                raise ValueError(
                    f"column {f.name!r} has {arr.shape[0]} rows, expected {n}"
                )
            cols[f.name] = arr
        self.columns = cols
        self._nrows = 0 if n is None else n

    # ---------------------------------------------------------------- basics
    @property
    def nrows(self) -> int:
        return self._nrows

    @property
    def row_bytes(self) -> int:
        return self.schema.row_bytes

    @property
    def wire_bytes(self) -> int:
        return self.nrows * self.row_bytes

    def column(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    def __repr__(self) -> str:
        return f"Table({self.name!r}, rows={self.nrows}, cols={list(self.schema.names)})"

    # ----------------------------------------------------------- constructors
    @staticmethod
    def from_columns(name: str, schema: Schema, **columns) -> "Table":
        return Table(name, schema, columns)

    @staticmethod
    def from_rows(name: str, schema: Schema, rows: Iterable[Mapping[str, object]]) -> "Table":
        rows = list(rows)
        cols = {
            f.name: np.asarray([r[f.name] for r in rows], dtype=_storage_dtype(f.dtype))
            if rows
            else np.asarray([], dtype=_storage_dtype(f.dtype))
            for f in schema.fields
        }
        return Table(name, schema, cols)

    def empty_like(self) -> "Table":
        return Table(
            self.name,
            self.schema,
            {f.name: np.asarray([], dtype=_storage_dtype(f.dtype)) for f in self.schema.fields},
        )

    # ------------------------------------------------------------- row access
    def row(self, i: int) -> Dict[str, object]:
        return {n: self.columns[n][i].item() for n in self.schema.names}

    def to_rows(self) -> List[Dict[str, object]]:
        host = {n: np.asarray(self.columns[n]) for n in self.schema.names}
        return [{n: host[n][i].item() for n in self.schema.names} for i in range(self.nrows)]

    # ------------------------------------------------------------- transforms
    def take(self, idx) -> "Table":
        idx = jnp.asarray(idx)
        return Table(self.name, self.schema, {n: jnp.take(c, idx, axis=0) for n, c in self.columns.items()})

    def filter_mask(self, mask) -> "Table":
        keep = np.flatnonzero(np.asarray(mask))
        return self.take(keep)

    def head(self, k: int) -> "Table":
        return self.take(np.arange(min(k, self.nrows)))

    def select_columns(self, names: Sequence[str]) -> "Table":
        return Table(self.name, self.schema.subset(names), {n: self.columns[n] for n in names})

    def rename(self, mapping: Mapping[str, str]) -> "Table":
        fields = tuple(
            dataclasses.replace(f, name=mapping.get(f.name, f.name)) for f in self.schema.fields
        )
        cols = {mapping.get(n, n): c for n, c in self.columns.items()}
        return Table(self.name, Schema(fields), cols)

    def with_column(self, field: Field, values) -> "Table":
        values = jnp.asarray(values, dtype=_storage_dtype(field.dtype))
        if self.schema.has(field.name):
            fields = tuple(field if f.name == field.name else f for f in self.schema.fields)
        else:
            fields = self.schema.fields + (field,)
        cols = dict(self.columns)
        cols[field.name] = values
        return Table(self.name, Schema(fields), cols)

    def sort_by(self, keys: Sequence[str], descending: bool = False) -> "Table":
        if self.nrows == 0:
            return self
        arrs = [np.asarray(self.columns[k]) for k in reversed(list(keys))]
        order = np.lexsort(arrs)
        if descending:
            order = order[::-1]
        return self.take(order)

    def concat_rows(self, other: "Table") -> "Table":
        if self.schema.names != other.schema.names:
            raise ValueError("schema mismatch in concat")
        cols = {
            n: jnp.concatenate([self.columns[n], other.columns[n]]) for n in self.schema.names
        }
        return Table(self.name, self.schema, cols)

    # ------------------------------------------------------------- comparison
    def canonical_key(self) -> np.ndarray:
        """Row-set canonical form (sorted rows over sorted column names)."""
        names = sorted(self.schema.names)
        mat = np.stack([np.asarray(self.columns[n], dtype=np.float64) for n in names], axis=1)
        if mat.shape[0] > 1:
            order = np.lexsort(tuple(mat[:, j] for j in reversed(range(mat.shape[1]))))
            mat = mat[order]
        return mat

    def same_rows(self, other: "Table", ordered: bool = False, atol: float = 1e-6) -> bool:
        """Semantic equality: same multiset (or sequence) of rows."""
        if sorted(self.schema.names) != sorted(other.schema.names):
            return False
        if self.nrows != other.nrows:
            return False
        if self.nrows == 0:
            return True
        if ordered:
            names = sorted(self.schema.names)
            a = np.stack([np.asarray(self.columns[n], np.float64) for n in names], 1)
            b = np.stack([np.asarray(other.columns[n], np.float64) for n in names], 1)
            return bool(np.allclose(a, b, atol=atol))
        return bool(np.allclose(self.canonical_key(), other.canonical_key(), atol=atol))
