"""Simulated client/server database environment.

The paper evaluates Cobra against a real MySQL server over ethernet with a
network simulator (Sec. VIII). This container has neither, so we model the
*same knobs the paper's cost catalog exposes*:

  C_NRT       network round-trip time
  BW          network bandwidth
  C_Q^F/C_Q^L server time to first/last row (from a simple server model —
              the paper "consulted the database query optimizer" for these)
  C_Z         per-imperative-statement cost
  AF_Q        amortization factor for prefetched queries

Two distinct views (kept deliberately separate):

  * ``DatabaseServer.run(query)``      — actually executes (jnp compute) and
    returns TRUE timing from true cardinalities → the *simulated wall clock*
    ("actual running time" axis of Fig. 13).
  * ``DatabaseServer.estimate(query)`` — cardinality/cost ESTIMATES from table
    statistics only → what Cobra's cost model consumes.

``ClientEnv`` owns the simulated clock, the ORM id-cache (Hibernate caches
fetched rows by primary key — needed to reproduce Fig. 13b), and the
client-side prefetch cache (``cacheByColumn`` / ``lookup``, footnote 3).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Mapping, Optional, Tuple

import numpy as np

from .algebra import (Aggregate, Join, Limit, OrderBy, Project, Query, Scan,
                      Select)
from .table import Table

__all__ = [
    "NetworkProfile", "ServerModel", "TableStats", "QueryEstimate",
    "DatabaseServer", "ClientEnv", "SLOW_REMOTE", "FAST_LOCAL",
]


# --------------------------------------------------------------------------
# Environment profiles (paper Sec. VIII, Experiment 1/2 settings)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NetworkProfile:
    name: str
    bandwidth_bytes_per_s: float
    rtt_s: float

    @property
    def c_nrt(self) -> float:
        return self.rtt_s


# bandwidth 500 kbps, latency 250 ms  (paper: "slow remote network")
SLOW_REMOTE = NetworkProfile("slow_remote", bandwidth_bytes_per_s=500e3 / 8, rtt_s=0.250)
# bandwidth 6 gbps, rtt 0.5 ms        (paper: "fast local network")
FAST_LOCAL = NetworkProfile("fast_local", bandwidth_bytes_per_s=6e9 / 8, rtt_s=0.5e-3)


@dataclasses.dataclass(frozen=True)
class ServerModel:
    """A simple DB-server timing model (stand-in for 'consult the optimizer').

    All rates in rows/second; overheads in seconds. Values loosely calibrated
    to a MySQL 5.7-class server on the paper's hardware.
    """

    startup_s: float = 2e-4            # parse/plan/dispatch per query
    scan_rows_per_s: float = 8e6       # sequential scan emit rate
    index_lookup_s: float = 3e-5       # one B-tree point lookup
    hash_build_rows_per_s: float = 6e6
    hash_probe_rows_per_s: float = 7e6
    sort_rows_per_s: float = 2.5e6     # n log n folded into effective rate
    agg_rows_per_s: float = 9e6
    emit_rows_per_s: float = 1.2e7     # result serialization


@dataclasses.dataclass(frozen=True)
class TableStats:
    nrows: int
    row_bytes: int
    distinct: Mapping[str, int]        # per-column NDV
    minmax: Mapping[str, Tuple[float, float]]
    # per-column histograms (repro.stats.histogram) — empty when the
    # server was built with StatsConfig(histograms=False); their reprs
    # carry content digests, so stats_fingerprint() content-addresses
    # them through repr(TableStats) unchanged
    hists: Mapping[str, "object"] = dataclasses.field(default_factory=dict)

    def ndv(self, col: str) -> int:
        return max(1, int(self.distinct.get(col, max(1, self.nrows // 10))))

    def hist(self, col: str):
        """The column's :class:`~repro.stats.histogram.ColumnHistogram`,
        or None (no histogram statistics for it)."""
        return self.hists.get(col)


@dataclasses.dataclass(frozen=True)
class QueryEstimate:
    """What the optimizer knows about a query before running it (Fig. 12 terms)."""

    n_rows: float          # N_Q
    row_bytes: float       # S_row(Q)
    first_row_s: float     # C_Q^F
    last_row_s: float      # C_Q^L

    @property
    def result_bytes(self) -> float:
        return self.n_rows * self.row_bytes


# --------------------------------------------------------------------------
# Server
# --------------------------------------------------------------------------

_INSTANCE_TOKENS = itertools.count(1)


class DatabaseServer:
    def __init__(self, tables: Dict[str, Table], model: ServerModel = ServerModel(),
                 stats_config=None):
        from ..stats.histogram import DEFAULT_STATS_CONFIG
        self.tables = dict(tables)
        self.model = model
        self.stats_config = stats_config if stats_config is not None \
            else DEFAULT_STATS_CONFIG
        # process-unique identity: result caches shared across sessions key
        # on it so two servers' identically-named tables never collide
        self.instance_token = next(_INSTANCE_TOKENS)
        self._stats: Dict[str, TableStats] = {}
        self._stats_version = 0
        self._table_versions: Dict[str, int] = {}
        self._data_versions: Dict[str, int] = {}
        # per-column histogram builds since startup — the ANALYZE work
        # counter targeted re-analyzes are judged by (tests/bench)
        self.histogram_builds = 0
        self.analyze()

    def table(self, name: str) -> Table:
        return self.tables[name]

    def add_table(self, t: Table) -> None:
        """Install (or replace) a table AND refresh its statistics."""
        self.tables[t.name] = t
        self._stats[t.name] = self._compute_stats(t)
        self._stats_version += 1
        self._table_versions[t.name] = self._table_versions.get(t.name, 0) + 1
        self._data_versions[t.name] = self._data_versions.get(t.name, 0) + 1

    def replace_table(self, t: Table) -> None:
        """Replace a table's DATA without refreshing statistics — like a bulk
        load on a real server before anyone runs ANALYZE. Estimates go stale
        (``estimate()`` keeps consulting the old stats) while ``run()`` sees
        the new rows; the serving runtime's feedback controller exists to
        detect exactly this drift and trigger a re-analyze. The table's DATA
        version does bump (result caches must never serve the old rows)."""
        self.tables[t.name] = t
        self._data_versions[t.name] = self._data_versions.get(t.name, 0) + 1

    # ----------------------------------------------------------- statistics
    @property
    def stats_version(self) -> int:
        """Monotonic counter over statistics refreshes. Any change to the
        stats a cost model may have consumed (``analyze()``, table
        replacement) bumps it; plan caches key on it for invalidation."""
        return self._stats_version

    def table_version(self, name: str) -> int:
        """Per-table stats version. Plan caches key compiled programs on the
        versions of only the tables they touch, so refreshing an unrelated
        table's statistics leaves those plans hot."""
        return self._table_versions.get(name, 0)

    def data_version(self, name: str) -> int:
        """Per-table DATA version: bumps whenever a table's rows change
        (``add_table``, ``replace_table``, interpreter updates), whether or
        not statistics were refreshed. Result caches — the serving-level
        :class:`~repro.runtime.sitecache.SiteCache` — key on it so a cached
        query result is never served over rows it was not computed from."""
        return self._data_versions.get(name, 0)

    def stats_token(self, tables) -> Tuple[Tuple[str, int], ...]:
        """Cache-key component: (table, stats version) for each named table."""
        return tuple((t, self.table_version(t)) for t in sorted(set(tables)))

    def site_epoch(self, tables) -> Tuple[Tuple[str, int, int], ...]:
        """Result-cache validity token: (table, stats version, data version)
        per named table. Any ``analyze()`` or write to one of the tables
        changes the epoch, so epoch-keyed cached results self-invalidate."""
        return tuple((t, self.table_version(t), self.data_version(t))
                     for t in sorted(set(tables)))

    def stats_fingerprint(self, tables) -> Tuple[Tuple[str, str], ...]:
        """CONTENT hash of the named tables' current statistics.

        Version counters are process-local (a restarted server re-analyzes
        from zero), so the cross-session plan store compares this instead:
        a stored plan stays warm across restarts as long as the statistics
        it was costed on are byte-equal, regardless of how many ``analyze()``
        calls either process has issued."""
        import hashlib
        out = []
        for t in sorted(set(tables)):
            st = self._stats.get(t)
            digest = ("missing" if st is None else
                      hashlib.sha256(repr(st).encode()).hexdigest()[:16])
            out.append((t, digest))
        return tuple(out)

    def analyze(self, *tables: str,
                columns: Optional[Tuple[str, ...]] = None) -> int:
        """Refresh table statistics. With no arguments every table is
        re-analyzed (the legacy behaviour); naming tables refreshes only
        those, bumping only their per-table versions. ``columns`` makes
        the refresh *targeted*: scalar statistics (row counts, NDV,
        min/max) always recompute, but histograms rebuild only for the
        named columns — the others carry over from the previous stats —
        which is what the feedback controller's q-error path requests
        when one site's estimate went bad."""
        names = tables or tuple(self.tables)
        for name in names:
            self._stats[name] = self._compute_stats(
                self.tables[name], columns=columns,
                prev=self._stats.get(name) if columns else None)
            self._table_versions[name] = self._table_versions.get(name, 0) + 1
        self._stats_version += 1
        return self._stats_version

    def _compute_stats(self, t: Table,
                       columns: Optional[Tuple[str, ...]] = None,
                       prev: Optional[TableStats] = None) -> TableStats:
        from ..stats.histogram import build_histogram
        distinct, minmax, hists = {}, {}, {}
        want = None if columns is None else set(columns)
        for f in t.schema.fields:
            arr = np.asarray(t.column(f.name))
            if arr.size:
                distinct[f.name] = int(len(np.unique(arr)))
                minmax[f.name] = (float(arr.min()), float(arr.max()))
            else:
                distinct[f.name] = 1
                minmax[f.name] = (0.0, 0.0)
            if not self.stats_config.histograms:
                continue
            if want is not None and f.name not in want:
                # targeted analyze: keep the previous histogram (possibly
                # stale — exactly the staleness the q-error signal scores)
                carried = prev.hist(f.name) if prev is not None else None
                if carried is not None:
                    hists[f.name] = carried
                continue
            hists[f.name] = build_histogram(arr, self.stats_config)
            self.histogram_builds += 1
        return TableStats(t.nrows, t.row_bytes, distinct, minmax, hists)

    def stats(self, name: str) -> TableStats:
        return self._stats[name]

    # ----------------------------------------------------------- execution
    def run(self, query: Query, params: Optional[Mapping[str, object]] = None
            ) -> Tuple[Table, float, float]:
        """Execute and return (result, true C_Q^F, true C_Q^L)."""
        result = query.execute(self, params)
        first, last = self._true_times(query, params)
        return result, first, last

    def _true_times(self, q: Query, params) -> Tuple[float, float]:
        """Server time model evaluated on TRUE cardinalities (post-execution)."""
        m = self.model
        total = m.startup_s
        blocking = m.startup_s

        def walk(node: Query) -> int:
            nonlocal total, blocking
            if isinstance(node, Scan):
                n = self.table(node.table).nrows
                total += n / m.scan_rows_per_s
                return n
            if isinstance(node, Select):
                n_in = walk(node.child)
                out = node.execute(self, params).nrows
                return out
            if isinstance(node, Project):
                return walk(node.child)
            if isinstance(node, Join):
                nl = walk(node.left)
                nr = walk(node.right)
                build = min(nl, nr)
                probe = max(nl, nr)
                total += build / m.hash_build_rows_per_s + probe / m.hash_probe_rows_per_s
                blocking += build / m.hash_build_rows_per_s
                return node.execute(self, params).nrows
            if isinstance(node, Aggregate):
                n_in = walk(node.child)
                total += n_in / m.agg_rows_per_s
                blocking = total  # aggregation is blocking
                return node.execute(self, params).nrows
            if isinstance(node, OrderBy):
                n_in = walk(node.child)
                total += n_in / m.sort_rows_per_s
                blocking = total  # sort is blocking
                return n_in
            if isinstance(node, Limit):
                return min(node.k, walk(node.child))
            raise TypeError(f"unknown node {node}")

        n_out = walk(q)
        total += n_out / m.emit_rows_per_s
        first = min(blocking, total)
        last = total
        return first, last

    # ----------------------------------------------------------- estimation
    def estimate(self, q: Query, params_known: bool = False) -> QueryEstimate:
        """Cardinality + server-time estimates from statistics only."""
        m = self.model
        total = m.startup_s
        blocking = m.startup_s

        def est_rows(node: Query) -> Tuple[float, float]:
            """returns (est rows, est row_bytes)"""
            nonlocal total, blocking
            if isinstance(node, Scan):
                st = self.stats(node.table)
                total += st.nrows / m.scan_rows_per_s
                return float(st.nrows), float(st.row_bytes)
            if isinstance(node, Select):
                n, rb = est_rows(node.child)
                sel = self._selectivity(node)
                return max(1.0, n * sel), rb
            if isinstance(node, Project):
                n, rb = est_rows(node.child)
                try:
                    rb_exact = float(node.output_schema(self).row_bytes)
                    return n, max(4.0, rb_exact)
                except Exception:
                    sch_cols = len(node.cols) + len(node.computed)
                    return n, max(4.0, rb * sch_cols / max(1, sch_cols + 2))
            if isinstance(node, Join):
                nl, rbl = est_rows(node.left)
                nr, rbr = est_rows(node.right)
                ndv_l = self._ndv_of(node.left, node.left_key)
                ndv_r = self._ndv_of(node.right, node.right_key)
                out = nl * nr / max(ndv_l, ndv_r, 1.0)
                build = min(nl, nr)
                probe = max(nl, nr)
                total += build / m.hash_build_rows_per_s + probe / m.hash_probe_rows_per_s
                blocking += build / m.hash_build_rows_per_s
                return max(1.0, out), rbl + rbr
            if isinstance(node, Aggregate):
                n, rb = est_rows(node.child)
                total += n / m.agg_rows_per_s
                blocking = total
                if not node.group_by:
                    return 1.0, 8.0 * len(node.aggs)
                groups = 1.0
                for g in node.group_by:
                    groups *= self._ndv_of(node.child, g)
                return min(n, groups), 8.0 * (len(node.group_by) + len(node.aggs))
            if isinstance(node, OrderBy):
                n, rb = est_rows(node.child)
                total += n / m.sort_rows_per_s
                blocking = total
                return n, rb
            if isinstance(node, Limit):
                n, rb = est_rows(node.child)
                return min(float(node.k), n), rb
            raise TypeError(f"unknown node {node}")

        n, rb = est_rows(q)
        total += n / m.emit_rows_per_s
        return QueryEstimate(n_rows=n, row_bytes=rb,
                             first_row_s=min(blocking, total), last_row_s=total)

    def _selectivity(self, node: Select) -> float:
        from ..stats.selectivity import predicate_selectivity
        sel = predicate_selectivity(
            node.pred,
            resolve=lambda col: self._hist_of(node.child, col),
            ndv_of=lambda col: self._ndv_of(node.child, col))
        return 0.5 if sel is None else sel

    def _hist_of(self, node: Query, col: str):
        """The column's histogram at the Select's input, resolved like
        ``_ndv_of``: walk row-preserving nodes down to the base Scan. Join
        and post-aggregate inputs return None (their output distribution
        is not a base column's), falling back to the scalar estimates."""
        if isinstance(node, Scan):
            st = self._stats.get(node.table)
            return st.hist(col) if st is not None else None
        if isinstance(node, (Select, Project, OrderBy, Limit)):
            kids = node.children()
            return self._hist_of(kids[0], col) if kids else None
        return None

    def _ndv_of(self, node: Query, col: str) -> float:
        if isinstance(node, Scan):
            return float(self.stats(node.table).ndv(col))
        if isinstance(node, (Select, Project, OrderBy, Limit, Aggregate)):
            kids = node.children()
            return self._ndv_of(kids[0], col) if kids else 100.0
        if isinstance(node, Join):
            try:
                return self._ndv_of(node.left, col)
            except Exception:
                return self._ndv_of(node.right, col)
        return 100.0


# --------------------------------------------------------------------------
# Client environment (simulated clock + caches)
# --------------------------------------------------------------------------

class ClientEnv:
    """Application-side runtime: clock, ORM id-cache, prefetch cache.

    Charges time per Sec. VI:
        C_Q = C_NRT + C_Q^F + max(N_Q*S_row/BW, C_Q^L − C_Q^F)
    """

    def __init__(self, db: DatabaseServer, network: NetworkProfile,
                 c_z: float = 30e-9, orm_cache: bool = True):
        self.db = db
        self.network = network
        self.c_z = c_z              # per-imperative-statement cost (paper: 30ns)
        self.clock = 0.0
        self.orm_cache_enabled = orm_cache
        self._orm_cache: Dict[Tuple[str, object], Dict[str, object]] = {}
        self._prefetch_cache: Dict[Tuple[str, str], Dict[object, list]] = {}
        self.query_log: list = []
        self.n_queries = 0
        self.n_round_trips = 0
        # (site_key, iteration_count) per executed while loop / collection-
        # source cursor loop — the observations the feedback controller
        # folds into an ExecutionContext's StatsProfile
        self.iteration_log: list = []

    def record_iterations(self, site: str, count: int) -> None:
        self.iteration_log.append((site, int(count)))

    # ---------------------------------------------------------------- clock
    def charge_statement(self, n: int = 1) -> None:
        self.clock += self.c_z * n

    def _charge_query(self, n_rows: int, row_bytes: int, first_s: float, last_s: float) -> float:
        transfer = n_rows * row_bytes / self.network.bandwidth_bytes_per_s
        cost = self.network.c_nrt + first_s + max(transfer, last_s - first_s)
        self.clock += cost
        self.n_queries += 1
        self.n_round_trips += 1
        return cost

    # --------------------------------------------------------------- queries
    def execute_query(self, q: Query, params: Optional[Mapping[str, object]] = None) -> Table:
        result, first_s, last_s = self.db.run(q, params)
        cost = self._charge_query(result.nrows, result.row_bytes, first_s, last_s)
        self.query_log.append((q.sql(), result.nrows, cost))
        return result

    def point_lookup(self, table: str, key_col: str, key_val) -> Optional[Dict[str, object]]:
        """ORM-style navigation (o.customer): point query w/ Hibernate id-cache."""
        ck = (table, key_val)
        if self.orm_cache_enabled and ck in self._orm_cache:
            self.charge_statement()
            return self._orm_cache[ck]
        t = self.db.table(table)
        # index lookup: server time is one B-tree probe, one row out
        arr = np.asarray(t.column(key_col))
        idx = np.flatnonzero(arr == key_val)
        m = self.db.model
        self._charge_query(len(idx), t.row_bytes,
                           m.startup_s + m.index_lookup_s,
                           m.startup_s + m.index_lookup_s + len(idx) / m.emit_rows_per_s)
        self.query_log.append((f"SELECT * FROM {table} WHERE {key_col} = {key_val}", len(idx), None))
        if len(idx) == 0:
            return None
        row = t.row(int(idx[0]))
        if self.orm_cache_enabled:
            self._orm_cache[ck] = row
        return row

    # --------------------------------------------------- prefetch cache (N1)
    def cache_by_column(self, t: Table, col: str) -> None:
        """``Utils.cacheByColumn`` from the paper (footnote 3)."""
        index: Dict[object, list] = {}
        arr = np.asarray(t.column(col))
        # building the local hash index costs C_Z per row
        self.charge_statement(t.nrows)
        order = np.argsort(arr, kind="stable")
        sorted_keys = arr[order]
        # store as (table, sorted keys, order) for O(log n) lookups
        self._prefetch_cache[(t.name, col)] = {
            "table": t, "keys": sorted_keys, "order": order,
        }

    def lookup_cache(self, table_name: str, col: str, key_val) -> Optional[Dict[str, object]]:
        entry = self._prefetch_cache.get((table_name, col))
        if entry is None:
            raise KeyError(f"no prefetch cache for ({table_name}, {col})")
        self.charge_statement()
        keys = entry["keys"]
        lo = np.searchsorted(keys, key_val, side="left")
        if lo < len(keys) and keys[lo] == key_val:
            return entry["table"].row(int(entry["order"][lo]))
        return None

    def lookup_cache_all(self, table_name: str, col: str, key_val) -> list:
        entry = self._prefetch_cache.get((table_name, col))
        if entry is None:
            raise KeyError(f"no prefetch cache for ({table_name}, {col})")
        self.charge_statement()
        keys = entry["keys"]
        lo = np.searchsorted(keys, key_val, side="left")
        hi = np.searchsorted(keys, key_val, side="right")
        t = entry["table"]
        return [t.row(int(entry["order"][i])) for i in range(lo, hi)]

    def has_cache(self, table_name: str, col: str) -> bool:
        return (table_name, col) in self._prefetch_cache

    def reset(self) -> None:
        self.clock = 0.0
        self._orm_cache.clear()
        self._prefetch_cache.clear()
        self.query_log.clear()
        self.n_queries = 0
        self.n_round_trips = 0
