"""Request routing and deadline-driven batch formation.

The cluster front door is two small, deterministic policies:

:class:`Router`
    Hashes each ``(program, bindings)`` request to a worker. Programs with
    a declared **affinity parameter** route by that binding's integer
    identity (``int(value) % n_workers`` — the same modulo hash the
    :class:`~repro.cluster.partition.Partitioner` places rows with, so a
    request lands on the worker whose shard owns the rows it will touch,
    and a skewed key distribution produces a measurably hot worker for
    ``triage()`` to flag). Everything else routes by a stable content hash
    of the bindings, spreading uniform traffic evenly.

:class:`BatchFormer`
    Coalesces routed requests into dynamic batches under a latency
    deadline, replacing fixed-size batching: per ``(worker, program)``
    queue, a batch flushes when it reaches ``max_batch`` ("full") or when
    its OLDEST request has waited ``deadline_s`` ("deadline"). With all
    requests arriving at once (the default), every queue flushes in
    max-batch-sized runs immediately — the deadline knob matters when an
    arrival process is given, where sparse traffic flushes small batches
    at the deadline and bursts flush full ones early. The formed batch
    sizes are what the batch-aware cost model then actually sees: each
    worker publishes its observed formed size into its serving context, so
    the batch-64 plan flip happens because the former MADE batches of 64,
    not because a config said so.

Both policies are pure functions of their inputs (no wall clock, no
randomness) — the cluster's bit-identity guarantee extends to WHICH
batches form, in WHAT order, on WHICH worker.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

__all__ = ["Request", "FormedBatch", "Router", "BatchFormer",
           "uniform_arrivals"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One routed request: original stream position + routing decision."""

    index: int                      # position in the request stream
    program: str
    params: Mapping[str, object]
    worker: int
    arrival_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class FormedBatch:
    """A flushed batch: same program, same worker, formed at ``flush_s``."""

    worker: int
    program: str
    requests: Tuple[Request, ...]
    flush_s: float
    reason: str                     # "full" | "deadline"

    @property
    def size(self) -> int:
        return len(self.requests)


class Router:
    """Deterministic (program, bindings) → worker placement."""

    def __init__(self, n_workers: int,
                 affinity: Optional[Mapping[str, str]] = None):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        self.n_workers = n_workers
        # program -> parameter name whose binding carries shard identity
        self.affinity: Dict[str, str] = dict(affinity or {})
        self.routed = 0
        self.affinity_routed = 0
        self.worker_counts = [0] * n_workers

    def route(self, program: str, params: Mapping[str, object]) -> int:
        self.routed += 1
        w = self._affinity_worker(program, params)
        if w is None:
            w = self._hash_worker(program, params)
        else:
            self.affinity_routed += 1
        self.worker_counts[w] += 1
        return w

    def _affinity_worker(self, program: str,
                         params: Mapping[str, object]) -> Optional[int]:
        pname = self.affinity.get(program)
        if pname is None or pname not in params:
            return None
        v = params[pname]
        if isinstance(v, (list, tuple)):
            if not v:
                return None
            v = v[0]
        try:
            return int(v) % self.n_workers
        except (TypeError, ValueError):
            return None

    def _hash_worker(self, program: str,
                     params: Mapping[str, object]) -> int:
        try:
            ident = repr((program, tuple(sorted(
                (k, tuple(v) if isinstance(v, list) else v)
                for k, v in params.items()))))
        except TypeError:
            ident = repr((program, sorted(params)))
        return zlib.crc32(ident.encode()) % self.n_workers

    def skew(self) -> float:
        """Max worker share relative to a perfectly even split (1.0 =
        uniform, ``n_workers`` = everything on one worker)."""
        if not self.routed:
            return 1.0
        return max(self.worker_counts) * self.n_workers / self.routed

    def stats_dict(self) -> Dict[str, object]:
        return {"routed": self.routed,
                "affinity_routed": self.affinity_routed,
                "worker_counts": list(self.worker_counts),
                "skew": self.skew()}


class BatchFormer:
    """Deadline-driven dynamic batching over a routed request stream."""

    def __init__(self, deadline_s: float = 0.01, max_batch: int = 64):
        if deadline_s < 0:
            raise ValueError("deadline_s must be >= 0")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.deadline_s = deadline_s
        self.max_batch = max_batch
        self.flushes_full = 0
        self.flushes_deadline = 0

    def form(self, requests: Sequence[Request]) -> List[FormedBatch]:
        """Replay the arrival process and return every flushed batch, in
        flush order (ties broken by (worker, program) for determinism)."""
        queues: Dict[Tuple[int, str], List[Request]] = {}
        out: List[FormedBatch] = []

        def flush(key: Tuple[int, str], t: float, reason: str) -> None:
            q = queues.pop(key)
            out.append(FormedBatch(key[0], key[1], tuple(q), t, reason))
            if reason == "full":
                self.flushes_full += 1
            else:
                self.flushes_deadline += 1

        for r in sorted(requests, key=lambda r: (r.arrival_s, r.index)):
            # deadline-expire every queue whose oldest member would wait
            # past the deadline before this arrival lands
            for key in sorted(k for k, q in queues.items()
                              if q[0].arrival_s + self.deadline_s
                              < r.arrival_s):
                flush(key, queues[key][0].arrival_s + self.deadline_s,
                      "deadline")
            key = (r.worker, r.program)
            queues.setdefault(key, []).append(r)
            if len(queues[key]) >= self.max_batch:
                flush(key, r.arrival_s, "full")
        for key in sorted(queues):
            flush(key, queues[key][0].arrival_s + self.deadline_s,
                  "deadline")
        out.sort(key=lambda b: (b.flush_s, b.worker, b.program))
        return out

    def stats_dict(self) -> Dict[str, object]:
        return {"deadline_s": self.deadline_s, "max_batch": self.max_batch,
                "flushes_full": self.flushes_full,
                "flushes_deadline": self.flushes_deadline}


def uniform_arrivals(n: int, rps: float) -> List[float]:
    """Evenly spaced arrival times for ``n`` requests at ``rps`` req/s —
    the deterministic arrival process benches and examples use to exercise
    the deadline (all-at-once arrivals always flush full batches)."""
    if rps <= 0:
        raise ValueError("rps must be > 0")
    return [i / rps for i in range(n)]
