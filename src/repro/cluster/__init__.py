"""Sharded multi-worker serving cluster.

The serving tier above :mod:`repro.runtime`: a
:class:`~repro.cluster.database.ShardedDatabase` partitions the data plane
across N shards with bit-exact scatter-gather merges, and a
:class:`~repro.cluster.runtime.ClusterRuntime` fronts N
:class:`~repro.cluster.runtime.ShardWorker`\\ s with a deterministic
:class:`~repro.cluster.router.Router` and a deadline-driven
:class:`~repro.cluster.router.BatchFormer`. See each module's docstring
for the invariants; the headline one: cluster serving is bit-identical to
single-worker serving for every example program.
"""

from .database import ShardedDatabase
from .partition import GPOS, Partitioner, strip_gpos
from .router import BatchFormer, FormedBatch, Request, Router, \
    uniform_arrivals
from .runtime import ClusterRuntime, ShardWorker

__all__ = [
    "ShardedDatabase", "Partitioner", "GPOS", "strip_gpos",
    "Router", "BatchFormer", "Request", "FormedBatch", "uniform_arrivals",
    "ClusterRuntime", "ShardWorker",
]
