"""Sharded database coordinator: scatter-gather execution over N shards.

``ShardedDatabase`` is a drop-in :class:`~repro.relational.database
.DatabaseServer`: sessions, client environments, the interpreter's direct
table reads/writes, and the cost model all work against it unchanged. Under
the hood each table lives horizontally partitioned (or replicated) across N
plain per-shard ``DatabaseServer`` instances (see
:class:`~repro.cluster.partition.Partitioner`), and ``run()`` executes
query sites shard-parallel where a bit-exact merge exists:

  * **pruned** — an equality predicate on the partition key routes the
    whole query to the one shard owning those rows (all matching rows are
    colocated, in original relative order — no merge needed);
  * **replicated** — a query over replicated tables only runs on one
    replica (every replica is a full copy);
  * **ordered merge** — row-preserving shapes (Scan/Select/Project chains,
    and joins of a partitioned side against a replicated side) execute on
    every shard, partials are concatenated and stable-sorted by the hidden
    ``__gpos`` provenance column: exactly the unsharded row order;
  * **partial-aggregate combine** — aggregates whose fold is exact under
    re-association (count, min, max, and sum/avg over integer columns —
    avg ships as a (sum, count) partial-state pair with one final
    division) run per shard and combine; float sums/avgs are NOT combined
    (float addition is order-sensitive) and fall back to gathering the
    child;
  * **gather** — anything else executes against the coordinator's merged
    views, which are themselves rebuilt from the shards — always correct,
    never shard-parallel.

**Global statistics.** ``analyze()`` computes statistics over the MERGED
table content, so ``estimate()`` (inherited unchanged) returns exactly the
numbers an unsharded server would — the optimizer picks the same plans,
and drift detection fires on the same evidence. Version counters
(``stats_version`` / ``table_version`` / ``data_version``) are derived as
sums over the per-shard counters: a write or ``analyze()`` on ONE shard —
even one issued directly against the shard, bypassing the coordinator —
moves the coordinator's epoch, so epoch-keyed site caches self-invalidate
with per-shard precision and the bit-identity guarantee survives
mid-stream writes.

**Writes.** ``add_table``/``replace_table`` (the interpreter's UPDATE path
funnels through ``add_table``) re-partition the written rows to their
owning shards; merged views are rebuilt lazily when any shard's data
version moves.

Simulated timing: a scattered site charges the slowest shard's server time
(shards work in parallel) plus a merge pass over the gathered rows; a
pruned site charges only its one shard. Output bit-identity never depends
on the clock — the non-negotiable invariant is on results and database
state, asserted program-by-program in ``tests/test_cluster.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..obs.trace import NOOP_TRACER
from ..relational.algebra import (Aggregate, AggSpec, BoolOp, Cmp, Col, Join,
                                  Limit, Lit, OrderBy, Param, Project, Query,
                                  Scan, Select, scan_tables)
from ..relational.database import DatabaseServer, ServerModel
from ..relational.table import Field, Schema, Table
from .partition import GPOS, Partitioner, strip_gpos

__all__ = ["ShardedDatabase"]

# combine function per aggregate: how per-shard partials fold into the
# global value (count partials ADD; min/max fold through themselves)
_COMBINE_FUNC = {"count": "sum", "sum": "sum", "min": "min", "max": "max"}


class _GatheredView:
    """A one-table shim database for applying a non-distributable head
    node (OrderBy / Limit / Aggregate) locally over an already-gathered
    child result — the head executes through the SAME node code as the
    unsharded server, so its output is bit-identical by construction."""

    def __init__(self, t: Table):
        self._t = t

    def table(self, name: str) -> Table:
        return self._t


class ShardedDatabase(DatabaseServer):
    """N-shard coordinator that is itself a ``DatabaseServer``."""

    def __init__(self, tables: Dict[str, Table], *, n_shards: int,
                 keys: Optional[Mapping[str, str]] = None,
                 model: ServerModel = ServerModel(),
                 merge_rows_per_s: Optional[float] = None,
                 tracer=None, stats_config=None):
        # base init computes GLOBAL stats over the unsharded tables and
        # calls the (guarded) analyze(); cluster structures come after
        self._cluster_ready = False
        super().__init__(tables, model, stats_config=stats_config)
        self.tracer = tracer if tracer is not None else NOOP_TRACER
        self.partitioner = Partitioner(n_shards, keys)
        self.n_shards = n_shards
        self.merge_rows_per_s = merge_rows_per_s or model.agg_rows_per_s
        # shards share the coordinator's histogram config: merging
        # per-shard histograms requires identical bucket/MCV/sketch shapes
        self.shards: List[DatabaseServer] = [
            DatabaseServer({}, model, stats_config=self.stats_config)
            for _ in range(n_shards)]
        for t in self.tables.values():
            for k, part in enumerate(self.partitioner.shard_tables(t)):
                self.shards[k].add_table(part)
        # per-table shard data-version tuple at last merged-view rebuild;
        # a direct write to any ONE shard invalidates the view lazily
        self._merged_sync: Dict[str, Tuple[int, ...]] = {
            name: self._shard_data_versions(name) for name in self.tables}
        # telemetry: how each query site actually executed
        self.pruned_queries = 0
        self.replicated_queries = 0
        self.scattered_queries = 0
        self.gathered_queries = 0
        self.shard_queries = [0] * n_shards     # per-shard routed load
        self._cluster_ready = True

    @classmethod
    def shard(cls, db: DatabaseServer, n_shards: int,
              keys: Optional[Mapping[str, str]] = None,
              **kw) -> "ShardedDatabase":
        """Partition an existing server's tables across ``n_shards``."""
        return cls(db.tables, n_shards=n_shards, keys=keys,
                   model=db.model, **kw)

    # ------------------------------------------------------ derived versions
    def _shard_data_versions(self, name: str) -> Tuple[int, ...]:
        return tuple(s.data_version(name) for s in self.shards)

    @property
    def stats_version(self) -> int:
        if not self._cluster_ready:
            return self._stats_version
        return sum(s.stats_version for s in self.shards)

    def table_version(self, name: str) -> int:
        if not self._cluster_ready:
            return super().table_version(name)
        return sum(s.table_version(name) for s in self.shards)

    def data_version(self, name: str) -> int:
        if not self._cluster_ready:
            return super().data_version(name)
        return sum(s.data_version(name) for s in self.shards)

    def shard_versions(self, name: str) -> Tuple[Tuple[int, int], ...]:
        """Per-shard (table_version, data_version) for the named table —
        the fine-grained view behind the summed coordinator epoch."""
        return tuple((s.table_version(name), s.data_version(name))
                     for s in self.shards)

    # -------------------------------------------------------- merged views
    def _partitioned(self, name: str) -> bool:
        """Partitioned IN PRACTICE: a declared key column that the current
        table actually has. A program installing a fresh table under a
        partitioned name without the key column gets it replicated (see
        ``Partitioner.shard_assignment``), and classification must agree —
        its shard copies carry no ``__gpos``, so an ordered merge would
        have nothing to order by."""
        key = self.partitioner.key_column(name)
        t = self.tables.get(name)
        return key is not None and t is not None and t.schema.has(key)

    def table(self, name: str) -> Table:
        if self._cluster_ready:
            self._refresh_merged(name)
        return self.tables[name]

    def _refresh_merged(self, name: str) -> None:
        cur = self._shard_data_versions(name)
        if self._merged_sync.get(name) == cur:
            return
        self.tables[name] = self._rebuild_merged(name)
        self._merged_sync[name] = cur

    def _rebuild_merged(self, name: str) -> Table:
        parts = [s.table(name) for s in self.shards]
        if self.partitioner.key_column(name) is None \
                or not parts[0].schema.has(self.partitioner.key_column(name)):
            # replicated (declared, or in practice — the key column is
            # absent so shard_tables stored full copies): shard 0 is the
            # canonical replica
            return strip_gpos(parts[0])
        stripped = [strip_gpos(p) for p in parts]
        merged = stripped[0]
        for p in stripped[1:]:
            merged = merged.concat_rows(p)
        if all(p.schema.has(GPOS) for p in parts):
            g = np.concatenate([np.asarray(p.column(GPOS)) for p in parts]) \
                if merged.nrows else np.asarray([], dtype=np.int64)
            if len(np.unique(g)) == len(g):
                # valid provenance: restore the exact global row order
                return merged.take(np.argsort(g, kind="stable"))
        # provenance missing or inconsistent (a shard was replaced
        # directly): shard-order concatenation defines the global order
        return merged

    # --------------------------------------------------------------- writes
    def add_table(self, t: Table) -> None:
        if not self._cluster_ready:
            return super().add_table(t)
        self.tables[t.name] = t
        self._stats[t.name] = self._compute_stats(t)
        for k, part in enumerate(self.partitioner.shard_tables(t)):
            self.shards[k].add_table(part)
        self._merged_sync[t.name] = self._shard_data_versions(t.name)

    def replace_table(self, t: Table) -> None:
        if not self._cluster_ready:
            return super().replace_table(t)
        # bulk load without ANALYZE: statistics stay stale, data moves
        self.tables[t.name] = t
        for k, part in enumerate(self.partitioner.shard_tables(t)):
            self.shards[k].replace_table(part)
        self._merged_sync[t.name] = self._shard_data_versions(t.name)

    def analyze(self, *tables: str,
                columns: Optional[Tuple[str, ...]] = None) -> int:
        if not self._cluster_ready:
            return super().analyze(*tables, columns=columns)
        names = tables or tuple(self.tables)
        for name in names:
            # GLOBAL statistics over the merged content: estimate() stays
            # bit-identical to an unsharded server's
            self._refresh_merged(name)
            for s in self.shards:
                s.analyze(name, columns=columns)
            prev = self._stats.get(name) if columns is not None else None
            if self._partitioned(name):
                self._stats[name] = self._merged_stats(
                    name, columns=columns, prev=prev)
            else:
                self._stats[name] = self._compute_stats(
                    self.tables[name], columns=columns, prev=prev)
        return self.stats_version

    def _merged_stats(self, name: str,
                      columns: Optional[Tuple[str, ...]] = None,
                      prev=None):
        """Coordinator statistics for a PARTITIONED table: scalars over the
        merged content, histograms by the lossless merge of the freshly
        analyzed per-shard histograms — ``merge_histograms`` is associative
        and the bucket/MCV/sketch derivation deterministic, so every merged
        histogram is bit-for-bit what a direct build over the merged rows
        produces (the reconciliation property ``tests/test_stats.py``
        asserts). The shards' hidden ``__gpos`` provenance column never has
        a coordinator-side field, so it drops out here by construction."""
        from ..stats.histogram import merge_all
        t = self.tables[name]
        # columns=() computes the scalar statistics without building (or
        # charging histogram_builds for) any coordinator-side histogram
        st = self._compute_stats(t, columns=())
        if not self.stats_config.histograms:
            return st
        hists = {}
        for f in t.schema.fields:
            if columns is not None and f.name not in columns:
                carried = prev.hist(f.name) if prev is not None else None
                if carried is not None:
                    hists[f.name] = carried
                continue
            shard_hists = [h for h in (s._stats[name].hist(f.name)
                                       for s in self.shards) if h is not None]
            if shard_hists:
                hists[f.name] = merge_all(shard_hists)
        return dataclasses.replace(st, hists=hists)

    # ------------------------------------------------------------ execution
    def run(self, query: Query, params: Optional[Mapping[str, object]] = None
            ) -> Tuple[Table, float, float]:
        if not self._cluster_ready:
            return super().run(query, params)
        tables = scan_tables(query)
        for t in tables:
            self._refresh_merged(t)
        parted = [t for t in tables if self._partitioned(t)]
        if not parted:
            self.replicated_queries += 1
            self.shard_queries[0] += 1
            result, first, last = self.shards[0].run(query, params)
            return strip_gpos(result), first, last
        k = self._prune_shard(query, params, parted)
        if k is not None:
            self.pruned_queries += 1
            self.shard_queries[k] += 1
            if self.tracer.enabled:
                self.tracer.event("scatter-gather", sql=query.sql(),
                                  mode="pruned", shard=k)
            result, first, last = self.shards[k].run(query, params)
            return strip_gpos(result), first, last
        kind = self._classify(query)
        if kind in ("part", "agg", "gather-child"):
            return self._scatter(query, params, kind)
        # no exact distributed merge: execute on the merged views — the
        # unsharded code path, charged at unsharded (single-node) cost
        self.gathered_queries += 1
        return super().run(query, params)

    # ----------------------------------------------------- merge planning
    def _classify(self, node: Query) -> Optional[str]:
        """How this subtree distributes:

        ``"repl"``  — touches only replicated tables (any replica answers);
        ``"part"``  — per-shard partials ordered-merge exactly by __gpos;
        ``"agg"``   — Aggregate over a "part" child with exactly-combinable
                      folds (partial-aggregate combine);
        ``"gather-child"`` — head node applies locally over its gathered
                      "part" child;
        ``None``    — no exact distributed execution (gather fallback).
        """
        if isinstance(node, Scan):
            return "part" if self._partitioned(node.table) else "repl"
        if isinstance(node, (Select, Project)):
            c = self._classify(node.child)
            return c if c in ("part", "repl") else None
        if isinstance(node, Join):
            left = self._classify(node.left)
            right = self._classify(node.right)
            if left == "repl" and right == "repl":
                return "repl"
            if left == "part" and right == "repl":
                # right is a full copy on every shard: each left row finds
                # ALL its matches on its own shard, in the same order the
                # unsharded join emits them
                return "part"
            return None
        if isinstance(node, Aggregate):
            c = self._classify(node.child)
            if c == "repl":
                return "repl"
            if c == "part":
                return "agg" if self._combinable(node) else "gather-child"
            return None
        if isinstance(node, (OrderBy, Limit)):
            c = self._classify(node.child)
            if c == "repl":
                return "repl"
            if c == "part":
                return "gather-child"
            return None
        return None

    def _combinable(self, node: Aggregate) -> bool:
        """True when every fold is exact under re-association: count / min /
        max always, sum and avg only over integer columns — float addition
        is order-sensitive, and bit-identity outranks shard-parallel sums.
        avg distributes as (sum, count) partial states with one final
        division (see :meth:`_scatter_agg`), so its guard is sum's."""
        for a in node.aggs:
            if a.func in ("count", "min", "max"):
                continue
            if a.func not in ("sum", "avg"):
                return False
            try:
                f = node.child.output_schema(self).field(a.col)
            except Exception:
                return False
            if np.dtype(f.dtype).kind not in "iu":
                return False
        return True

    # ---------------------------------------------------------- prune path
    def _prune_shard(self, query: Query, params, parted: Sequence[str]
                     ) -> Optional[int]:
        """The single shard owning every row the query can touch, or None.

        Sound only when exactly one partitioned table is involved and EVERY
        scan of it sits under Select predicates pinning the partition key
        to one value (conjunct ``key == literal/param``). Predicates are
        only collected through row-preserving ancestors (Select / Project /
        OrderBy) — a Limit or Aggregate between the Select and the Scan
        would make per-shard execution observe a different row set, and a
        Join's output columns may not be the scan's, so collection restarts
        below those nodes."""
        if len(parted) != 1:
            return None
        tname = parted[0]
        key_col = self.partitioner.key_column(tname)
        values: List[object] = []
        ok = [True]

        def eq_value(preds) -> Optional[object]:
            for p in preds:
                if not (isinstance(p, Cmp) and p.op == "=="):
                    continue
                for a, b in ((p.left, p.right), (p.right, p.left)):
                    if isinstance(a, Col) and a.name == key_col:
                        if isinstance(b, Lit):
                            return b.value
                        if isinstance(b, Param) and params \
                                and b.name in params:
                            return params[b.name]
            return None

        def conjuncts(pred) -> List:
            if isinstance(pred, BoolOp) and pred.op == "and":
                return conjuncts(pred.left) + conjuncts(pred.right)
            return [pred]

        def walk(node: Query, preds: List) -> None:
            if not ok[0]:
                return
            if isinstance(node, Scan):
                if node.table != tname:
                    return
                v = eq_value(preds)
                if v is None:
                    ok[0] = False
                else:
                    values.append(v)
                return
            if isinstance(node, Select):
                walk(node.child, preds + conjuncts(node.pred))
                return
            if isinstance(node, (Project, OrderBy)):
                walk(node.child, preds)
                return
            # Join / Aggregate / Limit: outer predicates don't push through
            for c in node.children():
                walk(c, [])

        walk(query, [])
        if not ok[0] or not values:
            return None
        shards = {self.partitioner.shard_of(tname, v) for v in values}
        if len(shards) != 1 or None in shards:
            return None
        return shards.pop()

    # -------------------------------------------------------- scatter path
    def _retain_gpos(self, node: Query) -> Query:
        """Rewrite the partitioned spine of a "part" subtree so every
        Project keeps the ``__gpos`` provenance column flowing upward."""
        if isinstance(node, Project):
            child = self._retain_gpos(node.child)
            cols = node.cols if GPOS in node.cols else node.cols + (GPOS,)
            return Project(cols, child, node.computed)
        if isinstance(node, Select):
            return Select(node.pred, self._retain_gpos(node.child))
        if isinstance(node, Join):
            # only the left (partitioned) side carries provenance
            return dataclasses.replace(node, left=self._retain_gpos(node.left))
        return node

    def _scatter_rows(self, node: Query, params
                      ) -> Tuple[Table, float, float]:
        """Execute a "part" subtree on every shard and ordered-merge the
        partials by ``__gpos`` — the exact unsharded row order."""
        rewritten = self._retain_gpos(node)
        parts, last = [], 0.0
        for k, s in enumerate(self.shards):
            r, _, l = s.run(rewritten, params)
            self.shard_queries[k] += 1
            parts.append(r)
            last = max(last, l)
        merged = parts[0]
        for p in parts[1:]:
            merged = merged.concat_rows(p)
        order = np.argsort(np.asarray(merged.column(GPOS)), kind="stable") \
            if merged.nrows else np.asarray([], dtype=np.int64)
        merged = strip_gpos(merged.take(order))
        # shards work in parallel: the gather blocks on the slowest shard,
        # then pays one merge pass over the gathered rows
        t = last + merged.nrows / self.merge_rows_per_s
        return merged, t, t

    @staticmethod
    def _partial_aggs(node: Aggregate
                      ) -> Tuple[Tuple[AggSpec, ...], Tuple[AggSpec, ...]]:
        """(per-shard probe aggs, coordinator combine aggs). An avg fold
        has no associative partial of its own, so it ships as a (sum,
        count) partial-state pair — ``out__avs`` / ``out__avn`` — whose
        partials ADD; :meth:`_finalize_avg` performs the single final
        division."""
        probe, combine = [], []
        for a in node.aggs:
            if a.func == "avg":
                probe.append(AggSpec("sum", a.col, a.out + "__avs"))
                probe.append(AggSpec("count", None, a.out + "__avn"))
                combine.append(AggSpec("sum", a.out + "__avs",
                                       a.out + "__avs"))
                combine.append(AggSpec("sum", a.out + "__avn",
                                       a.out + "__avn"))
            else:
                probe.append(a)
                combine.append(AggSpec(_COMBINE_FUNC[a.func], a.out, a.out))
        return tuple(probe), tuple(combine)

    def _finalize_avg(self, node: Aggregate, result: Table) -> Table:
        """Collapse each avg fold's combined (sum, count) state into the
        output column, reproducing the unsharded grouped-avg math —
        ``float32(s) / max(float32(c), 1)`` — with ONE division after all
        partials have been added."""
        import jax.numpy as jnp
        fields, cols = [], {}
        for g in node.group_by:
            fields.append(result.schema.field(g))
            cols[g] = np.asarray(result.column(g))
        for a in node.aggs:
            if a.func == "avg":
                s = jnp.asarray(result.column(a.out + "__avs"),
                                dtype=jnp.float32)
                c = jnp.asarray(result.column(a.out + "__avn"),
                                dtype=jnp.float32)
                fields.append(Field(a.out, "float32"))
                cols[a.out] = s / jnp.maximum(c, 1.0)
            else:
                fields.append(result.schema.field(a.out))
                cols[a.out] = np.asarray(result.column(a.out))
        return Table("agg", Schema(tuple(fields)), cols)

    def _scatter_agg(self, node: Aggregate, params
                     ) -> Tuple[Table, float, float]:
        """Partial-aggregate combine: run the probe Aggregate per shard,
        fold the partials (count/sum/avg-states add, min/max fold) — exact
        for the folds :meth:`_combinable` admits."""
        probe_aggs, combine_aggs = self._partial_aggs(node)
        probe = Aggregate(node.group_by, probe_aggs, node.child) \
            if node.group_by else Aggregate(
                (), probe_aggs + (AggSpec("count", None, "__pn"),),
                node.child)
        parts, last = [], 0.0
        for k, s in enumerate(self.shards):
            r, _, l = s.run(probe, params)
            self.shard_queries[k] += 1
            parts.append(r)
            last = max(last, l)
        if node.group_by:
            merged = parts[0]
            for p in parts[1:]:
                merged = merged.concat_rows(p)
            combine = Aggregate(node.group_by, combine_aggs,
                                Scan("__partials"))
            result = combine.execute(_GatheredView(merged), None)
            if any(a.func == "avg" for a in node.aggs):
                result = self._finalize_avg(node, result)
        else:
            result = self._combine_global(node, parts)
        t = last + max(1, result.nrows) / self.merge_rows_per_s
        return result, t, t

    def _combine_global(self, node: Aggregate,
                        parts: Sequence[Table]) -> Table:
        """Fold ungrouped per-shard partials, mirroring
        ``Aggregate._global``'s field assembly exactly (dtypes included).
        Empty shards are excluded from min/max folds via the piggybacked
        ``__pn`` partial row count."""
        import jax.numpy as jnp
        live = [p for p in parts if int(np.asarray(p.column("__pn"))[0])]
        fields, cols = [], {}
        fold = {"sum": jnp.add, "count": jnp.add,
                "min": jnp.minimum, "max": jnp.maximum}
        for a in node.aggs:
            if a.func == "count":
                val = sum(int(np.asarray(p.column(a.out))[0]) for p in parts)
                dt = "int32"
            elif not live:
                val, dt = 0, "float32"   # the unsharded empty-input branch
            elif a.func == "avg":
                # (sum, count) partial state: integer partial sums and row
                # counts add exactly. jnp.mean lowers its division to a
                # reciprocal multiply, so the single final fold must too —
                # a true divide rounds differently (499.5 vs 499.50003).
                s = sum(int(np.asarray(p.column(a.out + "__avs"))[0])
                        for p in live)
                n = sum(int(np.asarray(p.column(a.out + "__avn"))[0])
                        for p in live)
                val = jnp.float32(s) * (jnp.float32(1)
                                        / jnp.float32(max(n, 1)))
                dt = "float32"
            else:
                vals = [p.column(a.out)[0] for p in live]
                val = vals[0]
                for v in vals[1:]:
                    val = fold[a.func](val, v)
                dt = str(np.asarray(val).dtype)
            fields.append(Field(a.out, dt))
            cols[a.out] = np.asarray(
                [val], dtype=np.dtype(dt) if np.dtype(dt).itemsize < 8
                else np.dtype(dt.replace("64", "32")))
        return Table("agg", Schema(tuple(fields)), cols)

    def _scatter(self, query: Query, params, kind: str
                 ) -> Tuple[Table, float, float]:
        self.scattered_queries += 1
        if self.tracer.enabled:
            self.tracer.event("scatter-gather", sql=query.sql(), mode=kind,
                              shards=self.n_shards)
        if kind == "part":
            return self._scatter_rows(query, params)
        if kind == "agg":
            return self._scatter_agg(query, params)
        # gather-child: distribute the child, apply the head node locally
        # through the unsharded node code over the gathered (exact-order)
        # child result
        gathered, _, t = self._scatter_rows(query.child, params)
        head = dataclasses.replace(query, child=Scan(gathered.name))
        result = head.execute(_GatheredView(gathered), params)
        m = self.model
        if isinstance(query, OrderBy):
            t += gathered.nrows / m.sort_rows_per_s
        elif isinstance(query, Aggregate):
            t += gathered.nrows / m.agg_rows_per_s
        return strip_gpos(result), t, t

    # ------------------------------------------------------------ telemetry
    def stats_dict(self) -> Dict[str, object]:
        return {
            "n_shards": self.n_shards,
            "pruned_queries": self.pruned_queries,
            "replicated_queries": self.replicated_queries,
            "scattered_queries": self.scattered_queries,
            "gathered_queries": self.gathered_queries,
            "shard_queries": list(self.shard_queries),
        }

    def describe(self) -> str:
        s = self.stats_dict()
        return (f"ShardedDatabase[{self.n_shards} shard(s)]: "
                f"{s['pruned_queries']} pruned, "
                f"{s['scattered_queries']} scattered, "
                f"{s['replicated_queries']} replicated, "
                f"{s['gathered_queries']} gathered "
                f"({self.partitioner.describe()})")
