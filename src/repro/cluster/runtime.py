"""Sharded multi-worker serving: shard workers behind a router + former.

``ClusterRuntime`` splits serving across N :class:`ShardWorker`\\ s — one
per shard of a :class:`~repro.cluster.database.ShardedDatabase`. Each
worker is a full :class:`~repro.runtime.serving.ServingRuntime`: its own
:class:`~repro.api.session.CobraSession`, its own byte-budgeted
:class:`~repro.runtime.sitecache.SiteCache` (optionally with an oversize
spill tier), its own :class:`~repro.runtime.feedback.FeedbackController`.
What they share is the data plane (the ONE sharded database — so a write
or ``analyze()`` on any worker moves the coordinator's per-shard epochs
and every worker's epoch-keyed cached sites for exactly the affected
tables self-invalidate) and, when configured, one disk-backed
:class:`~repro.runtime.store.PlanStore` — a plan search won on one worker
warm-starts the identical compile on every other, because the shared
database gives them byte-equal stats fingerprints.

The request path::

    serve(requests)
      → Router: (program, bindings) → worker          [affinity or hash]
      → BatchFormer: deadline/max-batch flushes        [dynamic batches]
      → ShardWorker.serve_formed(batch)                [full serving path]
      → responses reassembled in request order

Each worker feeds its OBSERVED formed-batch sizes back into its serving
context: when the running mean drifts past ``publish_threshold`` from the
context's current ``batch_size``, the worker republishes the context and
recompiles — the batch-aware cost model prices exactly the batches the
router forms, so the batch-64 plan flip emerges from deadline-driven
formation rather than a fixed-size config.

**Bit-identity.** For every example program, ``ClusterRuntime.serve()``
returns request-for-request the same outputs (and leaves the same database
state) as a single-worker ``ServingRuntime.serve()`` over the same stream
— including under mid-stream writes, ``analyze()``, and drift-triggered
plan swaps. The pieces: the sharded database's scatter-gather merges are
bit-exact (``tests/test_cluster.py`` asserts per query shape); plan swaps
only exchange semantics-preserving rewrites; and ordering of mutations is
preserved per affinity key — same-key requests route to the same worker's
FIFO queue, while cross-key writes touch different shard rows and
commute. Simulated CLOCKS legitimately differ (that is the point: pruned
sites charge one shard, scatters charge the slowest shard plus a merge);
identity is over results and data.

Timing is discrete-event: worker clocks advance per formed batch
(``busy[w] = max(busy[w], flush_s) + batch.simulated_s``), the cluster
makespan is the slowest worker's clock, and per-request latency histograms
(queueing + service) land in the cluster registry.
"""

from __future__ import annotations

import dataclasses
import os
from collections import deque
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from ..api.cache import program_fingerprint
from ..api.session import CobraSession
from ..core.regions import Program
from ..obs.metrics import (MetricsRegistry, combine_snapshots,
                           merge_snapshots, registry_counter)
from ..obs.trace import NOOP_TRACER
from ..runtime.serving import ServingRuntime
from ..runtime.sitecache import SiteCache
from .database import ShardedDatabase
from .partition import Partitioner
from .router import BatchFormer, FormedBatch, Request, Router

__all__ = ["ShardWorker", "ClusterRuntime"]


class ShardWorker(ServingRuntime):
    """A ServingRuntime that serves router-formed batches and publishes the
    batch sizes it actually observes into its serving context."""

    batch_publishes = registry_counter()
    bit_vetoes = registry_counter()

    def __init__(self, session, worker_id: int, *,
                 publish_threshold: float = 1.5,
                 bit_guard_swaps: bool = True, **kw):
        super().__init__(session, **kw)
        if publish_threshold < 1.0:
            raise ValueError("publish_threshold must be >= 1.0")
        self.worker_id = worker_id
        self.publish_threshold = publish_threshold
        self.bit_guard_swaps = bit_guard_swaps
        self._formed_sizes: deque = deque(maxlen=32)
        self.batch_publishes = 0
        self.bit_vetoes = 0
        self._bit_guard = False

    def serve_formed(self, batch: FormedBatch):
        """Execute one formed batch through the full serving path; returns
        the BatchResult (results in the batch's request order)."""
        self._observe_formed(batch.size)
        return self.serve_batch(batch.program,
                                [dict(r.params) for r in batch.requests])

    def _observe_formed(self, size: int) -> None:
        self._formed_sizes.append(size)
        self.metrics.observe("formed_batch_size", size)
        mean = sum(self._formed_sizes) / len(self._formed_sizes)
        target = max(1, int(round(mean)))
        cur = self._base_context.batch_size
        ratio = max(target, cur) / max(1, min(target, cur))
        if ratio >= self.publish_threshold:
            # the router is forming materially different batches than the
            # context was costed for: republish and recompile, so the
            # batch-aware amortization prices the REAL batch size
            self._base_context = dataclasses.replace(
                self._base_context, batch_size=target)
            self.batch_size = target
            self.batch_publishes += 1
            self._bit_guard = True
            try:
                self._recompile_for_context()
            finally:
                self._bit_guard = False

    def _guarded_swap(self, name: str, new_exe) -> None:
        """The single-runtime guard plus, for PUBLISH-driven recompiles, a
        BIT-IDENTITY veto. Formed-size context publishes are a
        cluster-only mechanism — no single-worker baseline ever recompiles
        because a batch former changed its batch sizes — so a publish may
        propose plans a fixed-size runtime would never compile, and a
        proposal whose replayed outputs differ in even one bit from the
        incumbent's (e.g. a DB-side float32 SUM replacing a client-side
        float64 fold) is vetoed. Feedback-driven swaps (drift, published
        iteration stats) deliberately do NOT get the veto: they mirror the
        single-worker runtime's own recompile discipline decision-for-
        decision, which is what keeps cluster serving bit-identical to a
        single worker across those swaps. Mutating programs can't be
        replayed against the live database; they fall through to the base
        guard unchanged, exactly like the cost guard does.

        ``bit_guard_swaps=False`` turns the veto off: publishes then swap
        under the base cost guard alone, so a plan pair whose outputs
        differ in the float low bits (the SCAN batch-64 flip) can follow
        the formed sizes freely — at the price of the strict bit-identity
        guarantee across such flips."""
        old = self._executables.get(name)
        if self.bit_guard_swaps and self._bit_guard and old is not None \
                and program_fingerprint(
                    new_exe.program) != program_fingerprint(old.program):
            from ..runtime.batch import program_has_updates
            if not (program_has_updates(old.program)
                    or program_has_updates(new_exe.program)):
                # no observed bindings yet (a context publish can precede
                # the program's first request) → probe with the program's
                # defaults; bindings the program can't run without are
                # skipped rather than guessed
                bindings = list(self._recent.get(name, ())) or [{}]
                for b in bindings:
                    try:
                        o = old.run(**b).outputs
                        n = new_exe.run(**b).outputs
                    except Exception:
                        continue
                    if o != n:
                        self.bit_vetoes += 1
                        self.swaps_rejected += 1
                        return
        super()._guarded_swap(name, new_exe)


class ClusterRuntime:
    """N shard workers fronted by a router and a deadline batch former."""

    requests_served = registry_counter()
    batches_formed = registry_counter()
    serve_cycles = registry_counter()

    def __init__(self, db, *, n_workers: int,
                 partition_keys: Optional[Mapping[str, str]] = None,
                 affinity: Optional[Mapping[str, str]] = None,
                 deadline_s: float = 0.01, max_batch: int = 64,
                 store=None, catalog=None, config=None,
                 context=None, tracer=None,
                 site_cache_entries: int = 4096,
                 site_cache_max_bytes: Optional[int] = None,
                 site_cache_ttl_s: Optional[float] = None,
                 site_cache_spill_dir: Optional[str] = None,
                 entry_max_bytes: Optional[int] = None,
                 publish_threshold: float = 1.5,
                 bit_guard_swaps: bool = True,
                 initial_batch_size: Optional[int] = None,
                 **worker_kw):
        """``db`` is a :class:`ShardedDatabase` (``n_workers`` must match
        its shard count) or a plain ``DatabaseServer`` to shard here using
        ``partition_keys``. ``store`` (path or PlanStore) is coerced ONCE
        and shared by every worker. ``affinity`` maps program name → the
        parameter whose binding routes it (see :class:`Router`).
        ``initial_batch_size`` sets the batch size workers COMPILE for at
        registration (default ``max_batch``); the formed-size publishing
        then retargets it to whatever the former actually makes.
        Remaining keyword arguments pass through to each
        :class:`ShardWorker`."""
        if isinstance(db, ShardedDatabase):
            if db.n_shards != n_workers:
                raise ValueError(
                    f"db has {db.n_shards} shards but n_workers={n_workers}"
                    " — one worker per shard")
            self.db = db
        else:
            self.db = ShardedDatabase.shard(db, n_workers,
                                            keys=partition_keys,
                                            tracer=tracer)
        self.n_workers = n_workers
        self.tracer = tracer if tracer is not None else NOOP_TRACER
        self.metrics = MetricsRegistry()
        self.router = Router(n_workers, affinity)
        self.former = BatchFormer(deadline_s=deadline_s, max_batch=max_batch)
        if store is not None:
            from ..runtime.store import PlanStore
            store = PlanStore.coerce(store)
        self.store = store
        self.workers: List[ShardWorker] = []
        for w in range(n_workers):
            session = CobraSession(self.db, catalog=catalog, config=config,
                                   context=context, tracer=self.tracer)
            spill = None
            if site_cache_spill_dir is not None:
                spill = os.path.join(site_cache_spill_dir, f"w{w}")
            cache = SiteCache(ttl_s=site_cache_ttl_s,
                              max_entries=site_cache_entries,
                              max_bytes=site_cache_max_bytes,
                              entry_max_bytes=entry_max_bytes,
                              spill_dir=spill)
            self.workers.append(ShardWorker(
                session, w, publish_threshold=publish_threshold,
                bit_guard_swaps=bit_guard_swaps, store=store,
                batch_size=initial_batch_size or max_batch,
                site_cache=cache, context=context, tracer=self.tracer,
                **worker_kw))
        self._programs: Dict[str, Program] = {}
        self.requests_served = 0
        self.batches_formed = 0
        self.serve_cycles = 0
        self.last_makespan_s = 0.0
        self._busy = [0.0] * n_workers

    # ---------------------------------------------------------- registration
    def register(self, program: Program, name: Optional[str] = None,
                 affinity_param: Optional[str] = None):
        """Register a program on EVERY worker (the shared plan store makes
        the first worker's search warm-start the rest). ``affinity_param``
        optionally declares the binding the router should place it by."""
        name = name or program.name
        self._programs[name] = program
        if affinity_param is not None:
            self.router.affinity[name] = affinity_param
        exes = [w.register(program, name) for w in self.workers]
        return exes[0]

    # --------------------------------------------------------------- serving
    def serve(self, requests: Iterable[Tuple[str, Mapping[str, object]]],
              arrivals: Optional[Sequence[float]] = None) -> List[object]:
        """Route, form, and execute a request stream; returns one result
        per request in the original stream order. ``arrivals`` optionally
        gives each request's arrival time (default: all at t=0, which
        flushes full batches immediately)."""
        todo = list(requests)
        if arrivals is not None and len(arrivals) != len(todo):
            raise ValueError("arrivals must match the request count")
        routed = []
        for i, (name, params) in enumerate(todo):
            self.workers[0].executable(name)  # fail fast on unknown programs
            routed.append(Request(
                index=i, program=name, params=params,
                worker=self.router.route(name, params),
                arrival_s=arrivals[i] if arrivals is not None else 0.0))
        batches = self.former.form(routed)
        responses: List[Optional[object]] = [None] * len(todo)
        busy = list(self._busy)
        t0 = max(busy) if busy else 0.0
        with self.tracer.span("cluster_serve", n_requests=len(todo),
                              n_batches=len(batches)):
            for b in batches:
                worker = self.workers[b.worker]
                with self.tracer.span("flush", worker=b.worker,
                                      program=b.program, size=b.size,
                                      reason=b.reason):
                    result = worker.serve_formed(b)
                start = max(busy[b.worker], t0 + b.flush_s)
                busy[b.worker] = start + result.simulated_s
                self.metrics.observe("batch_service_s", result.simulated_s,
                                     worker=b.worker)
                for r, res in zip(b.requests, result.results):
                    responses[r.index] = res
                    self.metrics.observe(
                        "request_latency_s",
                        busy[b.worker] - (t0 + r.arrival_s))
                self.batches_formed += 1
        self._busy = busy
        self.requests_served += len(todo)
        self.serve_cycles += 1
        self.last_makespan_s = (max(busy) - t0) if todo else 0.0
        self.metrics.gauge("makespan_s", self.last_makespan_s)
        return responses

    # --------------------------------------------------------- observability
    def triage(self):
        """Cluster-wide triage: the union of every worker's fleet, ranked
        with per-shard request counts and hot-shard skew folded in."""
        from ..obs.triage import triage_cluster
        return triage_cluster(self)

    def worker_dump(self, w: int) -> Dict[str, Dict]:
        """One worker's structured metrics dump: its serving, session, and
        feedback registries (plus site-cache gauges) under stable
        prefixes — the unit :func:`combine_snapshots` folds."""
        rt = self.workers[w]
        reg = MetricsRegistry()
        reg.ingest(rt.metrics.dump(), prefix="serving_")
        reg.ingest(rt.session.metrics.dump(), prefix="session_")
        if rt.feedback is not None:
            reg.ingest(rt.feedback.metrics.dump(), prefix="feedback_")
        reg.ingest(rt.site_cache.stats(), prefix="site_cache_")
        if rt.compiler is not None:
            reg.ingest(rt.compiler.metrics.dump(), prefix="compiled_")
        return reg.dump()

    def metrics_dump(self) -> List[Dict[str, Dict]]:
        """Per-worker structured dumps, in worker order."""
        return [self.worker_dump(w) for w in range(self.n_workers)]

    def metrics_snapshot(self) -> Dict[str, object]:
        """One flat snapshot: the cluster's own registry (router / former /
        shard-database stats ingested as gauges) plus the per-worker
        registries AGGREGATED via :func:`combine_snapshots` — counters in
        the ``workers_`` section are exact sums of the per-worker values."""
        self.metrics.ingest(self.router.stats_dict(), prefix="router_")
        self.metrics.ingest(self.former.stats_dict(), prefix="former_")
        self.metrics.ingest(self.db.stats_dict(), prefix="db_")
        combined = combine_snapshots(*self.metrics_dump())
        agg = MetricsRegistry()
        agg.ingest(combined)
        return merge_snapshots(cluster=self.metrics.snapshot(),
                               workers=agg.snapshot())

    def telemetry(self) -> Dict[str, object]:
        t = {"n_workers": self.n_workers,
             "requests_served": self.requests_served,
             "batches_formed": self.batches_formed,
             "makespan_s": self.last_makespan_s,
             "programs": sorted(self._programs)}
        t.update({f"router_{k}": v for k, v in
                  self.router.stats_dict().items()})
        t.update({f"former_{k}": v for k, v in
                  self.former.stats_dict().items()})
        t.update({f"db_{k}": v for k, v in self.db.stats_dict().items()})
        t["worker_requests"] = [w.requests_served for w in self.workers]
        t["worker_batches"] = [w.batches_run for w in self.workers]
        t["worker_simulated_s"] = [w.simulated_s for w in self.workers]
        return t

    def explain(self, name: str, worker: int = 0) -> str:
        return self.workers[worker].explain(name)
