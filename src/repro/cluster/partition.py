"""Horizontal partitioning of columnar tables across shards.

The :class:`Partitioner` is the cluster's data-placement policy, in the
spirit of the mesh + ``PartitionSpec`` idiom in ``repro.launch.sharding``:
each table is either

  * **partitioned** — rows hashed to shards by one declared key column
    (``shard = int(key) % n_shards``, a deterministic modulo hash so tests
    and benchmarks can craft uniform or skewed placements on purpose), or
  * **replicated** — every shard holds a full copy (the small-dimension-
    table option: a join against a replicated table never crosses shards).

Partition tables carry a hidden provenance column ``__gpos`` — each row's
global position in the unsharded table — declared with ``wire_bytes=0`` so
row sizes, transfer charges, and the cost model are untouched by it.
``__gpos`` is what makes scatter-gather *ordered* merges exact: concat the
per-shard partials, stable-argsort by ``__gpos``, drop the column, and the
global result is bit-identical to the unsharded execution, row order
included. The column never escapes the cluster layer:
:class:`~repro.cluster.database.ShardedDatabase` strips it from every
result it returns.
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..relational.table import Field, Table

__all__ = ["GPOS", "GPOS_FIELD", "Partitioner", "strip_gpos"]

# hidden provenance column on partition tables: global row position in the
# unsharded table; wire_bytes=0 keeps row_bytes (hence every simulated
# transfer and cost-model figure) identical to the unsharded schema
GPOS = "__gpos"
GPOS_FIELD = Field(GPOS, "int64", wire_bytes=0)


class Partitioner:
    """Deterministic row→shard placement: hash-partition by key column,
    replicate everything else."""

    def __init__(self, n_shards: int, keys: Optional[Mapping[str, str]] = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.n_shards = n_shards
        # table -> partition key column; tables not listed are replicated
        self.keys: Dict[str, str] = dict(keys or {})

    def key_column(self, table: str) -> Optional[str]:
        """The named table's partition key column, or None if replicated."""
        return self.keys.get(table)

    def shard_of(self, table: str, value) -> Optional[int]:
        """Owning shard of the rows with ``key == value`` (None when the
        table is replicated or the value has no integer identity)."""
        if table not in self.keys:
            return None
        try:
            return int(value) % self.n_shards
        except (TypeError, ValueError):
            return None

    def shard_assignment(self, t: Table) -> Optional[np.ndarray]:
        """Per-row shard ids for a partitioned table (None if replicated,
        or if the declared key column is absent — e.g. a program installed
        a fresh table under this name; such tables replicate)."""
        key = self.keys.get(t.name)
        if key is None or not t.schema.has(key):
            return None
        return np.asarray(t.column(key)).astype(np.int64) % self.n_shards

    def split(self, t: Table) -> List[Table]:
        """The table's shard partitions, each carrying ``__gpos`` (the
        rows' global positions). Rows keep their relative order inside
        each partition, so a ``__gpos``-ordered merge of the partitions
        reconstructs the original table exactly."""
        shard = self.shard_assignment(t)
        if shard is None:
            raise ValueError(f"table {t.name!r} is not partitioned")
        out = []
        for k in range(self.n_shards):
            idx = np.flatnonzero(shard == k)
            out.append(t.take(idx).with_column(GPOS_FIELD, idx))
        return out

    def shard_tables(self, t: Table) -> List[Table]:
        """What each shard stores for this table: its partition (with
        ``__gpos``) when partitioned, the full table when replicated."""
        if self.shard_assignment(t) is None:
            return [t] * self.n_shards
        return self.split(t)

    def describe(self) -> str:
        parts = ", ".join(f"{t} by {c}" for t, c in sorted(self.keys.items()))
        return (f"Partitioner({self.n_shards} shard(s); "
                f"partitioned: {parts or 'none'}; others replicated)")


def strip_gpos(t: Table) -> Table:
    """Drop every provenance column (``__gpos``, or a join-renamed
    ``<table>___gpos``) from a result before it leaves the cluster layer."""
    keep = [c for c in t.schema.names if not c.endswith(GPOS)]
    if len(keep) == len(t.schema.names):
        return t
    return t.select_columns(keep)
