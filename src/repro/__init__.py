"""COBRA on TPU: cost-based rewriting of database applications (Emani &
Sudarshan, 2018) as a production JAX framework.

The public surface is the session API::

    from repro.api import CobraSession, OptimizerConfig, ProgramBuilder, q
    from repro.core import CostCatalog

    session = CobraSession(db, CostCatalog(SLOW_REMOTE),
                           config=OptimizerConfig.preset("paper-exp1-3"))
    exe = session.compile(program)     # memo search once, plan cached
    out = exe.run()                    # execute-many (outputs + sim clock)

Programs are written with the tracing ``ProgramBuilder`` (``with``-scoped
loops, ``q()`` relational query handles, attribute/relationship navigation)
or the ``session.trace()`` decorator. Compiled plans live in a cache keyed
by (program fingerprint, cost catalog, optimizer config, per-table stats
versions of the tables the program touches); ``db.analyze(table, ...)``
bumps those versions, invalidating exactly the plans whose cost estimates
went stale. The same session fronts the distributed TPU planner
(``session.plan_step``) with a shared ``PlanReport`` result vocabulary.

For production-shaped workloads, ``repro.runtime`` adds batched execution
(``Executable.run_batch`` — one server round trip per query site per
batch), a disk-backed cross-session ``PlanStore``, and a feedback-driven
serving loop (``ServingRuntime``) that re-optimizes programs when observed
cardinalities drift from the estimates their plans were costed on.

Migration note: the legacy free function ``repro.core.optimize(program, db,
catalog, choice, rules)`` remains supported as a thin shim that opens a
throwaway session per call — correct, but it re-runs the full memo search
every time; hold a ``CobraSession`` for compile-once/execute-many.

  repro.api         — CobraSession, OptimizerConfig, ProgramBuilder, PlanCache
  repro.runtime     — serving: run_batch, PlanStore, feedback re-optimization
  repro.core        — the paper: regions, F-IR, Region DAG, rules, search
  repro.core.planner — the technique applied to distributed execution
  repro.relational  — columnar JAX tables + simulated DB environment
  repro.models      — the 10 assigned architectures
  repro.kernels     — Pallas TPU kernels (+ jnp oracles)
  repro.launch      — meshes, sharding, dry-run, train/serve drivers
"""

__version__ = "1.2.0"
