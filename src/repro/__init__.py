"""COBRA on TPU: cost-based rewriting of database applications (Emani &
Sudarshan, 2018) as a production JAX framework.

  repro.core        — the paper: regions, F-IR, Region DAG, rules, search
  repro.core.planner — the technique applied to distributed execution
  repro.relational  — columnar JAX tables + simulated DB environment
  repro.models      — the 10 assigned architectures
  repro.kernels     — Pallas TPU kernels (+ jnp oracles)
  repro.launch      — meshes, sharding, dry-run, train/serve drivers
"""

__version__ = "1.0.0"
