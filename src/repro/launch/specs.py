"""Abstract input specs for every (arch × shape) cell — ShapeDtypeStructs
with NamedShardings attached; nothing is ever allocated (the shannon/kernels
pattern). ``step_fn`` builds the jittable train/prefill/decode step the
dry-run lowers.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import SHAPES
from ..models import forward, init_params, loss_fn, make_caches
from ..models.arch import ArchConfig
from ..optim.optimizers import Optimizer, adafactor, adamw, warmup_cosine, \
    clip_by_global_norm
from .sharding import (MeshPolicy, batch_specs, cache_specs, named_sharding,
                       param_specs)

__all__ = ["abstract_params", "make_optimizer", "input_specs", "step_fn",
           "shape_kind"]


def shape_kind(shape_name: str) -> str:
    return SHAPES[shape_name]["kind"]


def abstract_params(cfg: ArchConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))


def make_optimizer(cfg: ArchConfig, total_steps: int = 10000) -> Optimizer:
    """Adafactor for ≥0.5T params (HBM budget), AdamW otherwise."""
    warmup = max(10, min(200, total_steps // 10))
    lr = warmup_cosine(3e-4, warmup, total_steps)
    if cfg.n_params() > 5e11:
        return adafactor(lr)
    return adamw(lr)


def _sds(tree, shardings=None):
    if shardings is None:
        return jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    return jax.tree_util.tree_map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        tree, shardings)


def _batch_struct(cfg: ArchConfig, B: int, T: int, kind: str):
    b: Dict[str, Any] = {}
    ii = jnp.int32
    if kind == "train":
        if cfg.enc_dec:
            b["tokens"] = jax.ShapeDtypeStruct((B, T), ii)
            b["enc_embeds"] = jax.ShapeDtypeStruct((B, T, cfg.d_model),
                                                   jnp.bfloat16)
        elif cfg.frontend:
            b["embeds"] = jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16)
        else:
            b["tokens"] = jax.ShapeDtypeStruct((B, T), ii)
        b["labels"] = jax.ShapeDtypeStruct((B, T), ii)
        b["positions"] = jax.ShapeDtypeStruct((B, T), ii)
    elif kind == "prefill":
        if cfg.enc_dec or cfg.frontend:
            b["embeds"] = jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16)
        else:
            b["tokens"] = jax.ShapeDtypeStruct((B, T), ii)
        b["positions"] = jax.ShapeDtypeStruct((B, T), ii)
    else:  # decode: one new token against a T-token cache
        b["tokens"] = jax.ShapeDtypeStruct((B, 1), ii)
        b["positions"] = jax.ShapeDtypeStruct((B, 1), ii)
    return b


def input_specs(cfg: ArchConfig, shape_name: str, policy: MeshPolicy,
                optimizer: Optional[Optimizer] = None):
    """Returns (example_args, in_shardings_tree) for the step function."""
    spec = SHAPES[shape_name]
    B, T, kind = spec["global_batch"], spec["seq_len"], spec["kind"]
    mesh = policy.mesh
    seq_shard = policy.seq_shard

    p_abs = abstract_params(cfg)
    p_spec = param_specs(p_abs, cfg, mesh, policy.strategy)
    p_shard = named_sharding(mesh, p_spec)
    params = _sds(p_abs, p_shard)

    batch = _batch_struct(cfg, B, T, kind)
    b_spec = batch_specs(mesh, batch, seq_shard=seq_shard and kind != "decode")
    batch = _sds(batch, named_sharding(mesh, b_spec))

    if kind == "train":
        assert optimizer is not None
        o_abs = jax.eval_shape(optimizer.init, p_abs)
        o_spec = param_specs(o_abs, cfg, mesh, policy.strategy)
        opt = _sds(o_abs, named_sharding(mesh, o_spec))
        step = jax.ShapeDtypeStruct((), jnp.int32)
        return {"params": params, "opt_state": opt, "step": step,
                "batch": batch}
    if kind == "prefill":
        return {"params": params, "batch": batch}
    # decode
    caches = make_caches(cfg, B, T, abstract=True)
    c_spec = cache_specs(mesh, caches, seq_shard=seq_shard)
    caches = _sds(caches, named_sharding(mesh, c_spec))
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    return {"params": params, "caches": caches, "cache_index": idx,
            "batch": batch}


# --------------------------------------------------------------------------
# Step functions
# --------------------------------------------------------------------------

def step_fn(cfg: ArchConfig, kind: str, policy: MeshPolicy,
            optimizer: Optional[Optimizer] = None) -> Callable:
    if kind == "train":
        return make_train_step(cfg, policy, optimizer)
    if kind == "prefill":
        def prefill(params, batch):
            inp = batch.get("embeds", None)
            if inp is None:
                inp = batch["tokens"]
            enc = batch.get("embeds") if cfg.enc_dec else None
            if cfg.enc_dec:
                B, T = inp.shape[:2]
                dec_tokens = jnp.zeros((B, min(T, 1024)), jnp.int32)
                pos = jnp.broadcast_to(jnp.arange(dec_tokens.shape[1])[None],
                                       dec_tokens.shape)
                logits, _, _ = forward(params, cfg, dec_tokens, pos,
                                       pol=policy, enc_inputs=inp)
            else:
                logits, _, _ = forward(params, cfg, inp, batch["positions"],
                                       pol=policy)
            return logits
        return prefill

    def serve_step(params, caches, cache_index, batch):
        logits, new_caches, _ = forward(params, cfg, batch["tokens"],
                                        batch["positions"], caches=caches,
                                        cache_index=cache_index, pol=policy)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_caches
    return serve_step


def make_train_step(cfg: ArchConfig, policy: MeshPolicy,
                    optimizer: Optimizer) -> Callable:
    nmb = max(1, policy.microbatch)

    def train_step(params, opt_state, step, batch):
        if nmb == 1:
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch, pol=policy))(params)
        else:
            def split(x):
                B = x.shape[0]
                return x.reshape(nmb, B // nmb, *x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def acc_fn(carry, mbatch):
                acc_loss, acc_g = carry
                l, g = jax.value_and_grad(
                    lambda p: loss_fn(p, cfg, mbatch, pol=policy))(params)
                acc_g = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g)
                return (acc_loss + l, acc_g), ()

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (0.0, zero_g), mb)
            loss = loss / nmb
            grads = jax.tree_util.tree_map(lambda g: g / nmb, grads)

        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, new_opt = optimizer.update(grads, opt_state, params, step)
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)
                          ).astype(p.dtype), params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_opt, step + 1, metrics

    return train_step
