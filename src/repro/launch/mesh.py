"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import,
and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["make_production_mesh", "make_mesh", "mesh_info"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips, v5e) or 2×16×16 (2 pods, 512 chips)."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Mesh over the first prod(shape) devices (elastic: any divisor count)."""
    import jax
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            f"run under XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"for the dry-run")
    arr = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def mesh_info(mesh) -> dict:
    return {"axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "n_devices": int(np.prod(mesh.devices.shape))}
