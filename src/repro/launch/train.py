"""Fault-tolerant training driver.

  python -m repro.launch.train --arch h2o-danube-1.8b --steps 200 \
      --scale smoke --ckpt-dir /tmp/ckpt

Features exercised at any scale (and unit-tested in tests/test_trainer.py):
  * auto-resume from the latest atomic checkpoint (params, opt state, step,
    data-pipeline cursor) — restart-identical training;
  * async checkpoint every --ckpt-every steps, off the critical path;
  * elastic restore — checkpoints are canonical (unsharded); a restart on a
    different mesh re-shards on load;
  * straggler/failure drill: --fail-at N crashes mid-run (tests restart it
    and assert bitwise-continuation);
  * optional int8 gradient compression with error feedback (--compress);
  * microbatched gradient accumulation (--microbatch).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp

from ..checkpoint import Checkpointer
from ..data import PipelineConfig, Prefetcher, SyntheticLM
from ..models import get_arch, init_params
from ..models.layers import NULL_POLICY
from .mesh import make_mesh
from .sharding import make_policy, named_sharding, param_specs
from .specs import make_optimizer, make_train_step

__all__ = ["TrainConfig", "train", "main"]


@dataclasses.dataclass
class TrainConfig:
    arch: str = "h2o-danube-1.8b"
    scale: str = "smoke"          # smoke (reduced cfg) | full
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 64
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    microbatch: int = 1
    compress: bool = False
    fail_at: Optional[int] = None         # failure-injection drill
    mesh_shape: Optional[tuple] = None    # e.g. (2, 2) for local multi-device
    strategy: str = "dp"
    log_every: int = 10
    seed: int = 0


def train(cfg: TrainConfig, progress=print) -> dict:
    arch = get_arch(cfg.arch)
    if cfg.scale == "smoke":
        arch = arch.scaled()
    if cfg.mesh_shape:
        mesh = make_mesh(tuple(cfg.mesh_shape), ("data", "model")[:len(cfg.mesh_shape)])
        policy = make_policy(mesh, strategy=cfg.strategy,
                             microbatch=cfg.microbatch)
    else:
        mesh, policy = None, NULL_POLICY
        policy.microbatch = cfg.microbatch  # type: ignore

    optimizer = make_optimizer(arch, total_steps=cfg.steps)
    step_fn = make_train_step(arch, policy, optimizer)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    pipe_cfg = PipelineConfig(
        global_batch=cfg.global_batch, seq_len=cfg.seq_len,
        vocab_size=arch.vocab_size, seed=cfg.seed,
        emb_dim=arch.d_model if (arch.frontend or arch.enc_dec) else None,
        enc_dec=arch.enc_dec)
    source = SyntheticLM(pipe_cfg)

    ckpt = Checkpointer(cfg.ckpt_dir) if cfg.ckpt_dir else None
    start_step = 0
    params = init_params(jax.random.PRNGKey(cfg.seed), arch)
    opt_state = optimizer.init(params)
    data_state = {"next_index": 0}

    if ckpt is not None and ckpt.latest_step() is not None:
        shardings = None
        if mesh is not None:
            p_spec = param_specs(params, arch, mesh, cfg.strategy)
            shardings = {"params": named_sharding(mesh, p_spec)}
        start_step, tree, extras = ckpt.restore(
            {"params": params, "opt": opt_state})
        params, opt_state = tree["params"], tree["opt"]
        data_state = extras.get("data", data_state)
        progress(f"[resume] step {start_step}")

    prefetch = Prefetcher(source, start_index=data_state["next_index"])
    step = jnp.asarray(start_step, jnp.int32)
    losses = []
    t0 = time.time()
    try:
        for i in range(start_step, cfg.steps):
            if cfg.fail_at is not None and i == cfg.fail_at:
                raise RuntimeError(f"injected failure at step {i}")
            batch_np = prefetch.get()
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt_state, step, metrics = step_fn(params, opt_state,
                                                       step, batch)
            if (i + 1) % cfg.log_every == 0 or i == cfg.steps - 1:
                loss = float(metrics["loss"])
                losses.append((i + 1, loss))
                progress(f"step {i+1}/{cfg.steps} loss={loss:.4f} "
                         f"gnorm={float(metrics['grad_norm']):.3f} "
                         f"({(time.time()-t0)/max(1,i+1-start_step):.2f}s/step)")
            if ckpt is not None and (i + 1) % cfg.ckpt_every == 0:
                ckpt.save(i + 1, {"params": params, "opt": opt_state},
                          extras={"data": prefetch.state()})
        if ckpt is not None:
            ckpt.save(cfg.steps, {"params": params, "opt": opt_state},
                      extras={"data": prefetch.state()}, block=True)
    finally:
        prefetch.close()
        if ckpt is not None:
            ckpt.wait()
    return {"final_step": int(step), "losses": losses,
            "params": params}


def main():
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(TrainConfig):
        name = "--" + f.name.replace("_", "-")
        if f.type in ("bool", bool):
            ap.add_argument(name, action="store_true")
        else:
            ap.add_argument(name, default=f.default, type=type(f.default)
                            if f.default is not None else str)
    args = ap.parse_args()
    cfg = TrainConfig(**{f.name: getattr(args, f.name)
                         for f in dataclasses.fields(TrainConfig)})
    cfg = dataclasses.replace(cfg, steps=int(cfg.steps),
                              global_batch=int(cfg.global_batch),
                              seq_len=int(cfg.seq_len))
    out = train(cfg)
    print(json.dumps({"final_step": out["final_step"],
                      "losses": out["losses"][-3:]}))


if __name__ == "__main__":
    main()
