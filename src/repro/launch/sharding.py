"""Sharding policies: logical-name → PartitionSpec rules + param spec trees.

A ``MeshPolicy`` is what the Cobra distributed planner emits: activation
rules (consumed by ``pol.cs`` inside the layers), a parameter-sharding
strategy, a remat policy, and microbatching. Divisibility is always checked
— a rule that does not divide a concrete dimension is dropped for that
tensor (e.g. 8 KV heads on a 16-way model axis stay replicated).

Strategies:
  dp       pure data parallel (params replicated)
  fsdp     params sharded on ("pod","data") dim-0 (ZeRO-3 style)
  tp       Megatron tensor parallel on "model" (heads / ffn / vocab / experts)
  fsdp_tp  both — the production default
  *_sp     + sequence parallelism: long-context activations/KV shard the
           sequence dim on "data"
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.arch import ArchConfig

__all__ = ["MeshPolicy", "make_policy", "param_specs", "batch_specs",
           "named_sharding", "STRATEGIES"]

STRATEGIES = ("dp", "fsdp", "tp", "fsdp_tp", "tp_sp", "fsdp_tp_sp",
              "fsdp_tp_ep")
# fsdp_tp_ep: like fsdp_tp, but MoE expert weights are FULLY owned by their
# (expert-on-model × ffn-on-data) shard — no per-layer weight regather; the
# contraction instead reduces the (E/16, C, d) activation buffer over data,
# which is ~14× smaller than the expert weights for kimi-k2 (§Perf).


def _axes(mesh: Mesh):
    names = mesh.axis_names
    data = tuple(n for n in ("pod", "data") if n in names)
    data = data if len(data) > 1 else (data[0] if data else None)
    model = "model" if "model" in names else None
    return data, model


def _divisible(shape, spec, mesh: Mesh) -> P:
    """Drop spec axes that don't divide the corresponding dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axis_size(entry):
        if entry is None:
            return 1
        if isinstance(entry, (tuple, list)):
            n = 1
            for e in entry:
                n *= sizes[e]
            return n
        return sizes[entry]

    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is not None and dim % axis_size(entry) == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


@dataclasses.dataclass
class MeshPolicy:
    mesh: Mesh
    strategy: str = "fsdp_tp"
    remat: str = "none"            # none | full | dots
    seq_shard: bool = False        # sequence parallelism (long context)
    microbatch: int = 1
    use_kernels: bool = False
    unroll_layers: bool = False   # dry-run accounting mode (see model._maybe_scan)
    rules: Dict[str, P] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.rules:
            self.rules = default_activation_rules(self.mesh, self.strategy,
                                                  self.seq_shard)

    def cs(self, x, name: str):
        spec = self.rules.get(name)
        if spec is None:
            return x
        spec = _divisible(x.shape, spec, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def describe(self) -> dict:
        return {"strategy": self.strategy, "remat": self.remat,
                "seq_shard": self.seq_shard, "microbatch": self.microbatch,
                "unroll_layers": self.unroll_layers}


def default_activation_rules(mesh: Mesh, strategy: str,
                             seq_shard: bool) -> Dict[str, P]:
    data, model = _axes(mesh)
    tp = model if "tp" in strategy or strategy == "fsdp_tp" else None
    seq = data if seq_shard else None
    if seq_shard:
        # long-context: batch=1 → put data axis on sequence instead
        return {
            "act_btd": P(None, data, None),
            "act_btf2": P(None, data, tp),
            "act_bthd": P(None, data, tp, None),
            "act_btkd": P(None, data, None, None),
            "logits": P(None, data, tp),
            "moe_ecd": P(tp, None, None),
            "kv_seq": P(None, None, data, None, None),
        }
    return {
        "act_btd": P(data, None, None),
        "act_btf2": P(data, None, tp),
        "act_bthd": P(data, None, tp, None),
        "act_btkd": P(data, None, tp, None),
        "logits": P(data, None, tp),
        "moe_ecd": P(tp, None, None),
        "kv_seq": P(None, data, None, None, None),
    }


def make_policy(mesh: Mesh, strategy: str = "fsdp_tp", remat: str = "none",
                seq_shard: bool = False, microbatch: int = 1,
                unroll_layers: bool = False) -> MeshPolicy:
    assert strategy in STRATEGIES, strategy
    return MeshPolicy(mesh=mesh, strategy=strategy, remat=remat,
                      seq_shard="sp" in strategy or seq_shard,
                      microbatch=microbatch, unroll_layers=unroll_layers)


# --------------------------------------------------------------------------
# Parameter sharding
# --------------------------------------------------------------------------

_TP_RULES = [
    # (path regex, spec builder over (shape, data, model)) — specs are for the
    # UNSTACKED tensor; a leading scan/layer dim gets None prepended.
    (r"\btok$",      lambda d, m: P(m, None)),        # vocab-sharded embed
    (r"\bunembed$",  lambda d, m: P(None, m)),
    (r"\bwq$|\bwk$|\bwv$|\bwq_b$|\bwkv_b$", lambda d, m: P(None, m)),
    (r"\bwo$",       lambda d, m: P(m, None)),
    (r"\bw_in$",     lambda d, m: P(None, m)),        # mlp gate+up
    (r"\bw_out$",    lambda d, m: P(m, None)),
    (r"\brouter$",   lambda d, m: P(None, None)),
    (r"moe.*w_in$",  lambda d, m: P(m, None, None)),  # experts on model (EP)
    (r"moe.*w_out$", lambda d, m: P(m, None, None)),
    (r"\bwr$|\bwk$|\bwv$|\bwg$", lambda d, m: P(None, m)),   # rwkv
    (r"\bcm_k$",     lambda d, m: P(None, m)),
    (r"\bcm_v$",     lambda d, m: P(m, None)),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _spec_for(path: str, shape, data, model, strategy: str, stacked: bool) -> P:
    spec = P()
    base_shape = shape[1:] if stacked else shape
    is_moe_w = re.search(r"moe.*w_(in|out)$", path) is not None
    if "ep" in strategy and model is not None and is_moe_w:
        # full expert ownership: (E on model, ffn on data) — no regather
        spec = P(model, None, data) if path.endswith("w_in") \
            else P(model, data, None)
        entries = list(tuple(spec) + (None,) * (len(base_shape) - len(spec)))
        if stacked:
            entries = [None] + entries
        return P(*entries)
    if "tp" in strategy and model is not None:
        for pat, builder in _TP_RULES:
            if re.search(pat, path):
                spec = builder(data, model)
                break
    entries = list(tuple(spec) + (None,) * (len(base_shape) - len(spec)))
    if "fsdp" in strategy and data is not None:
        # ZeRO-3: shard the largest still-unsharded dim on the data axis
        order = sorted(range(len(base_shape)), key=lambda i: -base_shape[i])
        for i in order:
            if entries[i] is None:
                entries[i] = data
                break
    if stacked:
        entries = [None] + entries
    return P(*entries)


def param_specs(params_tree, cfg: ArchConfig, mesh: Mesh,
                strategy: str = "fsdp_tp"):
    """PartitionSpec tree matching the (possibly abstract) param tree.

    Stacked layer tensors (leading dim == a layer count) get a None-sharded
    leading axis."""
    data, model = _axes(mesh)
    layer_counts = {cfg.n_layers, cfg.n_enc_layers, cfg.n_dec_layers,
                    cfg.n_dense_layers, cfg.n_layers - cfg.n_dense_layers,
                    max(1, cfg.n_layers // max(1, cfg.hybrid_every or 1))}
    layer_counts.discard(0)

    def one(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        stacked = (len(shape) >= 2 and shape[0] in layer_counts
                   and ("layers" in p or "enc" in p or "dec" in p))
        spec = _spec_for(p, shape, data, model, strategy, stacked)
        return _divisible(shape, spec, mesh)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def named_sharding(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# Batch / cache sharding
# --------------------------------------------------------------------------

def batch_specs(mesh: Mesh, batch_tree, seq_shard: bool = False):
    """Batch dims shard on ("pod","data"); long-context (batch=1) shards the
    sequence dim instead."""
    data, model = _axes(mesh)

    def one(leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        if seq_shard and len(shape) >= 2:
            spec = P(None, data)     # (B=1, T, ...) → shard T
        else:
            spec = P(data)
        return _divisible(shape, spec, mesh)

    return jax.tree_util.tree_map(one, batch_tree)


def cache_specs(mesh: Mesh, cache_tree, seq_shard: bool = False):
    """KV caches: (L, B, S, ...) — batch on data AND sequence on model
    (flash-decode style: partial softmax over the S shards, XLA inserts the
    combine collectives). long_500k (batch=1) shards S on data+model.
    State caches (ssm/wkv/shift) shard batch on data, heads on model."""
    data, model = _axes(mesh)
    seq_keys = ("k", "v", "xk", "xv", "lat", "rope")

    def one(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        shape = leaf.shape
        if name in seq_keys and len(shape) >= 3:
            if seq_shard:
                combined = (tuple(data) if isinstance(data, tuple)
                            else (data,)) + ((model,) if model else ())
                spec = P(None, None, combined)
            else:
                spec = P(None, data, model)
        elif len(shape) >= 3:                      # ssm/wkv states (L,B,H,..)
            spec = P(None, data, model)
        elif len(shape) == 2:
            spec = P(None, data)
        else:
            spec = P()
        return _divisible(shape, spec, mesh)

    return jax.tree_util.tree_map_with_path(one, cache_tree)
