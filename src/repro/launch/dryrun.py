import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes; extract memory and roofline accounting.

MUST be run before any other jax-touching import — the two lines above pin
the device count before jax initializes. Never set that flag globally
(smoke tests and benches must see 1 device).

Two passes per cell:

  A (compile proof)   — the FULL config on the production scan path
      (lax.scan over layers, remat, microbatching). `.lower().compile()`
      succeeding here is deliverable (e); `memory_analysis()` proves fit.
      XLA's cost_analysis tallies while-bodies once, so pass A numbers are
      NOT used for FLOP accounting.

  B (exact accounting) — the same cell at two reduced depths (k1 < k2)
      with layers UNROLLED: cost_analysis and the HLO collective sum are
      then exact; per-layer cost = (f(k2) − f(k1)) / (k2 − k1), and the full
      depth is linear extrapolation (layer stacks are homogeneous; the
      intercept captures embed/loss/optimizer). Validated in
      tests/test_dryrun_small.py against a fully-unrolled small model.

Usage:
  python -m repro.launch.dryrun --arch stablelm-12b --shape train_4k
  python -m repro.launch.dryrun --all --both-meshes [--out reports/dryrun]
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional, Tuple

import jax
import numpy as np

from ..analysis.roofline import collective_bytes_from_hlo, roofline_terms
from ..configs import ALL_ARCHS, SHAPES
from ..models.arch import get_arch
from .mesh import make_production_mesh
from .sharding import make_policy
from .specs import input_specs, make_optimizer, shape_kind, step_fn

__all__ = ["run_cell", "runnable", "main"]


def runnable(arch: str, shape: str) -> bool:
    cfg = get_arch(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return False  # documented skip: pure full attention at 512k decode
    return True


def accounting_depths(cfg) -> Tuple[int, int, float]:
    """(k1, k2, effective_layer_count) for linear extrapolation."""
    if cfg.ssm_kind == "mamba2" and cfg.shared_attn:
        every = max(1, cfg.hybrid_every)
        return every, 2 * every, float(cfg.n_layers)
    if cfg.moe and cfg.n_dense_layers:
        return cfg.n_dense_layers + 2, cfg.n_dense_layers + 4, float(cfg.n_layers)
    return 2, 4, float(cfg.n_layers)


def reduced(cfg, k: int):
    upd = dict(n_layers=k)
    if cfg.enc_dec:
        upd.update(n_enc_layers=k, n_dec_layers=k)
    return dataclasses.replace(cfg, **upd)


def _policy(mesh, kind, shape, unroll, micro):
    seq_shard = shape == "long_500k"
    remat = "full" if kind == "train" else "none"
    return make_policy(mesh, strategy="fsdp_tp", remat=remat,
                       seq_shard=seq_shard, microbatch=micro,
                       unroll_layers=unroll)


def _lower_compile(cfg, shape, kind, policy):
    optimizer = make_optimizer(cfg) if kind == "train" else None
    fn = step_fn(cfg, kind, policy, optimizer)
    args = input_specs(cfg, shape, policy, optimizer)
    # donate params/opt-state (train) or caches (decode): in-place update on
    # real hardware; keeps memory_analysis honest
    donate = (0, 1) if kind == "train" else ((1,) if kind == "decode" else ())
    t0 = time.time()
    lowered = jax.jit(fn, donate_argnums=donate).lower(*args.values())
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return {
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
        "bytes": float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
        "collectives": collective_bytes_from_hlo(compiled.as_text()),
        "memory": _mem_info(compiled),
    }


def _mem_info(compiled):
    mem = compiled.memory_analysis()
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    if out:
        out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                                  + out.get("output_size_in_bytes", 0)
                                  + out.get("temp_size_in_bytes", 0)
                                  - out.get("alias_size_in_bytes", 0))
    return out


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             strategy: str = "fsdp_tp", remat: Optional[str] = None,
             microbatch: Optional[int] = None, verbose: bool = True,
             accounting: bool = True, policy_overrides: Optional[dict] = None
             ) -> dict:
    cfg = get_arch(arch)
    kind = shape_kind(shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    spec = SHAPES[shape]
    overrides = policy_overrides or {}
    remat_eff = remat if remat is not None else \
        ("full" if kind == "train" else "none")
    micro_eff = microbatch if microbatch is not None else \
        (8 if kind == "train" else 1)

    with mesh:
        # ---- pass A: full config, production scan path
        polA = make_policy(mesh, strategy=strategy, remat=remat_eff,
                           seq_shard=(shape == "long_500k"),
                           microbatch=micro_eff, unroll_layers=False)
        for k, v in overrides.items():
            setattr(polA, k, v)
        passA = _lower_compile(cfg, shape, kind, polA)

        result = {
            "arch": arch, "shape": shape, "kind": kind,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_devices": n_dev, "status": "ok",
            "tokens": spec["seq_len"] * spec["global_batch"],
            "policy": polA.describe(),
            "full_compile": {k: passA[k] for k in
                             ("lower_s", "compile_s", "memory")},
            "full_collective_counts": passA["collectives"]["counts"],
        }

        # ---- pass B: two-point unrolled accounting
        if accounting:
            k1, k2, L_eff = accounting_depths(cfg)
            polB = make_policy(mesh, strategy=strategy, remat=remat_eff,
                               seq_shard=(shape == "long_500k"),
                               microbatch=1, unroll_layers=True)
            for k, v in overrides.items():
                if k != "microbatch":
                    setattr(polB, k, v)
            f1 = _lower_compile(reduced(cfg, k1), shape, kind, polB)
            f2 = _lower_compile(reduced(cfg, k2), shape, kind, polB)

            def extrap(a, b):
                per_layer = (b - a) / (k2 - k1)
                return a + (L_eff - k1) * per_layer

            flops = extrap(f1["flops"], f2["flops"])
            bytes_ = extrap(f1["bytes"], f2["bytes"])
            coll = extrap(f1["collectives"]["bytes_per_device"],
                          f2["collectives"]["bytes_per_device"])
            mb = polA.microbatch if kind == "train" else 1
            result.update({
                "accounting": {
                    "k1": k1, "k2": k2,
                    "flops_k1": f1["flops"], "flops_k2": f2["flops"],
                    "compile_s": f1["compile_s"] + f2["compile_s"],
                },
                # pass B ran microbatch=1; flops/bytes are per full batch
                "flops_per_device": flops,
                "bytes_per_device": bytes_,
                "collectives": {"bytes_per_device": coll,
                                "by_type": f2["collectives"]["by_type"],
                                "counts": f2["collectives"]["counts"]},
            })
            result["roofline"] = roofline_terms(cfg, spec, result)

    if verbose:
        slim = {k: v for k, v in result.items() if k != "collectives"}
        print(json.dumps(slim, indent=1, default=str))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-accounting", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ALL_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[cached] {tag}", flush=True)
                    continue
                if not runnable(arch, shape):
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "skipped",
                           "reason": "pure full attention at 512k decode "
                                     "(DESIGN.md §Arch-applicability)"}
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"[skipped] {tag}", flush=True)
                    continue
                print(f"[run] {tag}", flush=True)
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape, multi_pod=mp, verbose=False,
                                   accounting=not args.no_accounting)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "status": "error",
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": repr(e)}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec.get("status") == "ok":
                    rf = rec.get("roofline", {})
                    print(f"  ok in {time.time()-t0:.0f}s dominant="
                          f"{rf.get('dominant')} frac="
                          f"{rf.get('roofline_fraction', 0):.3f}", flush=True)
                else:
                    print(f"  -> {rec.get('status')}: "
                          f"{rec.get('error', '')[:200]}", flush=True)


if __name__ == "__main__":
    main()
