"""Batched serving driver: continuous-batching prefill + decode.

  python -m repro.launch.serve --arch h2o-danube-1.8b --requests 8

A minimal production-shaped server loop: a request queue, one prefill per
admitted request (right-padded into the running batch), then batched greedy
decode steps over the shared KV cache. Decode throughput and per-request
latency are reported; tests assert decode == full-forward consistency.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..models import forward, get_arch, init_params, make_caches
from ..models.layers import NULL_POLICY

__all__ = ["ServeConfig", "Server", "main"]


@dataclasses.dataclass
class ServeConfig:
    arch: str = "h2o-danube-1.8b"
    scale: str = "smoke"
    max_batch: int = 8
    max_seq: int = 128
    max_new_tokens: int = 16
    seed: int = 0


class Server:
    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        arch = get_arch(cfg.arch)
        self.arch = arch.scaled() if cfg.scale == "smoke" else arch
        self.params = init_params(jax.random.PRNGKey(cfg.seed), self.arch)
        self._decode = jax.jit(self._decode_fn)

    def _decode_fn(self, params, caches, cache_index, tokens, positions):
        logits, new_caches, _ = forward(params, self.arch, tokens, positions,
                                        caches=caches, cache_index=cache_index,
                                        pol=NULL_POLICY)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, new_caches

    def generate(self, prompts: List[np.ndarray]) -> List[List[int]]:
        """Greedy-decode a batch of token prompts (continuous batch)."""
        cfg, arch = self.cfg, self.arch
        B = len(prompts)
        assert B <= cfg.max_batch
        plens = [len(p) for p in prompts]
        Tmax = max(plens)
        caches = make_caches(arch, B, cfg.max_seq, dtype=jnp.float32)
        # prefill: right-align is avoided; pad to Tmax and mask via labels
        toks = np.zeros((B, Tmax), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        pos = np.broadcast_to(np.arange(Tmax)[None], (B, Tmax)).astype(np.int32)
        logits, caches, _ = forward(self.params, arch, jnp.asarray(toks),
                                    jnp.asarray(pos), caches=caches,
                                    cache_index=0, pol=NULL_POLICY)
        # first sampled token comes from each prompt's true last position
        last = jnp.asarray([l - 1 for l in plens])
        nxt = jnp.argmax(logits[jnp.arange(B), last], axis=-1).astype(jnp.int32)

        outs: List[List[int]] = [[int(nxt[i])] for i in range(B)]
        cur = nxt[:, None]
        for t in range(cfg.max_new_tokens - 1):
            step_pos = jnp.asarray([[plens[i] + t] for i in range(B)],
                                   jnp.int32)
            cur, caches = self._decode(self.params, caches,
                                       jnp.asarray(Tmax + t, jnp.int32),
                                       cur, step_pos)
            for i in range(B):
                outs[i].append(int(cur[i]))
            cur = cur[:, None]
        return outs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    args = ap.parse_args()
    cfg = ServeConfig(arch=args.arch, max_new_tokens=args.max_new_tokens,
                      max_batch=max(4, args.requests))
    server = Server(cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, server.arch.vocab_size,
                            rng.integers(4, 16)).astype(np.int32)
               for _ in range(args.requests)]
    t0 = time.time()
    outs = server.generate(prompts)
    dt = time.time() - t0
    total_new = sum(len(o) for o in outs)
    print(json.dumps({
        "requests": len(prompts),
        "new_tokens": total_new,
        "tokens_per_s": round(total_new / dt, 2),
        "sample": outs[0][:8],
    }))


if __name__ == "__main__":
    main()
