"""Launch layer: meshes, sharding policies, step builders, drivers.

NOTE: dryrun is intentionally NOT imported here — it sets XLA device-count
flags at import and must only run as __main__."""
from .mesh import make_mesh, make_production_mesh, mesh_info
from .sharding import MeshPolicy, STRATEGIES, batch_specs, make_policy, param_specs
__all__ = ["make_mesh", "make_production_mesh", "mesh_info", "MeshPolicy",
           "STRATEGIES", "batch_specs", "make_policy", "param_specs"]
