"""Trace spans: nested wall-clock + simulated-clock timing, near-free off.

A :class:`Tracer` records a tree of :class:`Span`\\ s — compile → rule
saturation rounds → costing; serve → batch → site fetch / cache hit →
compiled-kernel invoke → swap verdicts. Each span carries wall time
(``perf_counter``) and, when the caller passes a ``sim_clock`` callable
(e.g. ``lambda: env.clock``), the simulated clock interval too. Export as
JSONL (:meth:`Tracer.export_jsonl`) or render a text flamegraph-style tree
(:meth:`Tracer.render`).

The default everywhere is the module singleton :data:`NOOP_TRACER`: its
``span()`` returns a shared no-op handle, so an instrumented hot path pays
one attribute load and a branch — nothing is allocated, nothing recorded.
Hot inner loops guard event emission with ``if tracer.enabled:``.
"""

from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional

__all__ = ["Span", "Tracer", "NoopTracer", "NOOP_TRACER"]


class Span:
    __slots__ = ("name", "attrs", "wall_start", "wall_end",
                 "sim_start", "sim_end", "children")

    def __init__(self, name: str, attrs: Optional[Dict[str, object]] = None):
        self.name = name
        self.attrs: Dict[str, object] = attrs or {}
        self.wall_start: float = 0.0
        self.wall_end: Optional[float] = None
        self.sim_start: Optional[float] = None
        self.sim_end: Optional[float] = None
        self.children: List["Span"] = []

    @property
    def wall_s(self) -> float:
        end = self.wall_end if self.wall_end is not None \
            else time.perf_counter()
        return end - self.wall_start

    @property
    def sim_s(self) -> Optional[float]:
        if self.sim_start is None or self.sim_end is None:
            return None
        return self.sim_end - self.sim_start

    def __repr__(self):
        return f"Span({self.name!r}, {len(self.children)} child(ren))"


class _SpanHandle:
    """Context manager entering/exiting one span on its tracer's stack."""

    __slots__ = ("tracer", "span", "sim_clock")

    def __init__(self, tracer: "Tracer", span: Span,
                 sim_clock: Optional[Callable[[], float]]):
        self.tracer = tracer
        self.span = span
        self.sim_clock = sim_clock

    def __enter__(self) -> Span:
        t = self.tracer
        parent = t._stack[-1] if t._stack else None
        (parent.children if parent is not None else t.roots).append(self.span)
        t._stack.append(self.span)
        if self.sim_clock is not None:
            self.span.sim_start = self.sim_clock()
        self.span.wall_start = time.perf_counter()
        return self.span

    def __exit__(self, exc_type, exc, tb):
        self.span.wall_end = time.perf_counter()
        if self.sim_clock is not None:
            self.span.sim_end = self.sim_clock()
        stack = self.tracer._stack
        # robust to mismatched exits: pop until (and including) our span
        while stack:
            if stack.pop() is self.span:
                break
        return False


class Tracer:
    """Recording tracer. ``enabled`` is True so call sites can guard
    per-event work with a single branch."""

    enabled = True

    def __init__(self):
        self.roots: List[Span] = []
        self._stack: List[Span] = []

    # ------------------------------------------------------------ recording
    def span(self, name: str,
             sim_clock: Optional[Callable[[], float]] = None,
             **attrs) -> _SpanHandle:
        return _SpanHandle(self, Span(name, attrs), sim_clock)

    def event(self, name: str,
              sim_clock: Optional[Callable[[], float]] = None,
              sim: Optional[float] = None, **attrs) -> Span:
        """A zero-duration span attached to the current parent. Hot call
        sites pass the simulated clock by value (``sim=``) to skip the
        callable indirection."""
        sp = Span(name, attrs)
        now = time.perf_counter()
        sp.wall_start = sp.wall_end = now
        if sim is None and sim_clock is not None:
            sim = sim_clock()
        if sim is not None:
            sp.sim_start = sp.sim_end = sim
        parent = self._stack[-1] if self._stack else None
        (parent.children if parent is not None else self.roots).append(sp)
        return sp

    def reset(self) -> None:
        self.roots = []
        self._stack = []

    # ------------------------------------------------------------ inspection
    def well_nested(self) -> bool:
        """Every span closed, and every child's wall interval inside its
        parent's (the invariant mid-stream analyze()/replace_table/plan
        swaps must not break)."""
        if self._stack:
            return False
        eps = 1e-9

        def check(sp: Span) -> bool:
            if sp.wall_end is None or sp.wall_end + eps < sp.wall_start:
                return False
            for c in sp.children:
                if c.wall_start + eps < sp.wall_start:
                    return False
                if c.wall_end is None or c.wall_end > sp.wall_end + eps:
                    return False
                if not check(c):
                    return False
            return True

        return all(check(r) for r in self.roots)

    def spans(self, name: Optional[str] = None) -> List[Span]:
        """Flattened depth-first span list, optionally filtered by name."""
        out: List[Span] = []

        def walk(sp: Span):
            if name is None or sp.name == name:
                out.append(sp)
            for c in sp.children:
                walk(c)

        for r in self.roots:
            walk(r)
        return out

    # -------------------------------------------------------------- export
    def to_dicts(self) -> List[Dict[str, object]]:
        """Flatten to one dict per span with id/parent/depth links — the
        JSONL record shape."""
        out: List[Dict[str, object]] = []

        def walk(sp: Span, parent_id: Optional[int], depth: int):
            sid = len(out)
            rec: Dict[str, object] = {
                "id": sid, "parent": parent_id, "depth": depth,
                "name": sp.name, "wall_s": sp.wall_s,
            }
            if sp.sim_s is not None:
                rec["sim_s"] = sp.sim_s
            if sp.attrs:
                rec["attrs"] = dict(sp.attrs)
            out.append(rec)
            for c in sp.children:
                walk(c, sid, depth + 1)

        for r in self.roots:
            walk(r, None, 0)
        return out

    def export_jsonl(self, path: str) -> int:
        """Write one JSON record per span; returns the record count."""
        recs = self.to_dicts()
        with open(path, "w") as f:
            for rec in recs:
                f.write(json.dumps(rec, default=str) + "\n")
        return len(recs)

    def render(self, min_wall_s: float = 0.0) -> str:
        """Text flamegraph-style tree: nesting by indentation, wall (and
        simulated, when captured) duration per span."""
        from .render import fmt_seconds
        lines: List[str] = []

        def walk(sp: Span, prefix: str, is_last: bool, top: bool):
            if sp.wall_s < min_wall_s:
                return
            connector = "" if top else ("└─ " if is_last else "├─ ")
            parts = [f"{sp.name}  {fmt_seconds(sp.wall_s)} wall"]
            if sp.sim_s is not None:
                parts.append(f"{sp.sim_s:.4g}s sim")
            if sp.attrs:
                parts.append(" ".join(f"{k}={v}" for k, v in sp.attrs.items()))
            lines.append(prefix + connector + "  ".join(parts))
            kids = [c for c in sp.children if c.wall_s >= min_wall_s]
            child_prefix = prefix if top else \
                prefix + ("   " if is_last else "│  ")
            for i, c in enumerate(kids):
                walk(c, child_prefix, i == len(kids) - 1, False)

        for r in self.roots:
            walk(r, "", True, True)
        return "\n".join(lines)


class _NoopHandle:
    __slots__ = ()

    def __enter__(self) -> Span:
        return _NOOP_SPAN

    def __exit__(self, exc_type, exc, tb):
        return False


class NoopTracer:
    """The default tracer: a branch and nothing else on the hot path."""

    enabled = False

    roots: List[Span] = []

    def span(self, name: str, sim_clock=None, **attrs) -> _NoopHandle:
        return _NOOP_HANDLE

    def event(self, name: str, sim_clock=None, sim=None, **attrs) -> Span:
        return _NOOP_SPAN

    def reset(self) -> None:
        pass

    def well_nested(self) -> bool:
        return True

    def spans(self, name=None) -> List[Span]:
        return []

    def to_dicts(self) -> List[Dict[str, object]]:
        return []

    def export_jsonl(self, path: str) -> int:
        return 0

    def render(self, min_wall_s: float = 0.0) -> str:
        return ""


_NOOP_SPAN = Span("noop")
_NOOP_HANDLE = _NoopHandle()
NOOP_TRACER = NoopTracer()
