"""``Executable.explain()`` — EXPLAIN-style rendering of a winning plan.

The output answers the three questions a cost-based rewriter must be able
to answer to be trusted (the Froid lesson: surface the rewritten
imperative logic *inside* the plan view):

  * **why this plan** — header with estimated cost, alternatives searched,
    the execution context it was costed for, and the rewrite provenance
    (which rules derived the winning plan's nodes, plus per-rule
    alternative counts and per-phase optimizer time);
  * **where the time goes** — the region tree annotated per site with the
    model's estimated cost and, when serving observations exist, the
    estimated-vs-observed row/iteration counts and their q-error;
  * **what the runtime does with it** — execution tier, swap-guard
    verdict, per-site cache/binding-diversity status, compiled-tier
    verdict per loop.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from .render import fmt_seconds

from ..stats.qerror import q_error

__all__ = ["explain_plan", "q_error"]


def _cost_model(exe):
    from ..core.cost import CostModel
    from ..core.regions import write_tables
    cls = getattr(exe.session.config, "cost_model", None) or CostModel
    cm = cls(exe.session.db, exe.session.catalog, exe.context)
    cm.write_tables = frozenset(write_tables(exe.program))
    return cm


def explain_plan(exe, *, feedback=None, site_cache=None,
                 compiler=None) -> str:
    """Render the EXPLAIN text for ``exe`` (an
    :class:`~repro.api.session.Executable`). ``feedback`` /
    ``site_cache`` / ``compiler`` (a serving runtime's components) add
    observed-vs-estimated annotations; without them the output is purely
    model-side."""
    from ..core.context import (loop_site_key, query_site_key,
                                while_site_key)
    from ..core.regions import (BasicBlock, CondRegion, ILoadAll, LoopRegion,
                                Prefetch, SeqRegion, WhileRegion,
                                compilability)

    report = exe.report
    result = exe.result
    cm = _cost_model(exe)
    db = exe.session.db

    # observed serving statistics, keyed the way the annotations join them
    obs_sites: Dict[str, Dict[str, float]] = {}
    obs_iters: Dict[str, Dict[str, object]] = {}
    qerror_sites: Dict[str, Dict[str, float]] = {}
    if feedback is not None:
        fb = feedback.telemetry()
        obs_sites = fb.get("sites", {})
        obs_iters = fb.get("iteration_sites", {})
        qerror_sites = fb.get("qerror_sites", {})
    site_bindings: Dict[str, Dict[str, float]] = {}
    if site_cache is not None:
        site_bindings = site_cache.site_binding_stats()
    notes = compilability(exe.program)

    lines: List[str] = []
    lines.append(f"EXPLAIN {exe.source.name} -> {exe.program.name}")
    lines.append(f"  {report.describe()}")
    swap = ""
    if report.swap_checked:
        verdict = "accepted" if report.swap_accepted else "REJECTED"
        swap = (f"; swap-guard {verdict} "
                f"({report.swap_replayed} binding(s) replayed)")
    lines.append(f"  tier: {report.tier}{swap}")
    rules_fired = tuple(getattr(result, "rules_fired", ()) or ())
    rule_hits = dict(getattr(result, "rule_hits", {}) or {})
    if rules_fired:
        lines.append("  rules fired (winning plan): "
                     + " -> ".join(rules_fired))
    if rule_hits:
        hits = ", ".join(f"{r}:{n}" for r, n in sorted(rule_hits.items()))
        lines.append(f"  alternatives per rule: {hits}")
    phases = dict(getattr(result, "phase_times", {}) or {})
    if phases:
        lines.append("  optimizer phases: " + ", ".join(
            f"{k}={fmt_seconds(v)}" for k, v in phases.items()))
    rule_stats = dict(getattr(result, "rule_stats", {}) or {})
    for phase in sorted(rule_stats):
        per_rule = rule_stats[phase]
        if not per_rule:
            continue
        body = ", ".join(
            f"{name} fired {st.get('fired', 0)}/{st.get('matched', 0)} "
            f"(missed {st.get('missed', 0)})"
            for name, st in sorted(per_rule.items()))
        lines.append(f"    saturation phase {phase}: {body}")
    if getattr(report, "budget_exhausted", False):
        lines.append("  budget: EXHAUSTED -> greedy best-first fallback "
                     "(plan valid; raise node_budget/wall_budget_s for the "
                     "full search)")
    lines.append("  plan:")

    def fetch_annotation(q, binding_site: Optional[str] = None) -> str:
        est = db.estimate(q).n_rows
        parts = [f"est {est:.0f} row(s)", f"~{cm.query_cost(q):.4g}s"]
        seen = obs_sites.get(q.sql())
        if seen:
            o = seen.get("avg_rows", 0.0)
            parts.append(f"observed {o:.0f} over {int(seen.get('n', 0))} "
                         f"exec(s), q-error {q_error(est, o):.1f}")
        qe = qerror_sites.get(q.sql())
        if qe:
            parts.append(f"tracked q-error last {qe.get('last', 1.0):.1f} "
                         f"/ worst {qe.get('worst', 1.0):.1f}")
        if binding_site is not None:
            b = site_bindings.get(binding_site)
            if b:
                parts.append(f"binding diversity {b.get('fraction', 0):.2f} "
                             f"({int(b.get('distinct', 0))}/"
                             f"{int(b.get('lookups', 0))} distinct)")
        return "; ".join(parts)

    def stmt_line(stmt) -> str:
        if isinstance(stmt, Prefetch):
            am = cm.amortize(cm.prefetch_cost(stmt.query))
            note = f"prefetch cost ~{cm.prefetch_cost(stmt.query):.4g}s"
            if cm.batch_size > 1:
                note += f", ~{am:.4g}s amortized over batch={cm.batch_size:g}"
            return f"{stmt!r}   [{note}; {fetch_annotation(stmt.query)}]"
        ann: List[str] = []
        from .signals import _stmt_exprs, _query_of
        for e in _stmt_exprs(stmt):
            q = _query_of(e)
            if q is not None:
                ann.append(fetch_annotation(q, query_site_key(q)))
            elif isinstance(e, ILoadAll):
                ann.append(f"full fetch of {e.table} "
                           f"({db.table(e.table).nrows} row(s))")
        return f"{stmt!r}" + (f"   [{'; '.join(ann)}]" if ann else "")

    def iter_annotation(site: str, est: float) -> str:
        parts = [f"est {est:g} iter(s)"]
        seen = obs_iters.get(site)
        if seen:
            o = float(seen.get("avg_iters", 0.0))
            parts.append(f"observed {o:g}, q-error {q_error(est, o):.1f}")
        return ", ".join(parts)

    def walk(r, depth: int) -> None:
        pad = "    " + "  " * depth
        if isinstance(r, BasicBlock):
            lines.append(pad + stmt_line(r.stmt))
            return
        if isinstance(r, SeqRegion):
            for c in r.parts:
                walk(c, depth)
            return
        if isinstance(r, LoopRegion):
            site = loop_site_key(r.var, r.source)
            note = notes.get(r.key())
            tier = ""
            if note is not None:
                tier = (", columnar (compiled tier)"
                        if note.verdict == "columnar"
                        else f", interpreter ({note.reason})")
            # a loop over a query IS a fetch site: join the feedback
            # controller's per-site q-error account against it too
            from .signals import _query_of
            qerr = ""
            q = _query_of(r.source)
            if q is not None:
                qe = qerror_sites.get(q.sql())
                if qe:
                    qerr = (f", tracked q-error last "
                            f"{qe.get('last', 1.0):.1f} / worst "
                            f"{qe.get('worst', 1.0):.1f}")
            lines.append(pad + f"for {r.var} : {r.source!r}   "
                         f"[{iter_annotation(site, cm.loop_iters(r.source, r.var))}"
                         f"{qerr}{tier}]")
            walk(r.body, depth + 1)
            return
        if isinstance(r, WhileRegion):
            site = while_site_key(r.pred)
            lines.append(pad + f"while {r.pred!r}   "
                         f"[{iter_annotation(site, cm.while_iters(r.pred))}]")
            walk(r.body, depth + 1)
            return
        if isinstance(r, CondRegion):
            lines.append(pad + f"if {r.pred!r}")
            walk(r.then_r, depth + 1)
            if r.else_r is not None:
                lines.append(pad + "else")
                walk(r.else_r, depth + 1)
            return
        lines.append(pad + repr(r))

    walk(exe.program.body, 0)

    from .signals import scan_plan
    found = scan_plan(exe, feedback=feedback)
    if found:
        lines.append("  signals:")
        for s in found:
            lines.append(f"    {s.describe()}")
    return "\n".join(lines)
