"""Shared text-rendering helpers: one duration formatter, one table path.

Every human-facing formatter in the repo (trace trees, ``explain()``,
triage tables, the analysis/ report generators) goes through these two
functions, so durations and tables read identically everywhere.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence

__all__ = ["fmt_seconds", "markdown_table"]


def fmt_seconds(s: Optional[float], none: str = "—") -> str:
    """``1.23s`` / ``4.5ms`` / ``678µs`` — None renders as a dash."""
    if s is None:
        return none
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.1f}ms"
    return f"{s*1e6:.0f}µs"


def markdown_table(headers: Sequence[str],
                   rows: Iterable[Sequence[object]]) -> str:
    lines = ["| " + " | ".join(str(h) for h in headers) + " |",
             "|" + "---|" * len(headers)]
    for row in rows:
        lines.append("| " + " | ".join(str(c) for c in row) + " |")
    return "\n".join(lines)
