"""Unified metrics registry: labeled counters / gauges / histograms.

One :class:`MetricsRegistry` per component (session, serving runtime,
feedback controller, compile manager) replaces the scattered ad-hoc
telemetry dicts. The legacy attributes and telemetry-dict shapes are kept
as views: a :class:`registry_counter` descriptor routes ``obj.counter += 1``
mutations — including external call sites like
``session.executions += n`` — through the owning component's registry, so
the registry value and the telemetry dict reconcile bit-for-bit by
construction.

``snapshot()`` flattens everything to ``{name{label=value,...}: number}``;
``diff(older)`` returns the numeric deltas — the two primitives every
"what changed during this serve cycle?" question needs.

**Multi-worker aggregation.** Flat snapshots cannot be merged losslessly:
histogram stats are flattened to ``name_count``/``name_min``/… suffixes, so
a combiner cannot tell a counter named ``x_min`` from a histogram's min —
summing either loses information. ``dump()`` therefore exports the
STRUCTURED form (counters / gauges / hists kept apart) and
:func:`combine_snapshots` folds any number of dumps — with disjoint or
overlapping label sets — into one: counters sum, histogram stats combine
component-wise (count/sum add, min/max fold), numeric gauges sum (across
workers, "entries held" really is the sum). The fold is associative and
commutative by construction — ``combine(a, combine(b, c)) ==
combine(combine(a, b), c)`` is pinned by property tests — which is what
lets a cluster merge per-worker registries in any order, incrementally,
and still reconcile bit-for-bit with the per-worker sums.
``ingest()`` accepts a structured dump too, merging it into the registry
(counters accumulate, hist stats fold) instead of flattening to gauges.
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

__all__ = ["MetricsRegistry", "registry_counter", "merge_snapshots",
           "combine_snapshots"]

_LabelKey = Tuple[Tuple[str, object], ...]


def _key(name: str, labels: Mapping[str, object]) -> Tuple[str, _LabelKey]:
    return (name, tuple(sorted(labels.items())))


def _flat_name(name: str, labels: _LabelKey) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Labeled counters, gauges, and histograms with snapshot/diff."""

    def __init__(self):
        self._counters: Dict[Tuple[str, _LabelKey], float] = {}
        self._gauges: Dict[Tuple[str, _LabelKey], object] = {}
        self._hists: Dict[Tuple[str, _LabelKey], Dict[str, float]] = {}

    # ------------------------------------------------------------- counters
    def inc(self, name: str, value: float = 1, **labels) -> None:
        k = _key(name, labels)
        self._counters[k] = self._counters.get(k, 0) + value

    def set_counter(self, name: str, value, **labels) -> None:
        """Absolute assignment — the hook legacy ``obj.counter = 0`` /
        ``obj.counter += 1`` attribute writes route through."""
        self._counters[_key(name, labels)] = value

    def value(self, name: str, default=0, **labels):
        return self._counters.get(_key(name, labels), default)

    # --------------------------------------------------------------- gauges
    def gauge(self, name: str, value, **labels) -> None:
        self._gauges[_key(name, labels)] = value

    def gauge_value(self, name: str, default=None, **labels):
        return self._gauges.get(_key(name, labels), default)

    def ingest(self, mapping: Mapping[str, object], prefix: str = "") -> None:
        """Fold an existing telemetry dict's numeric leaves into gauges
        (the migration path for stats dicts owned by other components,
        e.g. SiteCache / PlanStore / ArtifactCache).

        A STRUCTURED dump (the :meth:`dump` shape) is merged instead of
        flattened: counters accumulate, histogram stats fold component-wise,
        gauges overwrite — so a registry can absorb another worker's
        registry without losing the counter/gauge/hist distinction."""
        if _is_structured(mapping):
            for k, v in mapping.get("counters", {}).items():
                self.inc(prefix + k, v)
            for k, v in mapping.get("gauges", {}).items():
                self.gauge(prefix + k, v)
            for k, h in mapping.get("hists", {}).items():
                self.merge_hist(prefix + k, h)
            return
        for k, v in mapping.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                self.gauge(prefix + k, v)

    # ----------------------------------------------------------- histograms
    def observe(self, name: str, value: float, **labels) -> None:
        k = _key(name, labels)
        h = self._hists.get(k)
        if h is None:
            self._hists[k] = {"count": 1, "sum": value,
                              "min": value, "max": value}
        else:
            h["count"] += 1
            h["sum"] += value
            h["min"] = min(h["min"], value)
            h["max"] = max(h["max"], value)

    def histogram(self, name: str, **labels) -> Optional[Dict[str, float]]:
        h = self._hists.get(_key(name, labels))
        return dict(h) if h is not None else None

    def merge_hist(self, name: str, stats: Mapping[str, float],
                   **labels) -> None:
        """Fold another histogram's (count, sum, min, max) into this one —
        the per-bucket combine :func:`combine_snapshots` and structured
        :meth:`ingest` are built on. Equivalent to having observed the other
        histogram's samples here (component-wise: counts and sums add,
        min/max fold), so merging is associative and lossless."""
        if not stats.get("count"):
            return
        k = _key(name, labels)
        h = self._hists.get(k)
        if h is None:
            self._hists[k] = {"count": stats["count"], "sum": stats["sum"],
                              "min": stats["min"], "max": stats["max"]}
        else:
            h["count"] += stats["count"]
            h["sum"] += stats["sum"]
            h["min"] = min(h["min"], stats["min"])
            h["max"] = max(h["max"], stats["max"])

    # ------------------------------------------------------- snapshot / diff
    def dump(self) -> Dict[str, Dict[str, object]]:
        """The STRUCTURED snapshot: counters, gauges, and histograms kept
        apart (flat label-rendered names inside each kind). This is the
        mergeable form — :func:`combine_snapshots` folds dumps from many
        workers; ``snapshot()``'s flat view is for humans and diffs."""
        return {
            "counters": {_flat_name(n, l): v
                         for (n, l), v in self._counters.items()},
            "gauges": {_flat_name(n, l): v
                       for (n, l), v in self._gauges.items()},
            "hists": {_flat_name(n, l): dict(h)
                      for (n, l), h in self._hists.items()},
        }

    def snapshot(self) -> Dict[str, object]:
        out: Dict[str, object] = {}
        for (name, labels), v in self._counters.items():
            out[_flat_name(name, labels)] = v
        for (name, labels), v in self._gauges.items():
            out[_flat_name(name, labels)] = v
        for (name, labels), h in self._hists.items():
            base = _flat_name(name, labels)
            for stat, v in h.items():
                out[f"{base}_{stat}"] = v
        return out

    def diff(self, older: Mapping[str, object]) -> Dict[str, object]:
        """Numeric deltas of the current snapshot against an older one
        (new keys diff against zero; non-numeric values compare-and-keep)."""
        now = self.snapshot()
        out: Dict[str, object] = {}
        for k, v in now.items():
            prev = older.get(k, 0)
            if isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and isinstance(prev, (int, float)):
                d = v - prev
                if d:
                    out[k] = d
            elif v != prev:
                out[k] = v
        return out


class registry_counter:
    """Class-level descriptor turning a legacy counter attribute into a
    registry-backed metric. ``obj.<name>`` reads the registry value;
    ``obj.<name> = v`` (hence ``+=``) writes it — the metric name defaults
    to the attribute name, the registry lives at ``obj.<registry_attr>``."""

    def __init__(self, metric: Optional[str] = None,
                 registry_attr: str = "metrics"):
        self.metric = metric
        self.registry_attr = registry_attr

    def __set_name__(self, owner, name):
        if self.metric is None:
            self.metric = name

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return getattr(obj, self.registry_attr).value(self.metric)

    def __set__(self, obj, value):
        getattr(obj, self.registry_attr).set_counter(self.metric, value)


def merge_snapshots(**named: Mapping[str, object]) -> Dict[str, object]:
    """Combine component snapshots under name prefixes:
    ``merge_snapshots(serving=a, session=b) -> {"serving_...", ...}``.

    This is the NAMESPACING merge (components keep their identity, flat
    values pass through untouched). To AGGREGATE equal-shaped snapshots
    from many workers — summing counters, folding histograms — use
    :func:`combine_snapshots` on structured :meth:`MetricsRegistry.dump`
    outputs instead; the flat form is not losslessly combinable."""
    out: Dict[str, object] = {}
    for prefix, snap in named.items():
        for k, v in snap.items():
            out[f"{prefix}_{k}"] = v
    return out


_STRUCTURED_KEYS = frozenset({"counters", "gauges", "hists"})


def _is_structured(mapping: Mapping[str, object]) -> bool:
    return (bool(mapping) and set(mapping) <= _STRUCTURED_KEYS
            and all(isinstance(v, Mapping) for v in mapping.values()))


def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def combine_snapshots(*dumps: Mapping[str, Mapping]) -> Dict[str, Dict]:
    """Fold structured dumps (:meth:`MetricsRegistry.dump`) from N workers
    into one, losslessly and associatively:

      * **counters** — sum (a metric absent from a worker counts as 0, so
        disjoint label sets union cleanly);
      * **hists** — component-wise: ``count``/``sum`` add, ``min``/``max``
        fold — exactly the stats of the concatenated sample streams;
      * **gauges** — numeric gauges sum (per-worker "entries" / "bytes_used"
        aggregate to the cluster total); non-numeric gauges must agree or
        the first value wins.

    Every per-element operation (+, min, max) is associative and
    commutative, so ``combine(a, combine(b, c)) == combine(combine(a, b),
    c)`` and worker order never matters — pinned by the property tests in
    ``tests/test_metrics_merge.py``."""
    counters: Dict[str, float] = {}
    gauges: Dict[str, object] = {}
    hists: Dict[str, Dict[str, float]] = {}
    for d in dumps:
        if not _is_structured(d):
            raise TypeError(
                "combine_snapshots takes structured dumps "
                "(MetricsRegistry.dump()); got a flat snapshot — flat "
                "forms merge lossily (histogram suffixes are ambiguous)")
        for k, v in d.get("counters", {}).items():
            counters[k] = counters.get(k, 0) + v
        for k, v in d.get("gauges", {}).items():
            if _num(v) and _num(gauges.get(k, 0)):
                gauges[k] = gauges.get(k, 0) + v
            else:
                gauges.setdefault(k, v)
        for k, h in d.get("hists", {}).items():
            if not h.get("count"):
                continue
            cur = hists.get(k)
            if cur is None:
                hists[k] = dict(h)
            else:
                cur["count"] += h["count"]
                cur["sum"] += h["sum"]
                cur["min"] = min(cur["min"], h["min"])
                cur["max"] = max(cur["max"], h["max"])
    return {"counters": counters, "gauges": gauges, "hists": hists}
