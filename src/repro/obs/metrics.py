"""Unified metrics registry: labeled counters / gauges / histograms.

One :class:`MetricsRegistry` per component (session, serving runtime,
feedback controller, compile manager) replaces the scattered ad-hoc
telemetry dicts. The legacy attributes and telemetry-dict shapes are kept
as views: a :class:`registry_counter` descriptor routes ``obj.counter += 1``
mutations — including external call sites like
``session.executions += n`` — through the owning component's registry, so
the registry value and the telemetry dict reconcile bit-for-bit by
construction.

``snapshot()`` flattens everything to ``{name{label=value,...}: number}``;
``diff(older)`` returns the numeric deltas — the two primitives every
"what changed during this serve cycle?" question needs.
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

__all__ = ["MetricsRegistry", "registry_counter", "merge_snapshots"]

_LabelKey = Tuple[Tuple[str, object], ...]


def _key(name: str, labels: Mapping[str, object]) -> Tuple[str, _LabelKey]:
    return (name, tuple(sorted(labels.items())))


def _flat_name(name: str, labels: _LabelKey) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Labeled counters, gauges, and histograms with snapshot/diff."""

    def __init__(self):
        self._counters: Dict[Tuple[str, _LabelKey], float] = {}
        self._gauges: Dict[Tuple[str, _LabelKey], object] = {}
        self._hists: Dict[Tuple[str, _LabelKey], Dict[str, float]] = {}

    # ------------------------------------------------------------- counters
    def inc(self, name: str, value: float = 1, **labels) -> None:
        k = _key(name, labels)
        self._counters[k] = self._counters.get(k, 0) + value

    def set_counter(self, name: str, value, **labels) -> None:
        """Absolute assignment — the hook legacy ``obj.counter = 0`` /
        ``obj.counter += 1`` attribute writes route through."""
        self._counters[_key(name, labels)] = value

    def value(self, name: str, default=0, **labels):
        return self._counters.get(_key(name, labels), default)

    # --------------------------------------------------------------- gauges
    def gauge(self, name: str, value, **labels) -> None:
        self._gauges[_key(name, labels)] = value

    def gauge_value(self, name: str, default=None, **labels):
        return self._gauges.get(_key(name, labels), default)

    def ingest(self, mapping: Mapping[str, object], prefix: str = "") -> None:
        """Fold an existing telemetry dict's numeric leaves into gauges
        (the migration path for stats dicts owned by other components,
        e.g. SiteCache / PlanStore / ArtifactCache)."""
        for k, v in mapping.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                self.gauge(prefix + k, v)

    # ----------------------------------------------------------- histograms
    def observe(self, name: str, value: float, **labels) -> None:
        k = _key(name, labels)
        h = self._hists.get(k)
        if h is None:
            self._hists[k] = {"count": 1, "sum": value,
                              "min": value, "max": value}
        else:
            h["count"] += 1
            h["sum"] += value
            h["min"] = min(h["min"], value)
            h["max"] = max(h["max"], value)

    def histogram(self, name: str, **labels) -> Optional[Dict[str, float]]:
        h = self._hists.get(_key(name, labels))
        return dict(h) if h is not None else None

    # ------------------------------------------------------- snapshot / diff
    def snapshot(self) -> Dict[str, object]:
        out: Dict[str, object] = {}
        for (name, labels), v in self._counters.items():
            out[_flat_name(name, labels)] = v
        for (name, labels), v in self._gauges.items():
            out[_flat_name(name, labels)] = v
        for (name, labels), h in self._hists.items():
            base = _flat_name(name, labels)
            for stat, v in h.items():
                out[f"{base}_{stat}"] = v
        return out

    def diff(self, older: Mapping[str, object]) -> Dict[str, object]:
        """Numeric deltas of the current snapshot against an older one
        (new keys diff against zero; non-numeric values compare-and-keep)."""
        now = self.snapshot()
        out: Dict[str, object] = {}
        for k, v in now.items():
            prev = older.get(k, 0)
            if isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and isinstance(prev, (int, float)):
                d = v - prev
                if d:
                    out[k] = d
            elif v != prev:
                out[k] = v
        return out


class registry_counter:
    """Class-level descriptor turning a legacy counter attribute into a
    registry-backed metric. ``obj.<name>`` reads the registry value;
    ``obj.<name> = v`` (hence ``+=``) writes it — the metric name defaults
    to the attribute name, the registry lives at ``obj.<registry_attr>``."""

    def __init__(self, metric: Optional[str] = None,
                 registry_attr: str = "metrics"):
        self.metric = metric
        self.registry_attr = registry_attr

    def __set_name__(self, owner, name):
        if self.metric is None:
            self.metric = name

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return getattr(obj, self.registry_attr).value(self.metric)

    def __set__(self, obj, value):
        getattr(obj, self.registry_attr).set_counter(self.metric, value)


def merge_snapshots(**named: Mapping[str, object]) -> Dict[str, object]:
    """Combine component snapshots under name prefixes:
    ``merge_snapshots(serving=a, session=b) -> {"serving_...", ...}``."""
    out: Dict[str, object] = {}
    for prefix, snap in named.items():
        for k, v in snap.items():
            out[f"{prefix}_{k}"] = v
    return out
