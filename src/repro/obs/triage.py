"""Fleet triage: rank served programs by traffic-weighted estimated win.

Re-optimization effort should follow the traffic: a mildly-bad plan
serving 80% of requests is worth more attention than a terrible plan
served twice. :func:`triage_fleet` scores every program registered on a
:class:`~repro.runtime.serving.ServingRuntime` as

    score = invocation_share × drift × (1 + Σ signal severity)

where *drift* is the worst observed estimate-vs-reality ratio among the
feedback controller's drift events touching the program's tables (1.0
when estimates held), and the signal severities come from
:func:`~repro.obs.signals.scan_plan` over the CURRENT serving plan.

:func:`triage_cluster` is the sharded-cluster view: the same scoring over
the union of every worker's fleet, with per-shard request counts, the hot
shard, and its skew factor folded in — a program whose traffic piles onto
one worker scores higher than its cluster-wide share alone would say,
because that one worker IS its bottleneck.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from .render import markdown_table

__all__ = ["TriageRow", "triage_fleet", "triage_cluster", "render_triage"]


@dataclasses.dataclass(frozen=True)
class TriageRow:
    name: str
    requests: int
    share: float            # fraction of all served requests
    drift: float            # worst observed drift ratio on its tables (>= 1)
    severity: float         # Σ scan_plan signal severities on current plan
    signals: Tuple[str, ...]
    score: float
    qerror: float = 1.0     # worst tracked per-site q-error on its tables
    # cluster columns (triage_cluster only; single-runtime rows keep the
    # defaults, so render/consumers handle both shapes)
    shard_requests: Tuple[int, ...] = ()  # this program's requests per worker
    hot_shard: int = -1                   # worker serving the most of them
    shard_share: float = 0.0              # hot shard's fraction of them
    skew: float = 1.0                     # shard_share × n_workers (1 = even)

    def describe(self) -> str:
        sig = ",".join(self.signals) or "-"
        hot = (f", hot shard {self.hot_shard} ({self.skew:.1f}x skew)"
               if self.shard_requests else "")
        return (f"{self.name}: score {self.score:.3f} "
                f"(share {self.share:.2f}, drift {self.drift:.1f}x, "
                f"q-error {self.qerror:.1f}, signals {sig}{hot})")


def triage_fleet(rt) -> List[TriageRow]:
    """Score and rank every program registered on ``rt`` (a
    :class:`~repro.runtime.serving.ServingRuntime`), highest first."""
    from ..api.cache import program_tables
    from .signals import scan_plan

    counts = dict(getattr(rt, "_requests_by_program", {}))
    total = sum(counts.values())
    events = rt.feedback.events if rt.feedback is not None else []
    qsites = (rt.feedback.qerrors.sites()
              if rt.feedback is not None else {})

    rows: List[TriageRow] = []
    for name in sorted(rt._programs):
        program = rt._programs[name]
        exe = rt._executables[name]
        requests = counts.get(name, 0)
        share = requests / total if total else 0.0
        tables = set(program_tables(program))
        drift = 1.0
        for e in events:
            if tables & set(e.tables):
                drift = max(drift, float(e.ratio))
        qerr = 1.0
        for s in qsites.values():
            if tables & set(s.tables):
                qerr = max(qerr, float(s.worst))
        found = scan_plan(exe, feedback=rt.feedback)
        severity = sum(s.severity for s in found)
        rows.append(TriageRow(
            name=name, requests=requests, share=share, drift=drift,
            severity=severity,
            signals=tuple(sorted({s.kind for s in found})),
            score=share * drift * (1.0 + severity), qerror=qerr))
    rows.sort(key=lambda r: (-r.score, r.name))
    return rows


def triage_cluster(cluster) -> List[TriageRow]:
    """Score and rank every program registered on a
    :class:`~repro.cluster.runtime.ClusterRuntime`, highest first.

    Same scoring as :func:`triage_fleet` with one extra factor — the hot
    shard's skew (its share of the program's traffic × worker count; 1.0
    when spread evenly) — and the per-shard request counts as columns."""
    from ..api.cache import program_tables
    from .signals import scan_plan

    workers = list(cluster.workers)
    n = len(workers)
    per_shard: dict = {}
    for w, rt in enumerate(workers):
        for name, c in getattr(rt, "_requests_by_program", {}).items():
            per_shard.setdefault(name, [0] * n)[w] += c
    total = sum(sum(v) for v in per_shard.values())

    rows: List[TriageRow] = []
    for name in sorted(cluster._programs):
        program = cluster._programs[name]
        counts = per_shard.get(name, [0] * n)
        requests = sum(counts)
        hot = counts.index(max(counts))
        # judge the plan (and feedback evidence) on the hot worker — the
        # one whose serving this program actually bottlenecks
        rt = workers[hot]
        exe = rt._executables.get(name) or workers[0]._executables[name]
        share = requests / total if total else 0.0
        tables = set(program_tables(program))
        drift = 1.0
        qerr = 1.0
        for w in workers:
            for e in (w.feedback.events if w.feedback is not None else []):
                if tables & set(e.tables):
                    drift = max(drift, float(e.ratio))
            if w.feedback is not None:
                for s in w.feedback.qerrors.sites().values():
                    if tables & set(s.tables):
                        qerr = max(qerr, float(s.worst))
        found = scan_plan(exe, feedback=rt.feedback)
        severity = sum(s.severity for s in found)
        shard_share = counts[hot] / requests if requests else 0.0
        skew = shard_share * n if requests else 1.0
        rows.append(TriageRow(
            name=name, requests=requests, share=share, drift=drift,
            severity=severity,
            signals=tuple(sorted({s.kind for s in found})),
            score=share * drift * (1.0 + severity) * max(1.0, skew),
            qerror=qerr,
            shard_requests=tuple(counts), hot_shard=hot,
            shard_share=shard_share, skew=skew))
    rows.sort(key=lambda r: (-r.score, r.name))
    return rows


def render_triage(rows: List[TriageRow]) -> str:
    if any(r.shard_requests for r in rows):
        return markdown_table(
            ["program", "requests", "share", "shards", "hot", "skew",
             "drift", "q-error", "severity", "signals", "score"],
            [(r.name, r.requests, f"{r.share:.2f}",
              "/".join(str(c) for c in r.shard_requests) or "—",
              r.hot_shard if r.shard_requests else "—", f"{r.skew:.1f}x",
              f"{r.drift:.1f}x", f"{r.qerror:.1f}", f"{r.severity:.2f}",
              ",".join(r.signals) or "—", f"{r.score:.3f}")
             for r in rows])
    return markdown_table(
        ["program", "requests", "share", "drift", "q-error", "severity",
         "signals", "score"],
        [(r.name, r.requests, f"{r.share:.2f}", f"{r.drift:.1f}x",
          f"{r.qerror:.1f}", f"{r.severity:.2f}",
          ",".join(r.signals) or "—", f"{r.score:.3f}") for r in rows])
