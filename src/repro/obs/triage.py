"""Fleet triage: rank served programs by traffic-weighted estimated win.

Re-optimization effort should follow the traffic: a mildly-bad plan
serving 80% of requests is worth more attention than a terrible plan
served twice. :func:`triage_fleet` scores every program registered on a
:class:`~repro.runtime.serving.ServingRuntime` as

    score = invocation_share × drift × (1 + Σ signal severity)

where *drift* is the worst observed estimate-vs-reality ratio among the
feedback controller's drift events touching the program's tables (1.0
when estimates held), and the signal severities come from
:func:`~repro.obs.signals.scan_plan` over the CURRENT serving plan.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from .render import markdown_table

__all__ = ["TriageRow", "triage_fleet", "render_triage"]


@dataclasses.dataclass(frozen=True)
class TriageRow:
    name: str
    requests: int
    share: float            # fraction of all served requests
    drift: float            # worst observed drift ratio on its tables (>= 1)
    severity: float         # Σ scan_plan signal severities on current plan
    signals: Tuple[str, ...]
    score: float

    def describe(self) -> str:
        sig = ",".join(self.signals) or "-"
        return (f"{self.name}: score {self.score:.3f} "
                f"(share {self.share:.2f}, drift {self.drift:.1f}x, "
                f"signals {sig})")


def triage_fleet(rt) -> List[TriageRow]:
    """Score and rank every program registered on ``rt`` (a
    :class:`~repro.runtime.serving.ServingRuntime`), highest first."""
    from ..api.cache import program_tables
    from .signals import scan_plan

    counts = dict(getattr(rt, "_requests_by_program", {}))
    total = sum(counts.values())
    events = rt.feedback.events if rt.feedback is not None else []

    rows: List[TriageRow] = []
    for name in sorted(rt._programs):
        program = rt._programs[name]
        exe = rt._executables[name]
        requests = counts.get(name, 0)
        share = requests / total if total else 0.0
        tables = set(program_tables(program))
        drift = 1.0
        for e in events:
            if tables & set(e.tables):
                drift = max(drift, float(e.ratio))
        found = scan_plan(exe, feedback=rt.feedback)
        severity = sum(s.severity for s in found)
        rows.append(TriageRow(
            name=name, requests=requests, share=share, drift=drift,
            severity=severity,
            signals=tuple(sorted({s.kind for s in found})),
            score=share * drift * (1.0 + severity)))
    rows.sort(key=lambda r: (-r.score, r.name))
    return rows


def render_triage(rows: List[TriageRow]) -> str:
    return markdown_table(
        ["program", "requests", "share", "drift", "severity",
         "signals", "score"],
        [(r.name, r.requests, f"{r.share:.2f}", f"{r.drift:.1f}x",
          f"{r.severity:.2f}", ",".join(r.signals) or "—",
          f"{r.score:.3f}") for r in rows])
