"""Bad-plan-pattern catalog: structured signals over a (rewritten) plan.

:func:`scan_plan` walks a program's region tree — for an
:class:`~repro.api.session.Executable` that is the REWRITTEN program, so a
pattern the optimizer already eliminated (N+1 navigation folded into a
join, a per-iteration query hoisted to a batch-amortized prefetch) no
longer fires — and emits one :class:`Signal` per detected pattern:

  * ``n_plus_one`` — ORM navigation or a parameterized query inside a
    cursor-loop body: one point query per iterated row;
  * ``query_in_while`` — a server fetch inside a guarded (while) body,
    re-executed every data-dependent iteration; a binding-free prefetch
    under a BATCHED context is exempt (the site cache serves it once per
    batch — exactly the rewrite the optimizer uses to fix this pattern);
  * ``unbatched_writes`` — ``UPDATE`` statements inside a loop/while body,
    one server round trip per iteration;
  * ``diverse_bindings`` — a parameterized-site group whose OBSERVED
    distinct-binding fraction is high: the site cache cannot amortize it,
    so the plan pays nearly full fetch cost per invocation;
  * ``interpreter_hot_loop`` — a hot plan whose loops the compiled tier
    rejects (early exit, nested iteration, …), pinned row-at-a-time.

Severity is a coarse [0, 1] ranking weight (``triage`` multiplies it into
the traffic share), not a probability.
"""

from __future__ import annotations

import dataclasses
from typing import List

__all__ = ["Signal", "scan_plan"]

# observed distinct-binding fraction above which a parameterized site is
# considered cache-hostile (nearly every binding misses)
DIVERSE_BINDING_FRACTION = 0.8
# invocations after which a plan counts as hot for interpreter_hot_loop
HOT_RUNS = 3


@dataclasses.dataclass(frozen=True)
class Signal:
    """One detected bad-plan pattern, anchored to a site."""

    kind: str        # n_plus_one | query_in_while | unbatched_writes |
    #                  diverse_bindings | interpreter_hot_loop
    severity: float  # [0, 1] ranking weight
    site: str        # region/site key the pattern anchors to
    detail: str      # human-readable one-liner
    program: str = ""

    def describe(self) -> str:
        return f"[{self.kind} {self.severity:.2f}] {self.detail}"


def _query_of(e):
    return getattr(e, "query", None)


def _walk_exprs(e, out: List) -> None:
    """Collect every IExpr reachable from ``e`` (the api.cache walker
    idiom: fixed child attributes + args + bindings)."""
    from ..core.regions import IExpr
    if not isinstance(e, IExpr):
        return
    out.append(e)
    for attr in ("base", "left", "right", "keyexpr", "valexpr"):
        sub = getattr(e, attr, None)
        if isinstance(sub, IExpr):
            _walk_exprs(sub, out)
    for sub in getattr(e, "args", ()) or ():
        _walk_exprs(sub, out)
    for _, sub in getattr(e, "bindings", ()) or ():
        _walk_exprs(sub, out)


def _stmt_exprs(stmt) -> List:
    out: List = []
    for attr in ("expr", "keyexpr", "valexpr", "val"):
        _walk_exprs(getattr(stmt, attr, None), out)
    return out


def _is_parameterized(e) -> bool:
    from ..core.cost import query_has_params
    q = _query_of(e)
    if q is None:
        return False
    if getattr(e, "bindings", ()):
        return True
    try:
        return query_has_params(q)
    except Exception:
        return False


def scan_plan(target, *, feedback=None, stats=None,
              hot_runs_threshold: int = HOT_RUNS) -> List[Signal]:
    """Detect known bad-plan patterns in ``target`` (an Executable or a
    plain Program); returns :class:`Signal`\\ s ranked most severe first.

    For an Executable the REWRITTEN program is scanned under the context
    it was compiled for, so every signal answers "what is still wrong
    AFTER the optimizer had its say". ``stats`` (a
    :class:`~repro.core.context.StatsProfile`) or ``feedback`` (a
    :class:`~repro.runtime.feedback.FeedbackController`) supply observed
    binding-diversity fractions for ``diverse_bindings``."""
    from ..api.cache import program_param_sites
    from ..core.context import while_site_key, loop_site_key
    from ..core.regions import (BasicBlock, CondRegion, ICacheLookup, ILoadAll,
                                INav, LoopRegion, Prefetch, Program, Region,
                                UpdateRow, WhileRegion, compilability)

    if isinstance(target, (Program, Region)):
        program = target if isinstance(target, Program) else \
            Program("anonymous", target, ())
        context = None
        n_runs = 0
    else:
        program = target.program
        context = target.context
        n_runs = target.n_runs
    batch_size = context.batch_size if context is not None else 1
    name = program.name
    signals: List[Signal] = []

    def emit(kind: str, severity: float, site: str, detail: str) -> None:
        signals.append(Signal(kind=kind, severity=min(1.0, severity),
                              site=site, detail=detail, program=name))

    # ---------------------------------------------- structural region walk
    def check_fetches(exprs, in_loop, in_while, where: str) -> None:
        """Emit fetch-in-iteration signals for every server-touching
        expression in ``exprs`` (statement operands or a loop's source)."""
        for e in exprs:
            q = _query_of(e)
            is_fetch = q is not None or isinstance(e, ILoadAll)
            if isinstance(e, ICacheLookup):
                continue  # local cache lookup, no server interaction
            if in_while and is_fetch:
                what = q.sql() if q is not None else f"loadAll({e.table})"
                emit("query_in_while", 0.7, in_while,
                     f"server fetch in a {where} inside a while body, "
                     f"re-executed every data-dependent iteration: {what}")
            if in_loop:
                if isinstance(e, INav):
                    emit("n_plus_one", 0.8, in_loop,
                         f"ORM navigation ->{e.target} in a loop body: "
                         f"one point query per iterated row")
                elif is_fetch and _is_parameterized(e):
                    emit("n_plus_one", 0.8, in_loop,
                         f"parameterized query per loop iteration "
                         f"({where}): {q.sql()}")

    def walk(r: Region, loop_sites: tuple, while_sites: tuple) -> None:
        in_loop = loop_sites[-1] if loop_sites else None
        in_while = while_sites[-1] if while_sites else None
        if isinstance(r, BasicBlock):
            stmt = r.stmt
            if isinstance(stmt, UpdateRow) and (in_loop or in_while):
                emit("unbatched_writes", 0.5, in_loop or in_while,
                     f"UPDATE {stmt.table} inside an iteration body — "
                     f"one round trip per iteration")
            if isinstance(stmt, Prefetch):
                # a binding-free prefetch inside a while body re-fetches
                # per iteration in one-shot execution; under a batched
                # context the site cache serves it once per batch — the
                # optimizer's own fix for query_in_while
                if in_while and batch_size <= 1:
                    emit("query_in_while", 0.7, in_while,
                         f"prefetch re-executed each while iteration: "
                         f"{stmt.query.sql()}")
            check_fetches(_stmt_exprs(stmt), in_loop, in_while, "statement")
            return
        if isinstance(r, LoopRegion):
            # the loop's SOURCE is itself a fetch site: iterated inside an
            # enclosing while/loop it re-executes per outer iteration
            src_exprs: List = []
            _walk_exprs(r.source, src_exprs)
            check_fetches(src_exprs, in_loop, in_while, "loop source")
            walk(r.body, loop_sites + (loop_site_key(r.var, r.source),),
                 while_sites)
            return
        if isinstance(r, WhileRegion):
            walk(r.body, loop_sites,
                 while_sites + (while_site_key(r.pred),))
            return
        if isinstance(r, CondRegion):
            for c in r.children():
                walk(c, loop_sites, while_sites)
            return
        for c in r.children():
            walk(c, loop_sites, while_sites)

    walk(program.body, (), ())

    # -------------------------------------- observed binding diversity
    profile = stats
    if profile is None and context is not None and context.stats.bindings:
        profile = context.stats
    published = {}
    if profile is not None:
        published.update(dict(profile.bindings))
    if feedback is not None:
        published.update({k: v for k, v in
                          getattr(feedback, "_published_bindings", {}).items()
                          if v is not None})
    for group in program_param_sites(program):
        frac = published.get(group)
        if frac is not None and frac >= DIVERSE_BINDING_FRACTION:
            emit("diverse_bindings", frac, group,
                 f"parameterized site group {group}: observed "
                 f"distinct-binding fraction {frac:.2f} — the site cache "
                 f"cannot amortize it")

    # -------------------------------------------- compiled-tier eligibility
    if n_runs >= hot_runs_threshold:
        for note in compilability(program).values():
            if note.kind == "loop" and note.verdict == "interpreter":
                emit("interpreter_hot_loop", 0.4, note.site,
                     f"hot plan ({n_runs} invocation(s)) with a loop the "
                     f"compiled tier rejects: {note.reason}")

    signals.sort(key=lambda s: (-s.severity, s.kind, s.site))
    return signals
