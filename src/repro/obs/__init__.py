"""Serving observability: trace spans, metrics registry, plan diagnostics.

Four pieces, threaded through every tier of the framework:

  * :mod:`repro.obs.trace` — nested wall+simulated-clock spans (compile →
    saturation rounds; serve → batch → site fetch → kernel invoke → swap
    verdicts), JSONL export, text flamegraph rendering; a no-op tracer by
    default so the hot path pays only a branch;
  * :mod:`repro.obs.metrics` — labeled counters/gauges/histograms with
    ``snapshot()``/``diff()``; the legacy telemetry dicts are
    backwards-compatible views over per-component registries;
  * :mod:`repro.obs.explain` / :mod:`repro.obs.signals` — ``explain()``
    renders the winning region tree annotated with estimated cost, rule
    provenance, estimated-vs-observed counts and q-error; ``scan_plan()``
    detects known bad-plan patterns (N+1 navigation, query-inside-while,
    unbatched writes, cache-hostile binding diversity, interpreter-bound
    hot loops) as structured :class:`~repro.obs.signals.Signal`\\ s;
  * :mod:`repro.obs.triage` — ranks a serving fleet's programs by
    traffic-weighted estimated win so re-optimization follows the traffic.

``signals``/``explain``/``triage`` load lazily (PEP 562): they import the
API layer, which itself imports ``obs.trace``/``obs.metrics``.
"""

from .metrics import MetricsRegistry, merge_snapshots, registry_counter
from .render import fmt_seconds, markdown_table
from .trace import NOOP_TRACER, NoopTracer, Span, Tracer

__all__ = [
    "MetricsRegistry", "registry_counter", "merge_snapshots",
    "fmt_seconds", "markdown_table",
    "Tracer", "NoopTracer", "Span", "NOOP_TRACER",
    "Signal", "scan_plan", "explain_plan", "TriageRow", "triage_fleet",
    "render_triage",
]

_LAZY = {
    "Signal": ("signals", "Signal"),
    "scan_plan": ("signals", "scan_plan"),
    "explain_plan": ("explain", "explain_plan"),
    "TriageRow": ("triage", "TriageRow"),
    "triage_fleet": ("triage", "triage_fleet"),
    "render_triage": ("triage", "render_triage"),
}


def __getattr__(name):
    entry = _LAZY.get(name)
    if entry is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    mod = importlib.import_module(f".{entry[0]}", __name__)
    val = getattr(mod, entry[1])
    globals()[name] = val
    return val
