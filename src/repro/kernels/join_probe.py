"""Equi-join probe for TPU via Pallas.

The application-side join of Cobra's prefetch plans (P2: cacheByColumn +
lookup) — the TPU adaptation of a hash-table probe. Pointer-chasing hash
tables have no TPU analogue, so the build side is a direct-address table
(dense integer key space, the common case for surrogate keys): slot j holds
the row index of the build row with key j, or -1. The probe kernel streams
key blocks through VMEM and gathers slots; the full table stays VMEM-
resident (4 MB per million build keys — fits; larger tables fall back to
the jnp path in ops.py).

Validated in interpret mode against ``ref.join_probe_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["join_probe", "build_direct_table"]


def build_direct_table(table_keys, key_space: int):
    """slot[j] = row index of build key j, else -1. Keys must be unique."""
    slots = jnp.full((key_space,), -1, jnp.int32)
    return slots.at[table_keys].set(jnp.arange(table_keys.shape[0],
                                               dtype=jnp.int32))


def _kernel(keys_ref, table_ref, out_ref, *, key_space):
    keys = keys_ref[...]
    safe = jnp.clip(keys, 0, key_space - 1)
    idx = jnp.take(table_ref[...], safe, axis=0)
    valid = (keys >= 0) & (keys < key_space)
    out_ref[...] = jnp.where(valid, idx, -1)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def join_probe(probe_keys, table, block_n: int = 1024, interpret: bool = True):
    """probe_keys (N,) int32; table (M,) direct-address slots (int32).
    Returns (N,) int32 row indices into the build side, -1 when no match."""
    N = probe_keys.shape[0]
    M = table.shape[0]
    if N == 0:
        return jnp.zeros((0,), jnp.int32)
    if M == 0:
        # empty build side: every probe misses (a zero-length VMEM block
        # has no grid mapping, so short-circuit before pallas_call)
        return jnp.full((N,), -1, jnp.int32)
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        probe_keys = jnp.pad(probe_keys, (0, pad), constant_values=-1)
    Np = N + pad

    out = pl.pallas_call(
        functools.partial(_kernel, key_space=M),
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda ni: (ni,)),
            pl.BlockSpec((M,), lambda ni: (0,)),  # table resident in VMEM
        ],
        out_specs=pl.BlockSpec((bn,), lambda ni: (ni,)),
        out_shape=jax.ShapeDtypeStruct((Np,), jnp.int32),
        interpret=interpret,
    )(probe_keys.astype(jnp.int32), table)
    return out[:N]
