"""Blocked online-softmax attention (flash attention) for TPU via Pallas.

Targets the MXU: Q/K/V tiles live in VMEM, scores are (Bq, Bk) matmuls, and
softmax state (running max m, sum l, fp32 accumulator) is carried across
K-blocks in VMEM scratch. The grid is (B·H, Tq/Bq, Tk/Bk) — the TPU grid is
sequential in the last dimension, so the scratch carry is valid; the K/V
BlockSpec streams one (Bk, hd) tile per step (true streaming: VMEM working
set is Bq·hd + 2·Bk·hd + Bq·Bk fp32 ≈ 1–2 MB at the default 128×128 tiles,
inside the ~16 MB/core budget).

Supports causal masking, GQA (K/V index map folds the query head onto its
KV group), sliding-window (SWA) and chunked local attention (llama4-style).

Validated in interpret mode against ``ref.flash_attention_ref`` over
shape/dtype sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, chunk, block_q, block_k, n_kb, q_offset):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (Bq, hd)
    k = k_ref[0].astype(jnp.float32)                    # (Bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = q @ k.T                                         # (Bq, Bk)

    qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if chunk is not None:
        mask &= (kpos // chunk) == (qpos // chunk)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ki == n_kb - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "chunk",
                                             "scale", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None, chunk: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, H, Tq, hd); k/v: (B, KV, Tk, hd). Returns (B, H, Tq, hd).

    interpret=True executes the kernel body in Python on CPU (this
    container); pass interpret=False on real TPU hardware."""
    B, H, Tq, hd = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    assert H % KV == 0, "GQA requires H % KV == 0"
    group = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, "pad sequences to block multiples"
    n_kb = Tk // bk
    q_offset = Tk - Tq  # query block sits at the tail (prefill continuation)

    def kv_index(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * KV + h // group, ki, 0)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, chunk=chunk,
        block_q=bq, block_k=bk, n_kb=n_kb, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, Tq // bq, n_kb),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(B * H, Tq, hd),
      k.reshape(B * KV, Tk, hd),
      v.reshape(B * KV, Tk, hd))
    return out.reshape(B, H, Tq, hd)
