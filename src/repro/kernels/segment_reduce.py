"""Segment reduction (relational γ group-by aggregation) for TPU via Pallas.

Cobra's hottest relational operator after the join. The TPU adaptation of
hash-based grouping (which needs pointer chasing — no TPU analogue): build a
one-hot (Bn, G) membership tile from the segment-id block with an iota
compare and reduce with a single (1, Bn) × (Bn, G) MXU matmul per block,
accumulating into the (G,) output across the sequential grid. For min/max,
the same membership tile drives a masked reduce (VPU).

VMEM per step: Bn·G fp32 one-hot tile — with Bn = 256 and G ≤ 4096 that is
4 MB; larger G is tiled on the second grid axis.

Validated in interpret mode against ``ref.segment_reduce_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_reduce"]


def _kernel(v_ref, s_ref, o_ref, *, op, block_n, block_g, n_blocks):
    ni = pl.program_id(1)
    gi = pl.program_id(0)

    @pl.when(ni == 0)
    def _init():
        if op in ("sum", "count"):
            o_ref[...] = jnp.zeros_like(o_ref)
        elif op == "min":
            o_ref[...] = jnp.full_like(o_ref, jnp.inf)
        else:
            o_ref[...] = jnp.full_like(o_ref, -jnp.inf)

    vals = v_ref[...].astype(jnp.float32)              # (Bn,)
    segs = s_ref[...]                                  # (Bn,)
    g0 = gi * block_g
    onehot = (segs[:, None] == (g0 + jax.lax.broadcasted_iota(
        jnp.int32, (block_n, block_g), 1))).astype(jnp.float32)
    if op == "sum":
        o_ref[...] += (vals[None, :] @ onehot)[0]      # MXU (1,Bn)x(Bn,G)
    elif op == "count":
        o_ref[...] += jnp.sum(onehot, axis=0)
    elif op == "min":
        masked = jnp.where(onehot > 0, vals[:, None], jnp.inf)
        o_ref[...] = jnp.minimum(o_ref[...], jnp.min(masked, axis=0))
    else:  # max
        masked = jnp.where(onehot > 0, vals[:, None], -jnp.inf)
        o_ref[...] = jnp.maximum(o_ref[...], jnp.max(masked, axis=0))


@functools.partial(jax.jit, static_argnames=("num_segments", "op", "block_n",
                                             "block_g", "interpret"))
def segment_reduce(values, segment_ids, num_segments: int, op: str = "sum",
                   block_n: int = 256, block_g: int = 512,
                   interpret: bool = True):
    """values (N,) float; segment_ids (N,) int32 in [0, num_segments).
    Returns (num_segments,) float32 aggregation."""
    N = values.shape[0]
    if num_segments == 0:
        return jnp.zeros((0,), jnp.float32)
    if N == 0:
        # every group is empty: sum/count identity is 0, and the min/max
        # convention below maps empty groups to 0 as well
        return jnp.zeros((num_segments,), jnp.float32)
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        values = jnp.pad(values, (0, pad))
        # padded rows point at an out-of-range segment → never matched
        segment_ids = jnp.pad(segment_ids, (0, pad),
                              constant_values=num_segments + block_g)
    Np = N + pad
    bg = min(block_g, num_segments)
    gpad = (-num_segments) % bg
    G = num_segments + gpad

    out = pl.pallas_call(
        functools.partial(_kernel, op=op, block_n=bn, block_g=bg,
                          n_blocks=Np // bn),
        grid=(G // bg, Np // bn),
        in_specs=[
            pl.BlockSpec((bn,), lambda gi, ni: (ni,)),
            pl.BlockSpec((bn,), lambda gi, ni: (ni,)),
        ],
        out_specs=pl.BlockSpec((bg,), lambda gi, ni: (gi,)),
        out_shape=jax.ShapeDtypeStruct((G,), jnp.float32),
        interpret=interpret,
    )(values, segment_ids.astype(jnp.int32))
    out = out[:num_segments]
    if op in ("min", "max"):
        out = jnp.where(jnp.isfinite(out), out, 0.0)  # empty groups → 0
    return out
