"""Pallas TPU kernels for the framework's compute hot spots.

  flash_attention — blocked online-softmax attention (causal/SWA/chunked/GQA)
  rwkv6_scan      — chunked WKV linear-attention scan (data-dependent decay)
  segment_reduce  — relational γ group-by aggregation via one-hot MXU matmul
  join_probe      — direct-address equi-join probe (application-side join)

Each kernel has a pure-jnp oracle in ``ref.py``; ``ops.py`` is the jit'd
dispatch layer. Kernels are validated in interpret mode on CPU
(tests/test_kernels.py); on real TPUs pass interpret=False.
"""

from . import ref

try:  # the Pallas kernels and their dispatch layer need jax
    from . import ops
    from .flash_attention import flash_attention
    from .join_probe import build_direct_table, join_probe
    from .rwkv6_scan import rwkv6_scan
    from .segment_reduce import segment_reduce
    HAS_JAX = True
except ImportError:  # jax-free install: ref.py numpy fallbacks remain usable
    ops = None
    flash_attention = rwkv6_scan = segment_reduce = None
    join_probe = build_direct_table = None
    HAS_JAX = False

__all__ = ["ops", "ref", "flash_attention", "rwkv6_scan", "segment_reduce",
           "join_probe", "build_direct_table", "HAS_JAX"]
