"""Chunked RWKV6 WKV scan for TPU via Pallas.

Recurrence (per head, K channels, V channels):
    y_t = r_t · S_{t-1} + (u ⊙ k_t · r_t) v_t
    S_t = diag(exp(w_t)) · S_{t-1} + k_t ⊗ v_t            (w_t ≤ 0)

TPU adaptation: the per-timestep recurrence is hostile to the MXU, so the
kernel processes the sequence in chunks of C tokens held in VMEM. The grid
is (B·H, T/C) — sequential in the chunk dimension, carrying the (K, V)
fp32 state in VMEM scratch. Within a chunk the pairwise decay
exp(A_{t-1} − A_s) (s < t) is computed from cumulative log-decays as an
explicit (C, C, K) difference tensor — every exponent ≤ 0, so the only
failure mode is benign underflow (true decay to zero). VMEM at the default
C = 64, K = 64: the difference tensor is 64·64·64·4 B = 1 MB; inputs/state
add < 0.5 MB — far under budget. Inter-chunk terms are (C,K)×(K,V) MXU
matmuls.

Validated in interpret mode against ``ref.rwkv6_scan_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv6_scan"]


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, state_ref, *,
            chunk, n_chunks, n_heads):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)          # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (C, V)
    w = w_ref[0].astype(jnp.float32)          # (C, K), ≤ 0
    u = u_ref[0].astype(jnp.float32)          # (K,)
    S = state_ref[...]                        # (K, V) fp32

    A = jnp.cumsum(w, axis=0)                 # A_t = Σ_{r≤t} w_r
    A_prev = A - w                            # A_{t-1}
    A_end = A[-1:]                            # (1, K)

    # inter-chunk: y += (r ⊙ exp(A_{t-1})) · S        exponents ≤ 0
    q_in = r * jnp.exp(A_prev)
    y = q_in @ S                              # (C, V) MXU

    # intra-chunk: pairwise decays exp(A_{t-1} − A_s), s < t  (≤ 0)
    diff = A_prev[:, None, :] - A[None, :, :]          # (C, C, K)
    tt = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ss = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    D = jnp.exp(jnp.where((tt > ss)[:, :, None], diff, -jnp.inf))
    scores = jnp.einsum("tk,tsk,sk->ts", r, D, k)
    y = y + scores @ v

    # bonus (current token)
    bonus = jnp.sum(r * (u[None, :] * k), axis=-1)     # (C,)
    y = y + bonus[:, None] * v
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: exponents ≤ 0
    k_carry = k * jnp.exp(A_end - A)
    state_ref[...] = S * jnp.exp(A_end[0])[:, None] + k_carry.T @ v

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        s_out_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w_log, u, chunk: int = 64, interpret: bool = True):
    """r/k/w_log: (B, H, T, K); v: (B, H, T, V); u: (H, K).
    Returns (y (B,H,T,V), final state (B,H,K,V) fp32).

    T must be a multiple of `chunk` (pad upstream)."""
    B, H, T, K = r.shape
    V = v.shape[-1]
    C = min(chunk, T)
    assert T % C == 0, "pad T to a chunk multiple"
    nC = T // C

    grid = (B * H, nC)
    y, s_out = pl.pallas_call(
        functools.partial(_kernel, chunk=C, n_chunks=nC, n_heads=H),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, C, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, C, V), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, C, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, K), lambda bh, ci: (bh % H, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, V), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, K, V), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, T, V), r.dtype),
            jax.ShapeDtypeStruct((B * H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r.reshape(B * H, T, K), k.reshape(B * H, T, K),
      v.reshape(B * H, T, V), w_log.reshape(B * H, T, K), u)
    return y.reshape(B, H, T, V), s_out.reshape(B, H, K, V)
