"""Jit'd public wrappers: kernel on TPU, reference elsewhere.

``use_pallas(True)`` flips dispatch to the Pallas kernels (interpret mode on
CPU — used by the kernel tests; on a real TPU pod the launcher enables it
with interpret=False). Default is the pure-jnp reference path so CPU smoke
tests and the dry-run lower plain XLA HLO.
"""

from __future__ import annotations

from typing import Optional


from . import ref
from .flash_attention import flash_attention as _flash_pallas
from .join_probe import build_direct_table, join_probe as _probe_pallas
from .rwkv6_scan import rwkv6_scan as _rwkv_pallas
from .segment_reduce import segment_reduce as _segred_pallas

_STATE = {"use_pallas": False, "interpret": True}


def use_pallas(on: bool = True, interpret: bool = True) -> None:
    _STATE["use_pallas"] = on
    _STATE["interpret"] = interpret


def pallas_state() -> tuple:
    """Current dispatch state as ``(use_pallas, interpret)`` — read by the
    compiled execution tier to pick its probe path."""
    return (_STATE["use_pallas"], _STATE["interpret"])


def attention(q, k, v, causal=True, window=None, chunk=None, scale=None,
              block_q: int = 128, block_k: int = 128):
    """q (B,H,Tq,hd), k/v (B,KV,Tk,hd)."""
    if _STATE["use_pallas"]:
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             chunk=chunk, scale=scale, block_q=block_q,
                             block_k=block_k, interpret=_STATE["interpret"])
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   chunk=chunk, scale=scale)


def rwkv_scan(r, k, v, w_log, u, chunk: int = 64):
    if _STATE["use_pallas"]:
        return _rwkv_pallas(r, k, v, w_log, u, chunk=chunk,
                            interpret=_STATE["interpret"])
    return ref.rwkv6_scan_ref(r, k, v, w_log, u)


def segment_reduce(values, segment_ids, num_segments: int, op: str = "sum"):
    if _STATE["use_pallas"]:
        return _segred_pallas(values, segment_ids, num_segments, op=op,
                              interpret=_STATE["interpret"])
    return ref.segment_reduce_ref(values, segment_ids, num_segments, op=op)


def equi_probe(probe_keys, table_keys, key_space: Optional[int] = None):
    """Index of each probe key's match in table_keys (-1 if absent)."""
    if _STATE["use_pallas"] and key_space is not None and key_space <= (1 << 22):
        table = build_direct_table(table_keys, key_space)
        return _probe_pallas(probe_keys, table, interpret=_STATE["interpret"])
    return ref.join_probe_ref(probe_keys, table_keys)
