"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Also hosts the numpy fallbacks (``*_np``) used by the compiled execution
tier when ``jax`` is not importable — those must stay importable without
jax, hence the guarded import.
"""

from __future__ import annotations

import math
from typing import Optional

import numpy as np

try:  # pragma: no cover - exercised implicitly by import
    import jax
    import jax.numpy as jnp
except Exception:  # jax optional: numpy fallbacks below still work
    jax = None
    jnp = None


def flash_attention_ref(q, k, v, causal: bool = True,
                        window: Optional[int] = None,
                        chunk: Optional[int] = None,
                        scale: Optional[float] = None):
    """q (B,H,Tq,hd), k/v (B,KV,Tk,hd) — GQA broadcast; fp32 softmax."""
    B, H, Tq, hd = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    rep = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf = q.reshape(B, KV, rep, Tq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bkrqh,bksh->bkrqs", qf, kf) * scale
    qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)
    kpos = jnp.arange(Tk)[None, :]
    m = jnp.ones((Tq, Tk), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    if chunk is not None:
        m &= (kpos // chunk) == (qpos // chunk)
    scores = jnp.where(m[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bkrqs,bksh->bkrqh", p, v.astype(jnp.float32))
    return out.reshape(B, H, Tq, hd).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w_log, u, state=None):
    """Exact sequential recurrence (B,H,T,K)/(B,H,T,V) — see layers.py."""
    B, H, T, K = r.shape
    V = v.shape[-1]
    S = jnp.zeros((B, H, K, V), jnp.float32) if state is None else state

    def step(S, inp):
        rt, kt, vt, wt = inp
        rt, kt, vt = (x.astype(jnp.float32) for x in (rt, kt, vt))
        bonus = jnp.einsum("bhk,hk,bhk->bh", rt, u.astype(jnp.float32), kt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S) + bonus[..., None] * vt
        S = S * jnp.exp(wt.astype(jnp.float32))[..., None] \
            + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return S, y

    inputs = tuple(jnp.moveaxis(x, 2, 0) for x in (r, k, v, w_log))
    S, ys = jax.lax.scan(step, S, inputs)
    return jnp.moveaxis(ys, 0, 2).astype(r.dtype), S


def segment_reduce_ref(values, segment_ids, num_segments: int, op: str = "sum"):
    """Relational γ oracle: per-group sum/count/min/max."""
    if op == "sum":
        return jax.ops.segment_sum(values, segment_ids, num_segments)
    if op == "count":
        return jax.ops.segment_sum(jnp.ones_like(values), segment_ids,
                                   num_segments)
    if op == "min":
        return jax.ops.segment_min(values, segment_ids, num_segments)
    if op == "max":
        return jax.ops.segment_max(values, segment_ids, num_segments)
    raise ValueError(op)


def join_probe_ref(probe_keys, table_keys):
    """For each probe key: index of its match in table_keys (unique) or -1."""
    n = probe_keys.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.int32)
    if table_keys.shape[0] == 0:
        return jnp.full((n,), -1, jnp.int32)
    order = jnp.argsort(table_keys)
    sk = table_keys[order]
    pos = jnp.clip(jnp.searchsorted(sk, probe_keys), 0, len(order) - 1)
    idx = order[pos]
    found = table_keys[idx] == probe_keys
    return jnp.where(found, idx, -1).astype(jnp.int32)


def join_probe_np(probe_keys, table_keys):
    """numpy twin of :func:`join_probe_ref` (jax-free compiled backend)."""
    probe_keys = np.asarray(probe_keys)
    table_keys = np.asarray(table_keys)
    n = probe_keys.shape[0]
    if n == 0:
        return np.zeros((0,), np.int32)
    if table_keys.shape[0] == 0:
        return np.full((n,), -1, np.int32)
    order = np.argsort(table_keys, kind="stable")
    sk = table_keys[order]
    pos = np.clip(np.searchsorted(sk, probe_keys), 0, len(order) - 1)
    idx = order[pos]
    found = table_keys[idx] == probe_keys
    return np.where(found, idx, -1).astype(np.int32)


def segment_reduce_np(values, segment_ids, num_segments: int, op: str = "sum"):
    """numpy twin of :func:`segment_reduce_ref`, with the Pallas kernel's
    empty-group convention for min/max (empty groups report 0)."""
    values = np.asarray(values, np.float32)
    segment_ids = np.asarray(segment_ids)
    if op == "count":
        values = np.ones_like(values)
        op = "sum"
    if op == "sum":
        out = np.zeros((num_segments,), np.float32)
        np.add.at(out, segment_ids, values)
        return out
    if op == "min":
        out = np.full((num_segments,), np.inf, np.float32)
        np.minimum.at(out, segment_ids, values)
    elif op == "max":
        out = np.full((num_segments,), -np.inf, np.float32)
        np.maximum.at(out, segment_ids, values)
    else:
        raise ValueError(op)
    return np.where(np.isfinite(out), out, 0.0).astype(np.float32)
