"""Per-column statistics: frequency backbone, MCVs, equi-depth buckets,
and a mergeable distinct-count sketch.

``ColumnHistogram`` is the unit ``DatabaseServer.analyze()`` builds per
column. Its storage is an exact sorted ``(values, counts)`` frequency map —
the one representation whose ``merge()`` is **lossless, associative and
commutative by construction** (a sorted merge-add of counts), which is what
lets a :class:`~repro.cluster.database.ShardedDatabase` coordinator
reconcile per-shard statistics bit-for-bit with the unsharded server's
(property-tested like ``combine_snapshots``). Everything the estimator
consumes is *derived* deterministically from that backbone:

  * **MCVs** — the ``n_mcv`` most common values with their exact
    frequencies (ties broken by value), Postgres-style;
  * **equi-depth buckets** over the residual (non-MCV) values — bucket
    boundaries placed on value frequencies so each bucket holds ~equal
    row mass; estimation inside a bucket assumes uniformity (this is the
    histogram-grade approximation — the estimator never reads the raw
    frequency map directly except for MCVs);
  * a **KMV distinct-count sketch** (k smallest deterministic 64-bit
    mixes of the values) whose union-merge is exact under re-sharding.

Because derivation is deterministic, two histograms with equal frequency
maps are equal bucket-for-bucket — so ``merge(shard parts) ==
build(whole table)`` exactly, not just approximately.

Content identity: ``repr()`` (and :meth:`content_digest`) hash the full
backbone + config, so the existing ``stats_fingerprint`` content-addressing
(``sha256(repr(TableStats))``) extends to histograms unchanged.
"""

from __future__ import annotations

import dataclasses
import hashlib
from functools import cached_property
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["StatsConfig", "ColumnHistogram", "build_histogram",
           "merge_histograms", "merge_all", "kmv_sketch", "kmv_merge",
           "kmv_estimate"]


@dataclasses.dataclass(frozen=True)
class StatsConfig:
    """Knobs for ``analyze()``'s histogram build (the tunable statistics
    half of the cost-catalog file). ``histograms=False`` reverts to the
    legacy scalar NDV estimates — the control arm of every
    scalar-vs-histogram comparison."""

    histograms: bool = True
    n_buckets: int = 16
    n_mcv: int = 8
    sketch_k: int = 256


DEFAULT_STATS_CONFIG = StatsConfig()


# --------------------------------------------------------------- KMV sketch

def _mix64(x: np.ndarray) -> np.ndarray:
    """Deterministic 64-bit finalizer (splitmix64) over value bit patterns —
    a stand-in hash that is identical across shards and sessions."""
    with np.errstate(over="ignore"):
        z = x.astype(np.uint64, copy=True)
        z += np.uint64(0x9E3779B97F4A7C15)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


def kmv_sketch(values: np.ndarray, k: int) -> np.ndarray:
    """The k smallest mixed hashes of ``values`` (sorted uint64)."""
    if values.size == 0:
        return np.asarray([], dtype=np.uint64)
    bits = np.ascontiguousarray(np.asarray(values, dtype=np.float64)) \
        .view(np.uint64)
    h = np.unique(_mix64(bits))
    return h[:k]


def kmv_merge(a: np.ndarray, b: np.ndarray, k: int) -> np.ndarray:
    """Union-merge two KMV sketches: the k smallest of the union — exactly
    the sketch of the concatenated value sets (associative/commutative)."""
    return np.unique(np.concatenate([a, b]))[:k]


def kmv_estimate(sketch: np.ndarray, k: int) -> float:
    """Distinct-count estimate: exact while the sketch is not full, else
    the classic (k-1)/kth-minimum estimator."""
    if len(sketch) < k:
        return float(len(sketch))
    kth = float(sketch[k - 1]) / float(2 ** 64)
    return (k - 1) / max(kth, 1e-300)


# ------------------------------------------------------------ the histogram

@dataclasses.dataclass(frozen=True)
class ColumnHistogram:
    """Exact sorted value frequencies + derived MCVs / equi-depth buckets.

    ``values`` are float64 (int columns cast exactly for the magnitudes the
    simulator uses), ``counts`` int64. ``sketch`` is the KMV distinct-count
    sketch over the same values.
    """

    values: np.ndarray            # sorted distinct values, float64
    counts: np.ndarray            # int64, counts[i] = rows with values[i]
    config: StatsConfig = DEFAULT_STATS_CONFIG
    sketch: Optional[np.ndarray] = None

    # ------------------------------------------------------------- identity
    def content_digest(self) -> str:
        h = hashlib.sha256()
        h.update(np.ascontiguousarray(self.values).tobytes())
        h.update(np.ascontiguousarray(self.counts).tobytes())
        h.update(repr((self.config.n_buckets, self.config.n_mcv,
                       self.config.sketch_k)).encode())
        return h.hexdigest()[:16]

    def __repr__(self) -> str:   # feeds repr(TableStats) → stats_fingerprint
        return (f"ColumnHistogram(nrows={self.nrows}, ndv={self.ndv}, "
                f"digest={self.content_digest()!r})")

    def __eq__(self, other) -> bool:
        return (isinstance(other, ColumnHistogram)
                and self.config == other.config
                and np.array_equal(self.values, other.values)
                and np.array_equal(self.counts, other.counts))

    def __hash__(self):
        return hash(self.content_digest())

    # -------------------------------------------------------------- scalars
    @cached_property
    def nrows(self) -> int:
        return int(self.counts.sum()) if self.counts.size else 0

    @property
    def ndv(self) -> int:
        return int(len(self.values))

    @property
    def vmin(self) -> float:
        return float(self.values[0]) if self.values.size else 0.0

    @property
    def vmax(self) -> float:
        return float(self.values[-1]) if self.values.size else 0.0

    def distinct_estimate(self) -> float:
        if self.sketch is not None:
            return kmv_estimate(self.sketch, self.config.sketch_k)
        return float(self.ndv)

    # ------------------------------------------------- derived summaries
    @cached_property
    def _mcv_index(self) -> np.ndarray:
        """Indices of the ``n_mcv`` most common values (count desc, value
        asc — a total, shard-independent order)."""
        k = min(self.config.n_mcv, len(self.values))
        if k == 0:
            return np.asarray([], dtype=np.int64)
        order = np.lexsort((self.values, -self.counts))
        return np.sort(order[:k])

    @cached_property
    def mcvs(self) -> Tuple[np.ndarray, np.ndarray]:
        """(values, counts) of the most common values, value-sorted."""
        i = self._mcv_index
        return self.values[i], self.counts[i]

    @cached_property
    def buckets(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Equi-depth buckets over the NON-MCV residual:
        ``(upper_bounds, bucket_counts, bucket_ndvs)`` — bucket ``i`` spans
        ``(upper_bounds[i-1], upper_bounds[i]]`` (first bucket from the
        residual minimum), holds ``bucket_counts[i]`` rows across
        ``bucket_ndvs[i]`` distinct values. Boundaries are chosen on the
        cumulative residual mass, so each bucket carries ~1/n_buckets of
        the residual rows regardless of value skew."""
        mask = np.ones(len(self.values), dtype=bool)
        mask[self._mcv_index] = False
        vals, cnts = self.values[mask], self.counts[mask]
        if vals.size == 0:
            e = np.asarray([], dtype=np.float64)
            z = np.asarray([], dtype=np.int64)
            return e, z, z
        nb = max(1, min(self.config.n_buckets, len(vals)))
        cum = np.cumsum(cnts)
        total = cum[-1]
        # first distinct value whose cumulative mass reaches each depth cut
        cuts = np.searchsorted(cum, total * np.arange(1, nb + 1) / nb)
        cuts = np.unique(np.minimum(cuts, len(vals) - 1))
        uppers = vals[cuts]
        lo = 0
        bc, bd = [], []
        for c in cuts:
            bc.append(int(cnts[lo:c + 1].sum()))
            bd.append(int(c + 1 - lo))
            lo = c + 1
        return uppers, np.asarray(bc, dtype=np.int64), \
            np.asarray(bd, dtype=np.int64)

    # ----------------------------------------------------------- estimation
    def eq_fraction(self, value: float) -> float:
        """Fraction of rows equal to ``value``: exact for MCVs, bucket
        average frequency for residual values, 0 outside the domain."""
        n = self.nrows
        if n == 0:
            return 0.0
        v = float(value)
        mv, mc = self.mcvs
        j = np.searchsorted(mv, v)
        if j < len(mv) and mv[j] == v:
            return float(mc[j]) / n
        uppers, bc, bd = self.buckets
        if uppers.size == 0 or v > uppers[-1]:
            return 0.0
        b = int(np.searchsorted(uppers, v, side="left"))
        return float(bc[b]) / max(int(bd[b]), 1) / n

    def param_eq_fraction(self) -> float:
        """Expected selectivity of ``col == :param`` with the binding drawn
        from the column's own distribution — Σ (f_v/N)², the self-join
        selectivity. Correlated rewrites (T2/T5) bind their parameter from
        rows of a related table, so frequent values are looked up often:
        under skew this is far larger than 1/NDV, and for uniform columns
        it degenerates to exactly 1/NDV. Computed from MCVs exactly plus
        the within-bucket-uniform residual approximation."""
        n = self.nrows
        if n == 0:
            return 1.0
        _, mc = self.mcvs
        s = float((mc.astype(np.float64) ** 2).sum())
        _, bc, bd = self.buckets
        if bc.size:
            s += float((bc.astype(np.float64) ** 2
                        / np.maximum(bd, 1)).sum())
        return min(1.0, s / (float(n) ** 2))

    def le_fraction(self, value: float) -> float:
        """Fraction of rows with ``col <= value`` — MCV mass counted
        exactly, residual buckets linearly interpolated."""
        n = self.nrows
        if n == 0:
            return 0.0
        v = float(value)
        mv, mc = self.mcvs
        acc = float(mc[mv <= v].sum())
        uppers, bc, _ = self.buckets
        if uppers.size:
            lo = self.values[0]
            b = int(np.searchsorted(uppers, v, side="left"))
            acc += float(bc[:b].sum())
            if b < len(uppers):
                lower = float(uppers[b - 1]) if b > 0 else float(lo)
                width = float(uppers[b]) - lower
                if v >= lower:
                    frac = 1.0 if width <= 0 else \
                        min(1.0, (v - lower) / width)
                    acc += float(bc[b]) * frac
        return min(1.0, acc / n)

    def range_fraction(self, op: str, value: float) -> float:
        """Selectivity of ``col <op> value`` for op in {<, <=, >, >=}."""
        le = self.le_fraction(value)
        eq = self.eq_fraction(value)
        if op == "<=":
            return le
        if op == "<":
            return max(0.0, le - eq)
        if op == ">":
            return max(0.0, 1.0 - le)
        if op == ">=":
            return max(0.0, 1.0 - le + eq)
        raise ValueError(f"not a range op: {op!r}")


# ------------------------------------------------------------ build / merge

def build_histogram(arr: np.ndarray,
                    config: StatsConfig = DEFAULT_STATS_CONFIG
                    ) -> ColumnHistogram:
    """Build the exact frequency backbone (and sketch) for one column."""
    a = np.asarray(arr)
    if a.size == 0:
        values = np.asarray([], dtype=np.float64)
        counts = np.asarray([], dtype=np.int64)
    else:
        values, counts = np.unique(a.astype(np.float64), return_counts=True)
        counts = counts.astype(np.int64)
    return ColumnHistogram(values=values, counts=counts, config=config,
                           sketch=kmv_sketch(values, config.sketch_k))


def merge_histograms(a: ColumnHistogram, b: ColumnHistogram
                     ) -> ColumnHistogram:
    """Lossless merge: sorted merge-add of the frequency backbones (and
    KMV union). Associative and commutative by construction, and equal —
    bucket-for-bucket, since every summary is derived deterministically —
    to building one histogram over the concatenated rows."""
    if a.config != b.config:
        raise ValueError(f"histogram config mismatch: {a.config} != {b.config}")
    v = np.concatenate([a.values, b.values])
    c = np.concatenate([a.counts, b.counts])
    uv, inverse = np.unique(v, return_inverse=True)
    uc = np.zeros(len(uv), dtype=np.int64)
    np.add.at(uc, inverse, c)
    sk = None
    if a.sketch is not None and b.sketch is not None:
        sk = kmv_merge(a.sketch, b.sketch, a.config.sketch_k)
    return ColumnHistogram(values=uv, counts=uc, config=a.config, sketch=sk)


def merge_all(hists: Sequence[ColumnHistogram]) -> ColumnHistogram:
    """Fold ``merge_histograms`` over a sequence (must be non-empty)."""
    out = hists[0]
    for h in hists[1:]:
        out = merge_histograms(out, h)
    return out
