"""q-error: the feedback signal that scores cardinality estimates.

``q_error(est, act) = max((act+1)/(est+1), (est+1)/(act+1))`` — the
standard symmetric multiplicative error (1.0 = perfect), +1-smoothed so
empty results neither divide by zero nor hide an est≈0-vs-observed≫0 miss.

:class:`QErrorTracker` keeps a per-site running account of it for the
:class:`~repro.runtime.feedback.FeedbackController`: the controller feeds
every observed (estimated, actual) pair in, reads back the site's latest
q-error to decide whether a *targeted per-column re-analyze* is due, and
publishes the per-site values into ``StatsProfile``/``explain()``/
``triage()``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

__all__ = ["q_error", "QErrorTracker", "SiteQError"]


def q_error(estimated: float, observed: float) -> float:
    """Symmetric multiplicative estimation error; 1.0 is a perfect
    estimate, and over/under-estimation by the same factor score the
    same. +1 smoothing keeps empty results finite."""
    e, o = float(estimated) + 1.0, float(observed) + 1.0
    return max(e / o, o / e)


@dataclasses.dataclass
class SiteQError:
    """Running q-error account of one query site."""

    n: int = 0
    total: float = 0.0
    worst: float = 1.0
    last: float = 1.0
    last_est: float = 0.0
    last_observed: float = 0.0
    tables: Tuple[str, ...] = ()

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 1.0


class QErrorTracker:
    """Per-site q-error accounting keyed by the site's SQL text (the same
    key the feedback controller aggregates observations under)."""

    def __init__(self):
        self._sites: Dict[str, SiteQError] = {}

    def observe(self, sql: str, estimated: float, observed: float,
                tables: Tuple[str, ...] = ()) -> float:
        qe = q_error(estimated, observed)
        s = self._sites.setdefault(sql, SiteQError())
        s.n += 1
        s.total += qe
        s.worst = max(s.worst, qe)
        s.last = qe
        s.last_est = float(estimated)
        s.last_observed = float(observed)
        if tables:
            s.tables = tuple(tables)
        return qe

    def site(self, sql: str) -> SiteQError:
        return self._sites.get(sql, SiteQError())

    def sites(self) -> Dict[str, SiteQError]:
        return dict(self._sites)

    def latest(self) -> Dict[str, float]:
        """sql -> last observed q-error, every tracked site."""
        return {sql: s.last for sql, s in self._sites.items()}

    def worst_sites(self) -> List[Tuple[str, float]]:
        return sorted(((sql, s.worst) for sql, s in self._sites.items()),
                      key=lambda kv: -kv[1])

    def __len__(self) -> int:
        return len(self._sites)
