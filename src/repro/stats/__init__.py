"""Statistics subsystem: per-column histograms, selectivity, q-error.

Replaces the scalar per-table cardinalities the cost model launched with:

  * :mod:`repro.stats.histogram` — ``analyze()``'s per-column equi-depth
    histograms + distinct-count sketches, with a lossless associative
    ``merge()`` (sharded coordinator stats reconcile bit-for-bit);
  * :mod:`repro.stats.selectivity` — histogram-grade predicate
    selectivity consumed by ``DatabaseServer.estimate()`` / the cost
    model (equality/range from buckets, per-parameter expected
    selectivity for correlated sites);
  * :mod:`repro.stats.qerror` — the per-site q-error feedback signal the
    :class:`~repro.runtime.feedback.FeedbackController` uses to trigger
    targeted per-column re-analyzes.
"""

from .histogram import (ColumnHistogram, StatsConfig, build_histogram,
                        merge_all, merge_histograms)
from .qerror import QErrorTracker, q_error
from .selectivity import predicate_selectivity

__all__ = ["ColumnHistogram", "StatsConfig", "build_histogram",
           "merge_all", "merge_histograms", "predicate_selectivity",
           "q_error", "QErrorTracker"]
