"""Predicate selectivity from per-column histograms.

:func:`predicate_selectivity` is the histogram-grade replacement for the
scalar rules ``DatabaseServer._selectivity`` shipped with (1/NDV equality,
the System-R 1/3 range default). It receives a *resolver* — a callable
mapping a column name to the :class:`~repro.stats.histogram.ColumnHistogram`
of the Select's input (or ``None``) — so it works unchanged for base-table
scans, stacked Selects, and join inputs, and degrades per-column to the
legacy scalar estimate wherever a histogram is missing (fresh table,
``StatsConfig(histograms=False)``, sketch-only analyze).

Pricing rules:

  * ``col == literal``   — MCV exact match, else bucket average frequency;
  * ``col != literal``   — complement of the above;
  * ``col <op> literal`` — MCV mass + linear interpolation in the
    containing equi-depth bucket;
  * ``col == :param``    — the *expected* selectivity over bindings drawn
    from the column's own distribution (Σ (f/N)², exactly 1/NDV for
    uniform columns — see ``ColumnHistogram.param_eq_fraction``);
  * ``col != :param``    — its complement;
  * range vs ``:param``  — 1/3 (no binding distribution to price from);
  * conjunction/disjunction — independence, as before.
"""

from __future__ import annotations

from typing import Callable, Optional

from .histogram import ColumnHistogram

__all__ = ["predicate_selectivity"]

_RANGE_FLIP = {"<": ">", "<=": ">=", ">": "<", ">=": "<="}


def predicate_selectivity(pred, resolve: Callable[[str], Optional[ColumnHistogram]],
                          ndv_of: Callable[[str], float]) -> Optional[float]:
    """Selectivity of ``pred`` using histograms where available.

    Returns ``None`` when the predicate shape is not one this estimator
    prices (caller falls through to its own default)."""
    from ..relational.algebra import BoolOp, Cmp, Col, Lit, Param

    if isinstance(pred, BoolOp):
        l = predicate_selectivity(pred.left, resolve, ndv_of)
        r = predicate_selectivity(pred.right, resolve, ndv_of)
        if l is None or r is None:
            return None
        return l * r if pred.op == "and" else min(1.0, l + r)
    if not isinstance(pred, Cmp):
        return None
    # normalize to (col OP rhs); flip the operator when the column is on
    # the right (5 < col  ≡  col > 5)
    op, col, rhs = pred.op, None, None
    if isinstance(pred.left, Col):
        col, rhs = pred.left, pred.right
    elif isinstance(pred.right, Col):
        col, rhs = pred.right, pred.left
        op = _RANGE_FLIP.get(op, op)
    if col is None:
        return None
    hist = resolve(col.name)

    if isinstance(rhs, Lit) and isinstance(rhs.value, (int, float, bool)):
        if hist is not None and hist.nrows > 0:
            if op == "==":
                return hist.eq_fraction(float(rhs.value))
            if op == "!=":
                return max(0.0, 1.0 - hist.eq_fraction(float(rhs.value)))
            if op in _RANGE_FLIP:
                return hist.range_fraction(op, float(rhs.value))
        # legacy scalar fallback for this column
        if op == "==":
            return 1.0 / ndv_of(col.name)
        if op == "!=":
            return 1.0 - 1.0 / ndv_of(col.name)
        if op in _RANGE_FLIP:
            return 1.0 / 3.0
        return None

    if isinstance(rhs, Param):
        if op == "==":
            if hist is not None and hist.nrows > 0:
                return hist.param_eq_fraction()
            return 1.0 / ndv_of(col.name)
        if op == "!=":
            if hist is not None and hist.nrows > 0:
                return max(0.0, 1.0 - hist.param_eq_fraction())
            return 1.0 - 1.0 / ndv_of(col.name)
        if op in _RANGE_FLIP:
            return 1.0 / 3.0
        return None

    # Col-vs-Col and computed comparands: legacy scalar rules
    if op == "==":
        return 1.0 / ndv_of(col.name)
    if op == "!=":
        return 1.0 - 1.0 / ndv_of(col.name)
    if op in _RANGE_FLIP:
        return 1.0 / 3.0
    return None
