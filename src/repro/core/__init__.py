"""Cobra core: regions, F-IR, Region AND-OR DAG, rules, cost model, search.

The paper's primary contribution — cost-based rewriting of database
applications via a Volcano/Cascades memo over program regions.
"""

from .regions import (Assign, BasicBlock, BreakStmt, CacheByColumn,
                      CollectionAdd, CondRegion, ContinueStmt, IBin,
                      ICacheLookup, ICall, IConst, IEmptyList, IEmptyMap,
                      IField, IIndex, ILoadAll, INav, Interpreter, IQuery,
                      IQueryValues, IScalarQuery, IVar, LoopRegion, MapPut,
                      NoOp, Prefetch, Program, Region, ReturnStmt, SeqRegion,
                      UpdateRow, WhileRegion, register_function, seq)
from .fir import (FIRConversionError, eval_fir, fir_to_region, loop_to_fir)
from .dag import AndNode, Memo, Rule, expand
from .rules import RuleContext, build_memo, default_rules
from .context import (ExecutionContext, ONE_SHOT, StatsProfile,
                      loop_site_key, param_group_key, query_site_key,
                      while_site_key)
from .cost import CostCatalog, CostModel, query_has_params
from .search import OptimizationResult, Plan, optimize, run_search

__all__ = [
    "Assign", "BasicBlock", "BreakStmt", "CacheByColumn", "CollectionAdd",
    "CondRegion", "ContinueStmt", "IBin", "ICacheLookup", "ICall", "IConst",
    "IEmptyList", "IEmptyMap", "IField", "IIndex", "ILoadAll", "INav",
    "Interpreter", "IQuery", "IQueryValues", "IScalarQuery", "IVar",
    "LoopRegion", "MapPut", "NoOp", "Prefetch", "Program", "Region",
    "ReturnStmt", "SeqRegion", "UpdateRow", "WhileRegion",
    "register_function", "seq",
    "FIRConversionError", "eval_fir", "fir_to_region", "loop_to_fir",
    "AndNode", "Memo", "Rule", "expand", "RuleContext", "build_memo",
    "default_rules",
    "ExecutionContext", "ONE_SHOT", "StatsProfile", "loop_site_key",
    "param_group_key", "query_site_key", "while_site_key",
    "CostCatalog", "CostModel", "query_has_params",
    "OptimizationResult", "Plan", "optimize", "run_search",
]
