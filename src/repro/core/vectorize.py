"""Vectorized execution of recognized cursor loops.

``Interpreter(mode="fast")`` delegates here. ``analyze_loop`` statically
recognizes straight-line loop bodies (optionally with one guard ``if``)
built from the statement vocabulary of `regions.py`; ``exec_loop_vectorized``
then executes the loop columnar (jnp/np bulk ops) while charging the
*identical* simulated time the exact row-at-a-time interpreter would charge
(per-statement C_Z counts, per-query costs, ORM-cache hit/miss pattern).

Property tests (tests/test_properties.py) assert state AND clock equivalence
between the two modes on randomized programs/data. Unrecognized loops fall
back to exact mode — equivalence is never compromised for speed.

The columnar executor is split in two layers so the compiled tier
(:mod:`repro.compiled`) can reuse it: ``exec_loop_plan`` owns the statement
walk and ALL simulated-time charging, while the data-movement primitives
(navigation gather, prefetch-cache lookup, accumulator fold) are pluggable
:class:`LoopHooks`. The fast interpreter passes the defaults; the compiled
tier passes kernel-backed, artifact-cached implementations — both charge
identically because the charging lives in the shared walk.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..relational.table import Table
from .regions import (Assign, BasicBlock, BreakStmt, CollectionAdd, CondRegion,
                      ContinueStmt, IBin, ICacheLookup, ICall, IConst, IField,
                      ILen, INav, IVar, LoopRegion, MapPut, NoOp, Region,
                      ReturnStmt, SeqRegion, Stmt, UpdateRow, _BIN_OPS,
                      _FUNCTIONS)

__all__ = ["analyze_loop", "exec_loop_plan", "try_exec_loop_fast",
           "LoopHooks", "LoopPlan"]

_ACC_OPS = {"+", "min", "max"}
_ACC_IDENTITY = {"+": 0.0, "min": np.inf, "max": -np.inf}


@dataclasses.dataclass
class LoopPlan:
    stmts: List[Tuple[Stmt, Optional["IExpr"]]]  # (stmt, guard pred or None)
    accumulators: List[str]


# --------------------------------------------------------------------------
# Static recognition
# --------------------------------------------------------------------------

def _flatten(region: Region) -> Optional[List[Tuple[Stmt, Optional[object]]]]:
    """Flatten body to [(stmt, guard)] — straight-line + at most one-level if."""
    out: List[Tuple[Stmt, Optional[object]]] = []

    def walk(r: Region, guard) -> bool:
        if isinstance(r, BasicBlock):
            out.append((r.stmt, guard))
            return True
        if isinstance(r, SeqRegion):
            return all(walk(p, guard) for p in r.parts)
        if isinstance(r, CondRegion):
            if guard is not None or r.else_r is not None:
                return False  # nested/else guards: fall back to exact
            out.append((("__guard__", r.pred), guard))
            return walk(r.then_r, r.pred)
        return False  # nested loop etc.

    return out if walk(region, None) else None


def _is_pure_vec(e, rowvars: set, rowtmps: set, scalartmps: set) -> bool:
    if isinstance(e, IConst):
        return True
    if isinstance(e, IVar):
        return True  # invariant scalar, tmp column, or accumulator column
    if isinstance(e, IField):
        return isinstance(e.base, IVar) and (e.base.name in rowvars or e.base.name in rowtmps)
    if isinstance(e, IBin):
        return all(_is_pure_vec(x, rowvars, rowtmps, scalartmps) for x in (e.left, e.right))
    if isinstance(e, ICall):
        return all(_is_pure_vec(x, rowvars, rowtmps, scalartmps) for x in e.args)
    return False


def analyze_loop(r: LoopRegion, invariants: Dict[str, object]) -> Optional[LoopPlan]:
    flat = _flatten(r.body)
    if flat is None:
        return None
    rowvars = {r.var}
    rowtmps: set = set()
    scalartmps: set = set()
    accs: List[str] = []
    # Soundness rule for cross-iteration state: a statement may reference a
    # body-ASSIGNED variable only after its defining statement in body order
    # (then its per-row column — including an accumulator's running value —
    # is available). Referencing it BEFORE its definition means reading the
    # previous iteration's value, which has no columnar form outside the
    # matched `acc = acc <op> x` shape; those loops run exact.
    body_defs = {s.target for s, _ in flat
                 if isinstance(s, Assign)}
    defined: set = set()

    def refs_ok(e) -> bool:
        return all(nm not in body_defs or nm in defined
                   for nm in e.free_vars())

    for stmt, guard in flat:
        if isinstance(stmt, tuple) and stmt[0] == "__guard__":
            if not (_is_pure_vec(stmt[1], rowvars, rowtmps, scalartmps)
                    and refs_ok(stmt[1])):
                return None
            continue
        if isinstance(stmt, (BreakStmt, ContinueStmt, ReturnStmt)):
            # early exit makes iteration order observable: which rows ran
            # depends on per-row state, so columnar execution is unsound —
            # every invocation (batched ones included) falls back to the
            # exact row-at-a-time interpreter, which honors the exit point
            return None
        if isinstance(stmt, Assign):
            e = stmt.expr
            if isinstance(e, INav):
                if not (isinstance(e.base, IVar) and (e.base.name in rowvars or e.base.name in rowtmps)):
                    return None
                if guard is not None:
                    return None  # guarded nav: cache-state depends on mask order; exact only
                rowtmps.add(stmt.target)
                defined.add(stmt.target)
                continue
            if isinstance(e, ICacheLookup) and not e.all_matches:
                if not (_is_pure_vec(e.keyexpr, rowvars, rowtmps, scalartmps)
                        and refs_ok(e.keyexpr)):
                    return None
                rowtmps.add(stmt.target)
                defined.add(stmt.target)
                continue
            # scalar accumulator: acc = acc <op> expr | expr <op> acc
            if isinstance(e, IBin) and e.op in _ACC_OPS \
                    and stmt.target not in defined:
                l_is_acc = isinstance(e.left, IVar) and e.left.name == stmt.target
                r_is_acc = isinstance(e.right, IVar) and e.right.name == stmt.target
                if l_is_acc != r_is_acc:
                    other = e.right if l_is_acc else e.left
                    if _is_pure_vec(other, rowvars, rowtmps, scalartmps) \
                            and refs_ok(other):
                        if stmt.target not in accs:
                            accs.append(stmt.target)
                        scalartmps.add(stmt.target)
                        defined.add(stmt.target)
                        continue
                    return None
            if _is_pure_vec(e, rowvars, rowtmps, scalartmps) and refs_ok(e):
                scalartmps.add(stmt.target)
                defined.add(stmt.target)
                continue
            return None
        if isinstance(stmt, CollectionAdd):
            if not (_is_pure_vec(stmt.expr, rowvars, rowtmps, scalartmps)
                    and refs_ok(stmt.expr)):
                return None
            continue
        if isinstance(stmt, MapPut):
            if not (_is_pure_vec(stmt.keyexpr, rowvars, rowtmps, scalartmps)
                    and refs_ok(stmt.keyexpr)
                    and _is_pure_vec(stmt.valexpr, rowvars, rowtmps, scalartmps)
                    and refs_ok(stmt.valexpr)):
                return None
            continue
        if isinstance(stmt, UpdateRow):
            if not (_is_pure_vec(stmt.val, rowvars, rowtmps, scalartmps)
                    and refs_ok(stmt.val)
                    and _is_pure_vec(stmt.keyexpr, rowvars, rowtmps, scalartmps)
                    and refs_ok(stmt.keyexpr)):
                return None
            continue
        if isinstance(stmt, NoOp):
            continue
        return None
    return LoopPlan(stmts=flat, accumulators=accs)


# --------------------------------------------------------------------------
# Vectorized execution
# --------------------------------------------------------------------------

class _ColEnv:
    """Column environment: per-row values as arrays; invariants as scalars."""

    def __init__(self, n: int, state: Dict[str, object]):
        self.n = n
        self.state = state
        self.cols: Dict[str, object] = {}      # var -> np array (length n) or scalar
        self.rows: Dict[str, Dict[str, np.ndarray]] = {}  # row-typed var -> col dict

    def lookup(self, name: str):
        if name in self.cols:
            return self.cols[name]
        if name in self.state:
            return self.state[name]
        raise KeyError(name)


def _eval_vec(e, ce: _ColEnv):
    if isinstance(e, IConst):
        return e.value
    if isinstance(e, IVar):
        if e.name in ce.rows:
            return ce.rows[e.name]
        return ce.lookup(e.name)
    if isinstance(e, IField):
        base = _eval_vec(e.base, ce)
        return base[e.field]
    if isinstance(e, IBin):
        return _BIN_OPS[e.op](_as_arr(_eval_vec(e.left, ce)), _as_arr(_eval_vec(e.right, ce)))
    if isinstance(e, ICall):
        return _FUNCTIONS[e.func](*[_as_arr(_eval_vec(a, ce)) for a in e.args])
    if isinstance(e, ILen):
        v = _eval_vec(e.base, ce)
        return v.nrows if isinstance(v, Table) else len(v)
    raise TypeError(f"cannot vec-eval {e!r}")


def _as_arr(v):
    if isinstance(v, (np.ndarray, jnp.ndarray)):
        return v
    return v


def _broadcast(v, n):
    a = np.asarray(v)
    if a.ndim == 0:
        return np.broadcast_to(a, (n,)).copy()
    return a


@dataclasses.dataclass
class LoopHooks:
    """Pluggable data-movement primitives for the columnar walk.

    Every hook must be observationally identical to the default (same
    values, same ORM-cache mutations, same exceptions) — only HOW the
    gather/fold is computed may differ (cached indices, Pallas kernels).
    Simulated-time charging stays in :func:`exec_loop_plan`, shared by all
    hook sets, so clock equivalence cannot drift."""

    nav: object = None            # (env, ce, target, INav, n) -> None
    cache_lookup: object = None   # (env, ce, target, ICacheLookup, n) -> None
    accumulate: object = None     # (ce, stmt, IBin, mask|None, state) -> None
    row_source: object = None     # (Table) -> {col: np.ndarray}


def _default_row_source(src: Table) -> Dict[str, np.ndarray]:
    return {c: np.asarray(src.column(c)) for c in src.schema.names}


def try_exec_loop_fast(interp, r: LoopRegion, src, state: Dict[str, object]) -> bool:
    """Attempt vectorized execution. Returns False to request exact fallback."""
    if not isinstance(src, Table) or src.nrows == 0:
        return False
    plan = analyze_loop(r, state)
    if plan is None:
        return False
    exec_loop_plan(interp.env, r, src, state, plan)
    return True


def exec_loop_plan(env, r: LoopRegion, src: Table, state: Dict[str, object],
                   plan: LoopPlan, hooks: Optional[LoopHooks] = None) -> None:
    """Columnar execution of a recognized loop under a precomputed plan.

    Owns the statement walk and EVERY ``charge_statement``/query charge —
    the one code path both the fast interpreter and the compiled tier run
    through, so their simulated clocks are identical by construction."""
    hooks = hooks or LoopHooks()
    nav = hooks.nav or _vec_nav
    cache_lookup = hooks.cache_lookup or _vec_cache_lookup
    accumulate = hooks.accumulate or _vec_accumulate
    row_source = hooks.row_source or _default_row_source
    n = src.nrows
    ce = _ColEnv(n, state)
    ce.rows[r.var] = row_source(src)

    env.charge_statement(n)  # loop header per iteration
    mask = np.ones(n, dtype=bool)
    active = n

    for stmt, guard in plan.stmts:
        if isinstance(stmt, tuple) and stmt[0] == "__guard__":
            env.charge_statement(int(mask.sum()))  # cond evaluation per row
            pred = np.broadcast_to(np.asarray(_eval_vec(stmt[1], ce)), (n,))
            mask = mask & pred.astype(bool)
            active = int(mask.sum())
            continue
        nexec = active if guard is not None else n
        if isinstance(stmt, Assign):
            e = stmt.expr
            if isinstance(e, INav):
                nav(env, ce, stmt.target, e, n)
                env.charge_statement(nexec)  # the assign itself
                continue
            if isinstance(e, ICacheLookup):
                cache_lookup(env, ce, stmt.target, e, n)
                env.charge_statement(nexec)   # assign
                env.charge_statement(nexec)   # lookup_cache charge
                continue
            if stmt.target in plan.accumulators and isinstance(e, IBin) and e.op in _ACC_OPS:
                accumulate(ce, stmt, e, mask if guard is not None else None, state)
                env.charge_statement(nexec)
                continue
            val = _eval_vec(e, ce)
            ce.cols[stmt.target] = _broadcast(val, n) if not isinstance(val, dict) else val
            env.charge_statement(nexec)
            continue
        if isinstance(stmt, CollectionAdd):
            vals = _broadcast(_eval_vec(stmt.expr, ce), n)
            sel = vals[mask] if guard is not None else vals
            state.setdefault(stmt.target, [])
            state[stmt.target].extend(sel.tolist())
            env.charge_statement(nexec)
            continue
        if isinstance(stmt, MapPut):
            keys = _broadcast(_eval_vec(stmt.keyexpr, ce), n)
            vals = _broadcast(_eval_vec(stmt.valexpr, ce), n)
            if guard is not None:
                keys, vals = keys[mask], vals[mask]
            d = state.setdefault(stmt.target, {})
            for k, v in zip(keys.tolist(), vals.tolist()):
                d[k] = v
            env.charge_statement(nexec)
            continue
        if isinstance(stmt, UpdateRow):
            _vec_update(env, ce, stmt, mask if guard is not None else None, n)
            continue
        if isinstance(stmt, NoOp):
            env.charge_statement(nexec)
            continue
        raise AssertionError(f"unplanned stmt {stmt!r}")

    # export final accumulator values (a kernel-folded accumulator has
    # already written its scalar into `state` and left no running column)
    for acc in plan.accumulators:
        col = ce.cols.get(acc)
        if isinstance(col, np.ndarray):
            state[acc] = col[-1].item()


def _vec_nav(env, ce: _ColEnv, target: str, e: INav, n: int) -> None:
    base = ce.rows[e.base.name]
    keys = np.asarray(base[e.fk_field])
    t = env.db.table(e.target)
    tkeys = np.asarray(t.column(e.target_key))
    order = np.argsort(tkeys, kind="stable")
    pos = np.searchsorted(tkeys[order], keys)
    pos = np.clip(pos, 0, len(order) - 1)
    gidx = order[pos]
    found = tkeys[gidx] == keys
    if not found.all():
        raise KeyError(f"navigation {e!r}: missing keys (FK violation)")
    ce.rows[target] = {c: np.asarray(t.column(c))[gidx] for c in t.schema.names}
    # ORM cache accounting: first occurrence of an uncached key = point query;
    # every other occurrence = cache hit (1 statement).
    uniq, first_idx = np.unique(keys, return_index=True)
    new_keys = [k for k in uniq.tolist() if (e.target, k) not in env._orm_cache]
    n_misses = len(new_keys)
    n_hits = n - n_misses
    env.charge_statement(n_hits)
    m = env.db.model
    # A batching client env (runtime.batch.BatchClientEnv) combines all
    # missing keys into ONE bulk fetch — a single round trip per navigation
    # site instead of one per distinct key, amortizing C_NRT exactly like the
    # paper's batching transformation. The plain serving path keeps the
    # faithful N+1 accounting.
    bulk = getattr(env, "bulk_nav_charge", None)
    if bulk is not None and n_misses:
        bulk(t, n_misses)
    else:
        for _ in range(n_misses):
            env._charge_query(1, t.row_bytes,
                              m.startup_s + m.index_lookup_s,
                              m.startup_s + m.index_lookup_s + 1 / m.emit_rows_per_s)
    if env.orm_cache_enabled and n_misses:
        tk_order = np.searchsorted(tkeys[order], np.asarray(new_keys))
        rows_idx = order[tk_order]
        for k, i in zip(new_keys, rows_idx.tolist()):
            env._orm_cache[(e.target, k)] = t.row(int(i))


def _vec_cache_lookup(env, ce: _ColEnv, target: str, e: ICacheLookup, n: int) -> None:
    entry = env._prefetch_cache.get((e.table, e.col))
    if entry is None:
        raise KeyError(f"no prefetch cache for ({e.table}, {e.col})")
    keys = _broadcast(_eval_vec(e.keyexpr, ce), n)
    ckeys, corder = entry["keys"], entry["order"]
    pos = np.searchsorted(ckeys, keys)
    pos = np.clip(pos, 0, len(ckeys) - 1)
    found = ckeys[pos] == keys
    if not found.all():
        raise KeyError(f"cache lookup {e!r}: missing keys")
    gidx = corder[pos]
    t = entry["table"]
    ce.rows[target] = {c: np.asarray(t.column(c))[gidx] for c in t.schema.names}


def _vec_accumulate(ce: _ColEnv, stmt: Assign, e: IBin, mask, state) -> None:
    acc = stmt.target
    l_is_acc = isinstance(e.left, IVar) and e.left.name == acc
    other = e.right if l_is_acc else e.left
    delta = _broadcast(_eval_vec(other, ce), ce.n).astype(np.float64)
    if mask is not None:
        delta = np.where(mask, delta, _ACC_IDENTITY[e.op])
    a0 = float(state.get(acc, 0.0) if acc not in ce.cols else np.asarray(ce.cols[acc])[-1])
    if acc in ce.cols and isinstance(ce.cols[acc], np.ndarray):
        a0 = float(ce.cols[acc][-1])
    elif acc in state:
        a0 = float(state[acc])
    if e.op == "+":
        run = a0 + np.cumsum(delta)
    elif e.op == "min":
        run = np.minimum(a0, np.minimum.accumulate(delta))
    else:
        run = np.maximum(a0, np.maximum.accumulate(delta))
    ce.cols[acc] = run


def _vec_update(env, ce: _ColEnv, stmt: UpdateRow, mask, n: int) -> None:
    vals = _broadcast(_eval_vec(stmt.val, ce), n)
    keys = _broadcast(_eval_vec(stmt.keyexpr, ce), n)
    if mask is not None:
        vals, keys = vals[mask], keys[mask]
    m = env.db.model
    for _ in range(len(keys)):
        env._charge_query(1, 16, m.startup_s + m.index_lookup_s,
                          m.startup_s + m.index_lookup_s)
    t = env.db.table(stmt.table)
    arr = np.asarray(t.column(stmt.key_col))
    col = np.asarray(t.column(stmt.set_col)).copy()
    order = np.argsort(arr, kind="stable")
    pos = np.searchsorted(arr[order], keys)
    pos = np.clip(pos, 0, len(order) - 1)
    gidx = order[pos]
    hit = arr[gidx] == keys
    col[gidx[hit]] = vals[hit]
    env.db.add_table(t.with_column(t.schema.field(stmt.set_col), col))
