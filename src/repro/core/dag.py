"""The Region AND-OR DAG ("Region DAG", Sec. IV-B) — a Volcano/Cascades memo.

OR-nodes are *groups*: equivalence classes of regions/expressions — every
member computes the same state transition. AND-nodes are operators (`seq`,
`loop`, `cond`, `block`, and the F-IR operators) over child groups.

Volcano essentials implemented here:

  * **hash-consing** of AND-nodes: (op, child-group-ids, payload) → unique id,
    so re-derived expressions are detected as duplicates and cyclic rule sets
    (e.g. T2 ↔ N2) terminate;
  * **group union**: when a rule derives, inside group A, an expression whose
    root AND-node already belongs to group B, groups A and B are merged
    (union-find), exactly like Volcano's node merging;
  * **saturating expansion**: rules fire once per (AND-node, rule) pair until
    no rule produces anything new.

Saturation is **delta-driven and phased** (``expand``): every rule keeps a
cursor into a per-operator applicability index, so each fixpoint round
touches only the AND-nodes created since the rule last ran — a saturated
memo costs O(new nodes), not O(memo × rules × rounds). Rules declare a
phase (``normalize`` → ``explore`` → ``cleanup``) and each phase runs to its
own fixpoint, shrinking the explore frontier. A :class:`Budget` (node count
and/or wall clock) stops saturation gracefully mid-flight — the caller
falls back to greedy best-first search over whatever the memo holds.
``expand_exhaustive`` keeps the original rescan-everything loop as the
reference implementation for parity tests and the compile benchmark.

Payloads hold leaf content (a `Stmt`, an F-IR expr fragment, a `Query`) and
operator attributes (loop var/source, cond predicate).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import time
from typing import (Callable, Dict, List, Optional, Sequence, Set,
                    Tuple)

__all__ = ["AndNode", "Memo", "Rule", "Budget", "GroupId", "AndId",
           "PHASES", "expand", "expand_exhaustive", "memo_fingerprint"]

GroupId = int
AndId = int

# saturation phases, in firing order; each runs to its own fixpoint
PHASES = ("normalize", "explore", "cleanup")


@dataclasses.dataclass(frozen=True)
class AndNode:
    """(operator, ordered child groups, payload). Payload must be hashable."""

    op: str
    children: Tuple[GroupId, ...]
    payload: object = None

    def key(self, canon: Callable[[GroupId], GroupId]) -> Tuple:
        payload_key = self.payload.key() if hasattr(self.payload, "key") else self.payload
        return (self.op, tuple(canon(c) for c in self.children), payload_key)


class Memo:
    def __init__(self):
        self._groups: Dict[GroupId, Set[AndId]] = {}
        self._ands: Dict[AndId, AndNode] = {}
        self._owner: Dict[AndId, GroupId] = {}
        self._and_index: Dict[Tuple, AndId] = {}
        self._parent: Dict[GroupId, GroupId] = {}  # union-find
        self._next_group = itertools.count()
        self._next_and = itertools.count()
        self.merges = 0
        self.duplicates = 0
        # rewrite provenance: and_id -> (rule name, source and_id) for every
        # AND-node a rule created (build_memo originals have no entry), and
        # per-rule alternative counts — recorded by expand(), consumed by
        # search.run_search to report which rules produced the winning plan
        self.provenance: Dict[AndId, Tuple[str, AndId]] = {}
        self.rule_hits: Dict[str, int] = {}
        # per-phase per-rule saturation accounting: phase -> rule ->
        # {"matched": nodes visited, "fired": applies that added something,
        #  "missed": applies that added nothing}
        self.rule_stats: Dict[str, Dict[str, Dict[str, int]]] = {}
        # memoized canonical child tuples, invalidated on group union
        self._canon_children: Dict[AndId, Tuple[GroupId, ...]] = {}

    # -------------------------------------------------------------- groups
    def find(self, g: GroupId) -> GroupId:
        # full path compression: locate the root, then point every node on
        # the walked path directly at it
        p = self._parent
        root = g
        while p.get(root, root) != root:
            root = p[root]
        while p.get(g, g) != g:
            p[g], g = root, p[g]
        return root

    def new_group(self) -> GroupId:
        g = next(self._next_group)
        self._groups[g] = set()
        self._parent[g] = g
        return g

    def members(self, g: GroupId) -> Tuple[AndId, ...]:
        return tuple(sorted(self._groups[self.find(g)]))

    def groups(self) -> List[GroupId]:
        return sorted({self.find(g) for g in self._groups})

    def node(self, a: AndId) -> AndNode:
        return self._ands[a]

    def owner(self, a: AndId) -> GroupId:
        return self.find(self._owner[a])

    def canonical_children(self, a: AndId) -> Tuple[GroupId, ...]:
        cached = self._canon_children.get(a)
        if cached is not None:
            return cached
        out = tuple(self.find(c) for c in self._ands[a].children)
        self._canon_children[a] = out
        return out

    # --------------------------------------------------------------- insert
    def insert(self, node: AndNode, group: Optional[GroupId] = None) -> Tuple[GroupId, AndId]:
        """Insert an AND-node as an alternative of `group` (or a new group).

        Duplicate detection: if an identical node exists, reuse it; if it lives
        in a different group than requested, the groups are MERGED (they have
        been proven to compute the same transition)."""
        key = node.key(self.find)
        existing = self._and_index.get(key)
        if existing is not None:
            self.duplicates += 1
            owner = self.owner(existing)
            if group is not None and self.find(group) != owner:
                self._union(owner, self.find(group))
            return self.owner(existing), existing
        a = next(self._next_and)
        node = AndNode(node.op, tuple(self.find(c) for c in node.children), node.payload)
        self._ands[a] = node
        g = self.find(group) if group is not None else self.new_group()
        self._groups[g].add(a)
        self._owner[a] = g
        self._and_index[key] = a
        return g, a

    def _union(self, a: GroupId, b: GroupId) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return
        self.merges += 1
        # merge smaller into larger
        if len(self._groups[ra]) < len(self._groups[rb]):
            ra, rb = rb, ra
        self._groups[ra] |= self._groups[rb]
        for m in self._groups[rb]:
            self._owner[m] = ra
        self._groups[rb] = set()
        self._parent[rb] = ra
        # child references are canonicalized lazily via find(); memoized
        # canonical tuples may now be stale — drop them all (unions are
        # rare next to lookups)
        self._canon_children.clear()

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, int]:
        # root count without per-group find() calls: a group is a root iff
        # its union-find parent is itself (unions re-point exactly the
        # losing root), so counting roots is one O(groups) pass
        p = self._parent
        return {
            "groups": sum(1 for g, pg in p.items() if g == pg),
            "and_nodes": len(self._ands),
            "duplicates_detected": self.duplicates,
            "group_merges": self.merges,
        }


@dataclasses.dataclass
class Rule:
    """A transformation rule: matches an AND-node, adds alternatives.

    `apply(memo, and_id, ctx) -> list of (AndNode trees)` — implementations
    insert directly via memo.insert(..., group=owner) and return how many
    alternatives they added (for fixpoint detection). ``phase`` assigns the
    rule to one saturation phase (see :data:`PHASES`); each phase runs to
    its own fixpoint before the next starts."""

    name: str
    op: str  # root operator this rule matches ("fold", "loop", ...)
    fn: Callable  # (memo, and_id, ctx) -> int (number of new alternatives)
    phase: str = "explore"

    def __post_init__(self):
        if self.phase not in PHASES:
            raise ValueError(f"unknown rule phase {self.phase!r}; "
                             f"must be one of {PHASES}")

    def apply(self, memo: Memo, and_id: AndId, ctx) -> int:
        return self.fn(memo, and_id, ctx)


@dataclasses.dataclass
class Budget:
    """Compile-time budget for memo saturation.

    ``node_budget`` caps the number of AND-nodes in the memo;
    ``wall_budget_s`` caps saturation wall clock. When either trips,
    ``expand`` stops IMMEDIATELY (mid-phase) and reports
    ``budget_exhausted`` — never an error; the caller degrades to greedy
    best-first search over the partial memo."""

    node_budget: Optional[int] = None
    wall_budget_s: Optional[float] = None

    def __post_init__(self):
        if self.node_budget is not None and self.node_budget < 1:
            raise ValueError("node_budget must be >= 1 (or None)")
        if self.wall_budget_s is not None and self.wall_budget_s <= 0:
            raise ValueError("wall_budget_s must be > 0 (or None)")
        self._t0 = time.perf_counter()

    @property
    def bounded(self) -> bool:
        return self.node_budget is not None or self.wall_budget_s is not None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def exhausted(self, n_nodes: int) -> bool:
        if self.node_budget is not None and n_nodes >= self.node_budget:
            return True
        if self.wall_budget_s is not None and \
                time.perf_counter() - self._t0 >= self.wall_budget_s:
            return True
        return False


def expand(memo: Memo, rules: Sequence[Rule], ctx, max_rounds: int = 64,
           tracer=None, budget: Optional[Budget] = None,
           prefired=None) -> Dict[str, int]:
    """Saturate with delta-driven, phased rule scheduling.

    Each (and_id, rule) fires at most once, as in the exhaustive loop — but
    instead of rescanning the whole memo every round, every rule holds a
    cursor into a per-operator **applicability index** (op → and-ids, dense
    ids appended as nodes are created), so a round visits only the nodes
    created since that rule last ran. Rules run grouped by phase
    (``normalize`` → ``explore`` → ``cleanup``), each phase to its own
    fixpoint; a later phase's cursors start at zero, so it still sees every
    node earlier phases produced.

    ``budget`` (a :class:`Budget`) stops saturation mid-flight, setting
    ``budget_exhausted`` in the returned stats. ``prefired`` is a set of
    AND-ids no rule should visit — memo-pool replay marks restored nodes
    this way, since their alternatives were already harvested saturated.
    ``tracer`` (an :class:`repro.obs.trace.Tracer`) gets one span per
    phase round."""
    prefired = frozenset() if prefired is None else frozenset(prefired)
    if budget is not None:
        budget.start()

    # applicability index: op -> [and_id...], grown lazily; AND-ids are
    # dense sequential ints, so indexing new nodes is a range() walk.
    # Only ops some rule can match are indexed at all — on skeleton-heavy
    # programs most nodes (block/seq/cond) never enter any rule's worklist
    rule_ops = {r.op for r in rules}
    wildcard = "*" in rule_ops
    op_index: Dict[str, List[AndId]] = {op: [] for op in rule_ops
                                        if op != "*"}
    all_ids: List[AndId] = []
    indexed_upto = 0

    def _refresh() -> None:
        nonlocal indexed_upto
        n = len(memo._ands)
        ands = memo._ands
        for a in range(indexed_upto, n):
            lst = op_index.get(ands[a].op)
            if lst is not None:
                lst.append(a)
            if wildcard:
                all_ids.append(a)
        indexed_upto = n

    rounds = 0
    total_new = 0
    exhausted = False
    phase_rounds: Dict[str, int] = {}

    def _phase_round(phase: str, phase_rules: List[Rule],
                     cursors: Dict[str, int]) -> Tuple[int, bool]:
        stats_phase = memo.rule_stats.setdefault(phase, {})
        new = 0
        for r in phase_rules:
            _refresh()
            lst = all_ids if r.op == "*" else op_index.get(r.op)
            if not lst:
                continue
            pos = cursors[r.name]
            rstats = stats_phase.setdefault(
                r.name, {"matched": 0, "fired": 0, "missed": 0})
            # nodes appended to lst DURING this walk (by r itself or not yet
            # indexed) are picked up next round via the cursor
            end = len(lst)
            while pos < end:
                a = lst[pos]
                pos += 1
                if a in prefired:
                    continue
                rstats["matched"] += 1
                n_before = len(memo._ands)
                added = r.apply(memo, a, ctx)
                if added:
                    rstats["fired"] += 1
                    memo.rule_hits[r.name] = \
                        memo.rule_hits.get(r.name, 0) + added
                    for nid in range(n_before, len(memo._ands)):
                        memo.provenance.setdefault(nid, (r.name, a))
                    new += added
                else:
                    rstats["missed"] += 1
                if budget is not None and budget.exhausted(len(memo._ands)):
                    cursors[r.name] = pos
                    return new, True
            cursors[r.name] = pos
        return new, False

    for phase in PHASES:
        phase_rules = [r for r in rules
                       if getattr(r, "phase", "explore") == phase]
        if not phase_rules or exhausted:
            continue
        cursors = {r.name: 0 for r in phase_rules}
        pr = 0
        while rounds < max_rounds:
            rounds += 1
            pr += 1
            if tracer is not None and tracer.enabled:
                with tracer.span("saturate-round", round=rounds,
                                 phase=phase) as sp:
                    new, exhausted = _phase_round(phase, phase_rules, cursors)
                    sp.attrs["new_alternatives"] = new
            else:
                new, exhausted = _phase_round(phase, phase_rules, cursors)
            total_new += new
            if new == 0 or exhausted:
                break
        phase_rounds[phase] = pr

    return {"rounds": rounds, "alternatives_added": total_new,
            "budget_exhausted": exhausted,
            "phase_rounds": phase_rounds, **memo.stats()}


def expand_exhaustive(memo: Memo, rules: Sequence[Rule], ctx,
                      max_rounds: int = 64, tracer=None) -> Dict[str, int]:
    """The original saturation loop: every round rescans every AND-node
    against every rule until a full pass adds nothing. Kept as the reference
    implementation — the parity property tests and ``make bench-compile``
    assert ``expand`` reaches the identical memo fingerprint and winning
    plan, and measure the delta scheduler's speedup against this."""
    fired: Set[Tuple[AndId, str]] = set()
    rounds = 0
    total_new = 0

    def _round() -> int:
        new = 0
        for a in list(memo._ands):
            node = memo._ands[a]
            for r in rules:
                if r.op != node.op and r.op != "*":
                    continue
                tag = (a, r.name)
                if tag in fired:
                    continue
                fired.add(tag)
                n_before = len(memo._ands)
                added = r.apply(memo, a, ctx)
                if added:
                    memo.rule_hits[r.name] = \
                        memo.rule_hits.get(r.name, 0) + added
                    for nid in range(n_before, len(memo._ands)):
                        memo.provenance.setdefault(nid, (r.name, a))
                new += added
        return new

    while rounds < max_rounds:
        rounds += 1
        if tracer is not None and tracer.enabled:
            with tracer.span("saturate-round", round=rounds) as sp:
                new = _round()
                sp.attrs["new_alternatives"] = new
        else:
            new = _round()
        total_new += new
        if new == 0:
            break
    return {"rounds": rounds, "alternatives_added": total_new,
            "budget_exhausted": False, **memo.stats()}


def memo_fingerprint(memo: Memo, root: GroupId) -> str:
    """Content hash of the memo reachable from ``root``, invariant to group
    and AND-node numbering.

    Groups are relabeled canonically by a deterministic DFS from the root:
    within each group, members are ordered by structural key (operator,
    payload key, arity) — independent of insertion order — and their child
    groups visited in that order. The hash covers every reachable group's
    full member set, so two memos fingerprint equal iff they hold the same
    alternatives in the same equivalence classes (delta-scheduled and
    exhaustive saturation must agree here; the parity tests assert it)."""
    canon: Dict[GroupId, int] = {}
    order: List[GroupId] = []

    def label(g: GroupId) -> None:
        g = memo.find(g)
        if g not in canon:
            canon[g] = len(canon)
            order.append(g)

    def payload_key(node: AndNode):
        p = node.payload
        return p.key() if hasattr(p, "key") else p

    def member_sort_key(a: AndId):
        node = memo._ands[a]
        return (node.op, repr(payload_key(node)), len(node.children))

    label(root)
    i = 0
    while i < len(order):
        g = order[i]
        i += 1
        for a in sorted(memo._groups[memo.find(g)], key=member_sort_key):
            for c in memo._ands[a].children:
                label(c)

    desc = []
    for g in order:
        mems = []
        for a in memo._groups[memo.find(g)]:
            node = memo._ands[a]
            mems.append((node.op,
                         tuple(canon[memo.find(c)] for c in node.children),
                         repr(payload_key(node))))
        desc.append(tuple(sorted(mems, key=repr)))
    return hashlib.sha256(repr(tuple(desc)).encode()).hexdigest()
