"""The Region AND-OR DAG ("Region DAG", Sec. IV-B) — a Volcano/Cascades memo.

OR-nodes are *groups*: equivalence classes of regions/expressions — every
member computes the same state transition. AND-nodes are operators (`seq`,
`loop`, `cond`, `block`, and the F-IR operators) over child groups.

Volcano essentials implemented here:

  * **hash-consing** of AND-nodes: (op, child-group-ids, payload) → unique id,
    so re-derived expressions are detected as duplicates and cyclic rule sets
    (e.g. T2 ↔ N2) terminate;
  * **group union**: when a rule derives, inside group A, an expression whose
    root AND-node already belongs to group B, groups A and B are merged
    (union-find), exactly like Volcano's node merging;
  * **saturating expansion**: rules fire once per (AND-node, rule) pair until
    no rule produces anything new.

Payloads hold leaf content (a `Stmt`, an F-IR expr fragment, a `Query`) and
operator attributes (loop var/source, cond predicate).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

__all__ = ["AndNode", "Memo", "Rule", "GroupId", "AndId"]

GroupId = int
AndId = int


@dataclasses.dataclass(frozen=True)
class AndNode:
    """(operator, ordered child groups, payload). Payload must be hashable."""

    op: str
    children: Tuple[GroupId, ...]
    payload: object = None

    def key(self, canon: Callable[[GroupId], GroupId]) -> Tuple:
        payload_key = self.payload.key() if hasattr(self.payload, "key") else self.payload
        return (self.op, tuple(canon(c) for c in self.children), payload_key)


class Memo:
    def __init__(self):
        self._groups: Dict[GroupId, Set[AndId]] = {}
        self._ands: Dict[AndId, AndNode] = {}
        self._owner: Dict[AndId, GroupId] = {}
        self._and_index: Dict[Tuple, AndId] = {}
        self._parent: Dict[GroupId, GroupId] = {}  # union-find
        self._next_group = itertools.count()
        self._next_and = itertools.count()
        self.merges = 0
        self.duplicates = 0
        # rewrite provenance: and_id -> (rule name, source and_id) for every
        # AND-node a rule created (build_memo originals have no entry), and
        # per-rule alternative counts — recorded by expand(), consumed by
        # search.run_search to report which rules produced the winning plan
        self.provenance: Dict[AndId, Tuple[str, AndId]] = {}
        self.rule_hits: Dict[str, int] = {}

    # -------------------------------------------------------------- groups
    def find(self, g: GroupId) -> GroupId:
        while self._parent.get(g, g) != g:
            self._parent[g] = self._parent.get(self._parent[g], self._parent[g])
            g = self._parent[g]
        return g

    def new_group(self) -> GroupId:
        g = next(self._next_group)
        self._groups[g] = set()
        self._parent[g] = g
        return g

    def members(self, g: GroupId) -> Tuple[AndId, ...]:
        return tuple(sorted(self._groups[self.find(g)]))

    def groups(self) -> List[GroupId]:
        return sorted({self.find(g) for g in self._groups})

    def node(self, a: AndId) -> AndNode:
        return self._ands[a]

    def owner(self, a: AndId) -> GroupId:
        return self.find(self._owner[a])

    def canonical_children(self, a: AndId) -> Tuple[GroupId, ...]:
        return tuple(self.find(c) for c in self._ands[a].children)

    # --------------------------------------------------------------- insert
    def insert(self, node: AndNode, group: Optional[GroupId] = None) -> Tuple[GroupId, AndId]:
        """Insert an AND-node as an alternative of `group` (or a new group).

        Duplicate detection: if an identical node exists, reuse it; if it lives
        in a different group than requested, the groups are MERGED (they have
        been proven to compute the same transition)."""
        key = node.key(self.find)
        existing = self._and_index.get(key)
        if existing is not None:
            self.duplicates += 1
            owner = self.owner(existing)
            if group is not None and self.find(group) != owner:
                self._union(owner, self.find(group))
            return self.owner(existing), existing
        a = next(self._next_and)
        node = AndNode(node.op, tuple(self.find(c) for c in node.children), node.payload)
        self._ands[a] = node
        g = self.find(group) if group is not None else self.new_group()
        self._groups[g].add(a)
        self._owner[a] = g
        self._and_index[key] = a
        return g, a

    def _union(self, a: GroupId, b: GroupId) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return
        self.merges += 1
        # merge smaller into larger
        if len(self._groups[ra]) < len(self._groups[rb]):
            ra, rb = rb, ra
        self._groups[ra] |= self._groups[rb]
        for m in self._groups[rb]:
            self._owner[m] = ra
        self._groups[rb] = set()
        self._parent[rb] = ra
        # child references are canonicalized lazily via find()

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, int]:
        return {
            "groups": len(self.groups()),
            "and_nodes": len(self._ands),
            "duplicates_detected": self.duplicates,
            "group_merges": self.merges,
        }


@dataclasses.dataclass
class Rule:
    """A transformation rule: matches an AND-node, adds alternatives.

    `apply(memo, and_id, ctx) -> list of (AndNode trees)` — implementations
    insert directly via memo.insert(..., group=owner) and return how many
    alternatives they added (for fixpoint detection)."""

    name: str
    op: str  # root operator this rule matches ("fold", "loop", ...)
    fn: Callable  # (memo, and_id, ctx) -> int (number of new alternatives)

    def apply(self, memo: Memo, and_id: AndId, ctx) -> int:
        return self.fn(memo, and_id, ctx)


def expand(memo: Memo, rules: Sequence[Rule], ctx, max_rounds: int = 64,
           tracer=None) -> Dict[str, int]:
    """Saturate: apply every rule to every matching AND-node until fixpoint.

    Each (and_id, rule) fires at most once — with hash-consing this guarantees
    termination even for cyclic rule sets (Sec. III-A). Every AND-node a rule
    creates is attributed to it in ``memo.provenance`` (AND-ids are issued
    sequentially, so the nodes created by one ``apply`` call are exactly the
    id range that appeared across it). ``tracer`` (an
    :class:`repro.obs.trace.Tracer`) gets one span per saturation round."""
    fired: Set[Tuple[AndId, str]] = set()
    rounds = 0
    total_new = 0

    def _round() -> int:
        new = 0
        for a in list(memo._ands):
            node = memo._ands[a]
            for r in rules:
                if r.op != node.op and r.op != "*":
                    continue
                tag = (a, r.name)
                if tag in fired:
                    continue
                fired.add(tag)
                n_before = len(memo._ands)
                added = r.apply(memo, a, ctx)
                if added:
                    memo.rule_hits[r.name] = \
                        memo.rule_hits.get(r.name, 0) + added
                    for nid in range(n_before, len(memo._ands)):
                        memo.provenance.setdefault(nid, (r.name, a))
                new += added
        return new

    while rounds < max_rounds:
        rounds += 1
        if tracer is not None and tracer.enabled:
            with tracer.span("saturate-round", round=rounds) as sp:
                new = _round()
                sp.attrs["new_alternatives"] = new
        else:
            new = _round()
        total_new += new
        if new == 0:
            break
    return {"rounds": rounds, "alternatives_added": total_new, **memo.stats()}
