"""Imperative program IR with single-entry/single-exit regions (Sec. III-B, IV).

A program is a tree of regions:

    BasicBlock   — one statement (the paper treats each statement as a block)
    SeqRegion    — ordered children
    LoopRegion   — cursor loop ``for (t : <source>) { body }``
    CondRegion   — if/else
    WhileRegion  — guarded loop ``while (pred) { body }``

Early-exit statements (``BreakStmt``/``ContinueStmt``/``ReturnStmt``) cover
the imperative constructs the paper's Sec. V limitations call out: the
interpreters execute them faithfully (as non-local exits), while the
rewriting layers stay conservative — a cursor loop containing an exit is
never converted to F-IR or vectorized, and a ``while`` body participates in
rewrites only through the ordinary loops nested inside it.

Regions are *state transitions* ``R : X0 → X1`` (Sec. IV-A); the state is the
environment of program variables. Two interpreters execute regions against a
``ClientEnv`` (simulated client/server database, Sec. VIII):

  * ``Interpreter(mode="exact")`` — row-at-a-time semantics, the ground truth.
  * ``Interpreter(mode="fast")``  — vectorized execution of recognized cursor-
    loop bodies (columnar jnp compute) charging identical simulated time.
    Property-tested equivalent to ``exact`` (tests/test_properties.py).

Statement/expression vocabulary covers the paper's workloads: ORM loadAll /
relationship navigation (the N+1 pattern), executeQuery, prefetch +
cacheByColumn/lookup (footnote 3), collection/map accumulation, scalar
aggregation, and DB updates (left intact by F-IR, Sec. V-A).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..relational.algebra import Query, Scan
from ..relational.database import ClientEnv
from ..relational.table import Table
from .context import loop_site_key, while_site_key

__all__ = [
    # expressions
    "IExpr", "IConst", "IVar", "IField", "IBin", "ICall", "IQuery", "ILoadAll",
    "INav", "ICacheLookup", "IEmptyList", "IEmptyMap", "IIndex", "ILen",
    "IScalarQuery", "IQueryValues",
    # statements
    "Stmt", "Assign", "CollectionAdd", "MapPut", "Prefetch", "CacheByColumn",
    "UpdateRow", "NoOp", "BreakStmt", "ContinueStmt", "ReturnStmt",
    # regions
    "Region", "BasicBlock", "SeqRegion", "LoopRegion", "CondRegion",
    "WhileRegion", "Program",
    "Interpreter", "register_function", "get_function", "write_tables",
    "CompileNote", "compilability",
]

# --------------------------------------------------------------------------
# Registered pure functions (like myFunc in Fig. 3) — must be jnp-vectorizable
# --------------------------------------------------------------------------

_FUNCTIONS: Dict[str, Callable] = {
    "myFunc": lambda *args: sum(a * (i + 1) for i, a in enumerate(args)),
    "combine": lambda a, b: a * 31 + b,
    "scale": lambda a: a * 3,
}


def register_function(name: str, fn: Callable) -> None:
    _FUNCTIONS[name] = fn
    # the SQL-translation rules (T3/T4) push calls into relational computed
    # columns, so every program function is also a relational scalar func
    from ..relational.algebra import register_scalar_func
    register_scalar_func(name, fn)


def _register_builtins() -> None:
    for _n, _f in list(_FUNCTIONS.items()):
        register_function(_n, _f)


def get_function(name: str) -> Callable:
    return _FUNCTIONS[name]


# --------------------------------------------------------------------------
# Expressions
# --------------------------------------------------------------------------

class IExpr:
    def key(self) -> Tuple:
        raise NotImplementedError

    def __hash__(self):
        return hash(self.key())

    def __eq__(self, other):
        return isinstance(other, IExpr) and self.key() == other.key()

    def free_vars(self) -> Tuple[str, ...]:
        return ()


@dataclasses.dataclass(frozen=True, eq=False)
class IConst(IExpr):
    value: object

    def key(self):
        return ("iconst", self.value)

    def __repr__(self):
        return repr(self.value)


@dataclasses.dataclass(frozen=True, eq=False)
class IVar(IExpr):
    name: str

    def key(self):
        return ("ivar", self.name)

    def free_vars(self):
        return (self.name,)

    def __repr__(self):
        return self.name


@dataclasses.dataclass(frozen=True, eq=False)
class IField(IExpr):
    """Row-field access ``t.col`` where ``t`` holds a row (dict)."""

    base: IExpr
    field: str

    def key(self):
        return ("ifield", self.base.key(), self.field)

    def free_vars(self):
        return self.base.free_vars()

    def __repr__(self):
        return f"{self.base!r}.{self.field}"


_BIN_OPS: Dict[str, Callable] = {
    "+": lambda a, b: a + b, "-": lambda a, b: a - b, "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "==": lambda a, b: a == b, "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b, "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b, ">=": lambda a, b: a >= b,
    "and": lambda a, b: jnp.logical_and(a, b) if isinstance(a, jnp.ndarray) else (a and b),
    "or": lambda a, b: jnp.logical_or(a, b) if isinstance(a, jnp.ndarray) else (a or b),
    "min": lambda a, b: jnp.minimum(a, b) if isinstance(a, jnp.ndarray) else min(a, b),
    "max": lambda a, b: jnp.maximum(a, b) if isinstance(a, jnp.ndarray) else max(a, b),
}


@dataclasses.dataclass(frozen=True, eq=False)
class IBin(IExpr):
    op: str
    left: IExpr
    right: IExpr

    def key(self):
        return ("ibin", self.op, self.left.key(), self.right.key())

    def free_vars(self):
        return self.left.free_vars() + self.right.free_vars()

    def __repr__(self):
        return f"({self.left!r} {self.op} {self.right!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class ICall(IExpr):
    func: str
    args: Tuple[IExpr, ...]

    def key(self):
        return ("icall", self.func, tuple(a.key() for a in self.args))

    def free_vars(self):
        out: Tuple[str, ...] = ()
        for a in self.args:
            out += a.free_vars()
        return out

    def __repr__(self):
        return f"{self.func}({', '.join(map(repr, self.args))})"


@dataclasses.dataclass(frozen=True, eq=False)
class IQuery(IExpr):
    """``executeQuery(q)`` — q may contain Param(:p) bound from imperative exprs."""

    query: Query
    bindings: Tuple[Tuple[str, IExpr], ...] = ()

    def key(self):
        return ("iquery", self.query.key(), tuple((n, e.key()) for n, e in self.bindings))

    def free_vars(self):
        out: Tuple[str, ...] = ()
        for _, e in self.bindings:
            out += e.free_vars()
        return out

    def __repr__(self):
        return f"executeQuery({self.query.sql()!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class ILoadAll(IExpr):
    """ORM ``loadAll(Entity.class)`` — a full-table fetch."""

    table: str

    def key(self):
        return ("iloadall", self.table)

    def __repr__(self):
        return f"loadAll({self.table})"


@dataclasses.dataclass(frozen=True, eq=False)
class INav(IExpr):
    """ORM relationship navigation ``o.customer`` → lazy point query.

    ``base.fk_field`` is the foreign key; resolves one row of ``target``
    (keyed by ``target_key``) through the ORM id-cache.
    """

    base: IExpr
    fk_field: str
    target: str
    target_key: str

    def key(self):
        return ("inav", self.base.key(), self.fk_field, self.target, self.target_key)

    def free_vars(self):
        return self.base.free_vars()

    def __repr__(self):
        return f"{self.base!r}->{self.target}"


@dataclasses.dataclass(frozen=True, eq=False)
class ICacheLookup(IExpr):
    """``Utils.lookupCache`` over a prefetched, column-keyed cache."""

    table: str
    col: str
    keyexpr: IExpr
    all_matches: bool = False  # True → list of rows, False → single row

    def key(self):
        return ("icachelookup", self.table, self.col, self.keyexpr.key(), self.all_matches)

    def free_vars(self):
        return self.keyexpr.free_vars()

    def __repr__(self):
        return f"lookupCache({self.table}.{self.col}, {self.keyexpr!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class IScalarQuery(IExpr):
    """Execute a query and return one scalar (first row of `col`; 0 if empty)."""

    query: Query
    col: str
    bindings: Tuple[Tuple[str, "IExpr"], ...] = ()

    def key(self):
        return ("iscalarquery", self.query.key(), self.col,
                tuple((n, e.key()) for n, e in self.bindings))

    def free_vars(self):
        out: Tuple[str, ...] = ()
        for _, e in self.bindings:
            out += e.free_vars()
        return out

    def __repr__(self):
        return f"scalarQuery({self.query.sql()!r}, {self.col})"


@dataclasses.dataclass(frozen=True, eq=False)
class IQueryValues(IExpr):
    """Execute a query and return `col` as a Python list (collection value)."""

    query: Query
    col: str

    def key(self):
        return ("iqueryvalues", self.query.key(), self.col)

    def __repr__(self):
        return f"queryValues({self.query.sql()!r}, {self.col})"


@dataclasses.dataclass(frozen=True, eq=False)
class IEmptyList(IExpr):
    def key(self):
        return ("iemptylist",)

    def __repr__(self):
        return "{}"


@dataclasses.dataclass(frozen=True, eq=False)
class IEmptyMap(IExpr):
    def key(self):
        return ("iemptymap",)

    def __repr__(self):
        return "Map()"


@dataclasses.dataclass(frozen=True, eq=False)
class IIndex(IExpr):
    """Subscript read ``base[key]`` on a collection/map/query-result value.

    The field is named ``keyexpr`` (not ``index``) so the generic IExpr
    walkers — table extraction in ``api.cache`` and the operator-cost
    traversal in ``core.cost`` — cover it without special cases."""

    base: IExpr
    keyexpr: IExpr

    def key(self):
        return ("iindex", self.base.key(), self.keyexpr.key())

    def free_vars(self):
        return self.base.free_vars() + self.keyexpr.free_vars()

    def __repr__(self):
        return f"{self.base!r}[{self.keyexpr!r}]"


@dataclasses.dataclass(frozen=True, eq=False)
class ILen(IExpr):
    base: IExpr

    def key(self):
        return ("ilen", self.base.key())

    def free_vars(self):
        return self.base.free_vars()

    def __repr__(self):
        return f"len({self.base!r})"


# --------------------------------------------------------------------------
# Statements
# --------------------------------------------------------------------------

class Stmt:
    def key(self) -> Tuple:
        raise NotImplementedError

    def __hash__(self):
        return hash(self.key())

    def __eq__(self, other):
        return isinstance(other, Stmt) and self.key() == other.key()

    def defs(self) -> Tuple[str, ...]:
        return ()

    def uses(self) -> Tuple[str, ...]:
        return ()


@dataclasses.dataclass(frozen=True, eq=False)
class Assign(Stmt):
    target: str
    expr: IExpr

    def key(self):
        return ("assign", self.target, self.expr.key())

    def defs(self):
        return (self.target,)

    def uses(self):
        return self.expr.free_vars()

    def __repr__(self):
        return f"{self.target} = {self.expr!r}"


@dataclasses.dataclass(frozen=True, eq=False)
class CollectionAdd(Stmt):
    target: str
    expr: IExpr

    def key(self):
        return ("colladd", self.target, self.expr.key())

    def defs(self):
        return (self.target,)

    def uses(self):
        return (self.target,) + self.expr.free_vars()

    def __repr__(self):
        return f"{self.target}.add({self.expr!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class MapPut(Stmt):
    target: str
    keyexpr: IExpr
    valexpr: IExpr

    def key(self):
        return ("mapput", self.target, self.keyexpr.key(), self.valexpr.key())

    def defs(self):
        return (self.target,)

    def uses(self):
        return (self.target,) + self.keyexpr.free_vars() + self.valexpr.free_vars()

    def __repr__(self):
        return f"{self.target}.put({self.keyexpr!r}, {self.valexpr!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class Prefetch(Stmt):
    """``prefetch(R, A)``: fetch a query result and cache it keyed by column A."""

    query: Query
    col: str
    cache_name: Optional[str] = None  # defaults to root table name

    def key(self):
        return ("prefetch", self.query.key(), self.col)

    def __repr__(self):
        return f"prefetch({self.query.sql()!r}, by={self.col})"


@dataclasses.dataclass(frozen=True, eq=False)
class CacheByColumn(Stmt):
    """``Utils.cacheByColumn(collection_var, col)`` on an already-fetched table."""

    var: str
    col: str

    def key(self):
        return ("cachebycolumn", self.var, self.col)

    def uses(self):
        return (self.var,)

    def __repr__(self):
        return f"cacheByColumn({self.var}, {self.col!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class UpdateRow(Stmt):
    """DB update — F-IR leaves updates intact (Sec. V limitations)."""

    table: str
    set_col: str
    val: IExpr
    key_col: str
    keyexpr: IExpr

    def key(self):
        return ("update", self.table, self.set_col, self.val.key(),
                self.key_col, self.keyexpr.key())

    def uses(self):
        return self.val.free_vars() + self.keyexpr.free_vars()

    def __repr__(self):
        return (f"UPDATE {self.table} SET {self.set_col}={self.val!r} "
                f"WHERE {self.key_col}={self.keyexpr!r}")


@dataclasses.dataclass(frozen=True, eq=False)
class NoOp(Stmt):
    note: str = ""

    def key(self):
        return ("noop", self.note)

    def __repr__(self):
        return f"noop({self.note})"


@dataclasses.dataclass(frozen=True, eq=False)
class BreakStmt(Stmt):
    """Exit the nearest enclosing loop (``break``)."""

    def key(self):
        return ("break",)

    def __repr__(self):
        return "break"


@dataclasses.dataclass(frozen=True, eq=False)
class ContinueStmt(Stmt):
    """Skip to the next iteration of the nearest enclosing loop."""

    def key(self):
        return ("continue",)

    def __repr__(self):
        return "continue"


@dataclasses.dataclass(frozen=True, eq=False)
class ReturnStmt(Stmt):
    """Early exit from the whole program body.

    Program outputs stay the declared variable names; a return site assigns
    them first (the frontend lowers ``return e`` that way), then exits."""

    def key(self):
        return ("return",)

    def __repr__(self):
        return "return"


# --------------------------------------------------------------------------
# Regions
# --------------------------------------------------------------------------

_region_counter = itertools.count()


class Region:
    label: str

    def key(self) -> Tuple:
        raise NotImplementedError

    def __hash__(self):
        return hash(self.key())

    def __eq__(self, other):
        return isinstance(other, Region) and self.key() == other.key()

    def children(self) -> Tuple["Region", ...]:
        return ()


@dataclasses.dataclass(frozen=True, eq=False)
class BasicBlock(Region):
    stmt: Stmt
    label: str = ""

    def key(self):
        return ("B", self.stmt.key())

    def __repr__(self):
        return f"B[{self.stmt!r}]"


@dataclasses.dataclass(frozen=True, eq=False)
class SeqRegion(Region):
    parts: Tuple[Region, ...]
    label: str = ""

    def key(self):
        return ("S", tuple(p.key() for p in self.parts))

    def children(self):
        return self.parts

    def __repr__(self):
        return "S[" + "; ".join(map(repr, self.parts)) + "]"


@dataclasses.dataclass(frozen=True, eq=False)
class LoopRegion(Region):
    """Cursor loop ``for (var : source) body``. Source: IQuery/ILoadAll/IVar."""

    var: str
    source: IExpr
    body: Region
    label: str = ""

    def key(self):
        return ("L", self.var, self.source.key(), self.body.key())

    def children(self):
        return (self.body,)

    def __repr__(self):
        return f"L[for {self.var} : {self.source!r} {{ {self.body!r} }}]"


@dataclasses.dataclass(frozen=True, eq=False)
class CondRegion(Region):
    pred: IExpr
    then_r: Region
    else_r: Optional[Region] = None
    label: str = ""

    def key(self):
        return ("C", self.pred.key(), self.then_r.key(),
                self.else_r.key() if self.else_r else None)

    def children(self):
        return (self.then_r,) + ((self.else_r,) if self.else_r else ())

    def __repr__(self):
        e = f" else {{ {self.else_r!r} }}" if self.else_r else ""
        return f"C[if {self.pred!r} {{ {self.then_r!r} }}{e}]"


@dataclasses.dataclass(frozen=True, eq=False)
class WhileRegion(Region):
    """Guarded loop ``while (pred) body`` — iteration count is data-dependent,
    so the region itself is never folded to F-IR; loops nested in its body
    still participate in rewrites individually."""

    pred: IExpr
    body: Region
    label: str = ""

    def key(self):
        return ("W", self.pred.key(), self.body.key())

    def children(self):
        return (self.body,)

    def __repr__(self):
        return f"W[while {self.pred!r} {{ {self.body!r} }}]"


@dataclasses.dataclass(frozen=True, eq=False)
class Program:
    """Outermost region + the variables whose final values are the output state."""

    name: str
    body: Region
    outputs: Tuple[str, ...]
    inputs: Tuple[Tuple[str, object], ...] = ()

    def key(self):
        return ("P", self.name, self.body.key(), self.outputs)


def seq(*parts: Union[Region, Stmt]) -> SeqRegion:
    rs = tuple(BasicBlock(p) if isinstance(p, Stmt) else p for p in parts)
    return SeqRegion(rs)


def write_tables(program: Program) -> Tuple[str, ...]:
    """The base tables a Program WRITES (``UpdateRow`` statements), sorted.

    The canonical write-set walk: the serving runtime's write-set-aware
    batching and the cost model's amortization guard (a site over a
    written table can never be served from a shared cache) both consume
    it; ``repro.api.cache.program_write_tables`` delegates here."""
    out = set()

    def walk(r: Region):
        if isinstance(r, BasicBlock) and isinstance(r.stmt, UpdateRow):
            out.add(r.stmt.table)
        for c in r.children():
            walk(c)

    walk(program.body)
    return tuple(sorted(out))


# --------------------------------------------------------------------------
# Compilability analysis
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompileNote:
    """Per-region verdict of the compiled tier's lowering analysis.

    ``verdict`` is ``"columnar"`` (the region lowers to a vectorized
    executable) or ``"interpreter"`` (it stays on the row-at-a-time /
    splicing interpreter); ``reason`` names the construct that forced the
    interpreter tier. ``site`` is the region's iteration-site key, so
    annotations join against the feedback controller's observed counts."""

    kind: str      # "loop" | "while"
    verdict: str   # "columnar" | "interpreter"
    reason: str
    site: str


def _has_early_exit(r: Region) -> bool:
    if isinstance(r, BasicBlock):
        return isinstance(r.stmt, (BreakStmt, ContinueStmt, ReturnStmt))
    return any(_has_early_exit(c) for c in r.children())


def _has_nested_iteration(r: Region) -> bool:
    if isinstance(r, (LoopRegion, WhileRegion)):
        return True
    return any(_has_nested_iteration(c) for c in r.children())


def _loop_reject_reason(r: LoopRegion) -> str:
    """Coarse diagnosis of WHY ``analyze_loop`` rejected a loop body. The
    authoritative accept/reject is ``vectorize.analyze_loop``; this only
    names the blocking construct for annotations/telemetry."""
    if _has_early_exit(r.body):
        return "early-exit (break/continue/return pins iteration order)"
    if _has_nested_iteration(r.body):
        return "nested loop in body"

    def has_else(x: Region) -> bool:
        if isinstance(x, CondRegion) and x.else_r is not None:
            return True
        return any(has_else(c) for c in x.children())

    if has_else(r.body):
        return "if/else body (only a single guard if vectorizes)"
    return "statement outside the columnar vocabulary"


def compilability(program: Union[Program, Region]) -> Dict[Tuple, CompileNote]:
    """Annotate every iteration region with its compiled-tier verdict.

    Returns ``{region.key(): CompileNote}``. Loops whose bodies
    ``vectorize.analyze_loop`` accepts are ``"columnar"`` — the compiled
    tier lowers exactly those; ``while`` regions (data-dependent iteration
    counts) and rejected loop bodies stay ``"interpreter"``, and the
    compiled executable splices its columnar segments around them."""
    from .vectorize import analyze_loop

    notes: Dict[Tuple, CompileNote] = {}
    body = program.body if isinstance(program, Program) else program

    def walk(r: Region) -> None:
        if isinstance(r, LoopRegion):
            if analyze_loop(r, {}) is not None:
                notes[r.key()] = CompileNote(
                    kind="loop", verdict="columnar", reason="",
                    site=loop_site_key(r.var, r.source))
            else:
                notes[r.key()] = CompileNote(
                    kind="loop", verdict="interpreter",
                    reason=_loop_reject_reason(r),
                    site=loop_site_key(r.var, r.source))
        elif isinstance(r, WhileRegion):
            notes[r.key()] = CompileNote(
                kind="while", verdict="interpreter",
                reason="data-dependent iteration count",
                site=while_site_key(r.pred))
        for c in r.children():
            walk(c)

    walk(body)
    return notes


# --------------------------------------------------------------------------
# Interpreter
# --------------------------------------------------------------------------

class _Row(dict):
    """A row value; dict with attribute-ish access by field name."""


class _BreakSignal(Exception):
    """Raised by BreakStmt; caught by the nearest enclosing loop."""


class _ContinueSignal(Exception):
    """Raised by ContinueStmt; caught by the nearest enclosing loop."""


class _ReturnSignal(Exception):
    """Raised by ReturnStmt; caught at Program level (Interpreter.run)."""


# runaway-while backstop: a genuine program never gets close, a bad guard
# fails loudly instead of hanging the test suite
MAX_WHILE_ITERS = 1_000_000


class Interpreter:
    """Executes regions against a ClientEnv; accumulates simulated time there."""

    def __init__(self, env: ClientEnv, mode: str = "exact"):
        assert mode in ("exact", "fast")
        self.env = env
        self.mode = mode

    # ------------------------------------------------------------ public API
    def run(self, program: Program, init_state: Optional[Mapping[str, object]] = None
            ) -> Dict[str, object]:
        state: Dict[str, object] = dict(program.inputs)
        if init_state:
            state.update(init_state)
        try:
            self.exec_region(program.body, state)
        except _ReturnSignal:
            pass  # early `return`: outputs are the state at the exit point
        return {v: state.get(v) for v in program.outputs}

    # ---------------------------------------------------------------- exprs
    def eval(self, e: IExpr, state: Dict[str, object]):
        env = self.env
        if isinstance(e, IConst):
            return e.value
        if isinstance(e, IVar):
            return state[e.name]
        if isinstance(e, IField):
            row = self.eval(e.base, state)
            return row[e.field]
        if isinstance(e, IBin):
            return _BIN_OPS[e.op](self.eval(e.left, state), self.eval(e.right, state))
        if isinstance(e, ICall):
            return _FUNCTIONS[e.func](*[self.eval(a, state) for a in e.args])
        if isinstance(e, IQuery):
            params = {n: self.eval(x, state) for n, x in e.bindings}
            return env.execute_query(e.query, params or None)
        if isinstance(e, ILoadAll):
            return env.execute_query(Scan(e.table))
        if isinstance(e, INav):
            row = self.eval(e.base, state)
            return env.point_lookup(e.target, e.target_key, row[e.fk_field])
        if isinstance(e, ICacheLookup):
            k = self.eval(e.keyexpr, state)
            if e.all_matches:
                return env.lookup_cache_all(e.table, e.col, k)
            return env.lookup_cache(e.table, e.col, k)
        if isinstance(e, IScalarQuery):
            params = {n: self.eval(x, state) for n, x in e.bindings}
            t = env.execute_query(e.query, params or None)
            if t.nrows == 0:
                return 0
            return t.column(e.col)[0].item()
        if isinstance(e, IQueryValues):
            t = env.execute_query(e.query)
            return np.asarray(t.column(e.col)).tolist()
        if isinstance(e, IEmptyList):
            return []
        if isinstance(e, IEmptyMap):
            return {}
        if isinstance(e, IIndex):
            v = self.eval(e.base, state)
            k = self.eval(e.keyexpr, state)
            if isinstance(v, Table):
                return _Row(v.to_rows()[int(k)])
            out = v[k]
            return _Row(out) if isinstance(out, dict) and not isinstance(
                out, _Row) else out
        if isinstance(e, ILen):
            v = self.eval(e.base, state)
            return v.nrows if isinstance(v, Table) else len(v)
        raise TypeError(f"cannot eval {e!r}")

    # ----------------------------------------------------------- statements
    def exec_stmt(self, s: Stmt, state: Dict[str, object]) -> None:
        env = self.env
        if isinstance(s, Assign):
            env.charge_statement()
            state[s.target] = self.eval(s.expr, state)
        elif isinstance(s, CollectionAdd):
            env.charge_statement()
            state.setdefault(s.target, [])
            state[s.target].append(self.eval(s.expr, state))
        elif isinstance(s, MapPut):
            env.charge_statement()
            state.setdefault(s.target, {})
            state[s.target][self.eval(s.keyexpr, state)] = self.eval(s.valexpr, state)
        elif isinstance(s, Prefetch):
            t = env.execute_query(s.query)
            env.cache_by_column(
                t if s.cache_name is None else
                Table(s.cache_name, t.schema, t.columns), s.col)
            state[f"__prefetch_{t.name}_{s.col}"] = t
        elif isinstance(s, CacheByColumn):
            v = state[s.var]
            assert isinstance(v, Table), "cacheByColumn expects a query result"
            env.cache_by_column(v, s.col)
        elif isinstance(s, UpdateRow):
            # one round trip per update statement; value computed client-side
            val = self.eval(s.val, state)
            key = self.eval(s.keyexpr, state)
            m = env.db.model
            env._charge_query(1, 16, m.startup_s + m.index_lookup_s,
                              m.startup_s + m.index_lookup_s)
            t = env.db.table(s.table)
            arr = np.asarray(t.column(s.key_col))
            idx = np.flatnonzero(arr == key)
            if len(idx):
                col = np.asarray(t.column(s.set_col)).copy()
                col[idx] = val
                env.db.add_table(t.with_column(t.schema.field(s.set_col), col))
        elif isinstance(s, NoOp):
            env.charge_statement()
        elif isinstance(s, BreakStmt):
            env.charge_statement()
            raise _BreakSignal()
        elif isinstance(s, ContinueStmt):
            env.charge_statement()
            raise _ContinueSignal()
        elif isinstance(s, ReturnStmt):
            env.charge_statement()
            raise _ReturnSignal()
        else:
            raise TypeError(f"cannot exec {s!r}")

    # -------------------------------------------------------------- regions
    def exec_region(self, r: Region, state: Dict[str, object]) -> None:
        if isinstance(r, BasicBlock):
            self.exec_stmt(r.stmt, state)
        elif isinstance(r, SeqRegion):
            for p in r.parts:
                self.exec_region(p, state)
        elif isinstance(r, CondRegion):
            self.env.charge_statement()
            if bool(self.eval(r.pred, state)):
                self.exec_region(r.then_r, state)
            elif r.else_r is not None:
                self.exec_region(r.else_r, state)
        elif isinstance(r, LoopRegion):
            src = self.eval(r.source, state)
            if self.mode == "fast":
                from .vectorize import try_exec_loop_fast
                if try_exec_loop_fast(self, r, src, state):
                    return
            self._exec_loop_exact(r, src, state)
        elif isinstance(r, WhileRegion):
            iters = 0
            try:
                while True:
                    self.env.charge_statement()  # guard evaluation
                    if not bool(self.eval(r.pred, state)):
                        break
                    iters += 1
                    if iters > MAX_WHILE_ITERS:
                        raise RuntimeError(
                            f"while loop exceeded {MAX_WHILE_ITERS} iterations "
                            f"(guard {r.pred!r} never became false)")
                    try:
                        self.exec_region(r.body, state)
                    except _ContinueSignal:
                        continue
                    except _BreakSignal:
                        break
            finally:
                # observed iteration count for this while site — the number
                # the cost model only ever estimated (while_iters_default);
                # the feedback controller folds these into a StatsProfile
                self.env.record_iterations(while_site_key(r.pred), iters)
        else:
            raise TypeError(f"cannot exec region {r!r}")

    def _exec_loop_exact(self, r: LoopRegion, src, state: Dict[str, object]) -> None:
        rows: Sequence
        if isinstance(src, Table):
            rows = src.to_rows()
        elif isinstance(src, list):
            rows = src
            # collection-source loops have no table statistics behind them;
            # record the true length so feedback can replace the cost
            # model's loop_iters_default for this site
            if not isinstance(r.source, (IQuery, ILoadAll)):
                self.env.record_iterations(loop_site_key(r.var, r.source),
                                           len(rows))
        else:
            raise TypeError(f"cannot iterate {type(src)}")
        for row in rows:
            self.env.charge_statement()  # loop header/advance
            state[r.var] = _Row(row) if isinstance(row, dict) else row
            try:
                self.exec_region(r.body, state)
            except _ContinueSignal:
                continue
            except _BreakSignal:
                break
        state.pop(r.var, None)


_register_builtins()
