"""Cobra's cost model (Sec. VI, Fig. 12).

    C_Q        = C_NRT + C_Q^F + max(N_Q · S_row(Q) / BW,  C_Q^L − C_Q^F)
    C_prefetch = C_Q / AF_Q
    C_seq      = Σ children
    C_cond     = p·C_true + (1−p)·C_false + C_p
    C_fold     = N_Q · C_f + C_Db(Q)
    C_loop     = K · C_body          (non-fold loops; K estimated)
    C_block    = Σ C_Z per statement
    other F-IR operators: C_Y each

All database-dependent terms (N_Q, S_row, C_Q^F, C_Q^L) come from
``DatabaseServer.estimate`` — statistics only, never true execution (the
paper consulted the DB optimizer the same way). ORM point lookups are
costed with the Hibernate id-cache modeled: first access per distinct key
is a round trip, the rest are local hits.

**Execution-context awareness.** The model is constructed from
``(db, catalog, context)`` — an :class:`~repro.core.context.ExecutionContext`
describing the runtime the plan is compiled for:

  * ``batch_size`` B > 1 models :class:`~repro.runtime.batch.BatchClientEnv`
    sharing across a batch: a query site whose bindings cannot differ
    between invocations (no ``Param`` anywhere in the tree) is fetched from
    the server once per batch, so its cost amortizes to C_Q / B per
    invocation (:meth:`CostModel.amortize`); ORM point lookups amortize the
    same way (the batch env's id-cache and bulk navigation fetch are
    shared).
  * **parameterized** sites amortize by the OBSERVED distinct-binding
    fraction d when the context's stats carry one for the site's table
    group (:meth:`CostModel.param_site_amortization`): the serving site
    cache serves repeated bindings locally, so only the d·B distinct
    bindings in a batch pay a server fetch — per-invocation cost
    C_Q · max(d, 1/B). Without an observation they stay un-amortized
    (conservative — their bindings may all differ).
  * observed iteration counts from ``context.stats`` replace the catalog
    defaults for while guards (``while_iters_default``) and cursor loops
    over collection sources (``loop_iters_default``) — the sites whose
    cardinality table statistics cannot estimate.

``CostModel`` is a pluggable protocol: ``OptimizerConfig.cost_model``
accepts any class with this constructor signature and method surface, and
the memo search costs plans through it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from ..relational.algebra import (Cmp, Col, Param, Query, Scalar, Scan,
                                  Select, scan_tables)
from ..relational.database import DatabaseServer, NetworkProfile
from .context import (ExecutionContext, ONE_SHOT, loop_site_key,
                      param_group_key, param_prov_key, while_site_key)
from .fir import (FCacheLookupAllE, FCacheLookupE, FCondE, FExpr, FFoldE,
                  FPointLookup, FQueryE, FSelLookupE, FTupleE, fir_children)

__all__ = ["CostCatalog", "CostModel", "query_has_params",
           "query_param_cols", "query_pred_cols"]


def _embedded_scalars(node):
    """Every Scalar hanging off one dataclass node — covers predicates,
    computed-projection pairs, and whatever scalar slots future operators
    add, without naming fields."""
    for f in dataclasses.fields(node):
        v = getattr(node, f.name)
        if isinstance(v, Scalar):
            yield v
        elif isinstance(v, tuple):
            for item in v:
                if isinstance(item, Scalar):
                    yield item
                elif isinstance(item, tuple):
                    yield from (x for x in item if isinstance(x, Scalar))


def query_has_params(q: Query) -> bool:
    """True iff a relational tree contains a ``Param`` anywhere (predicates
    and computed projections included) — the sites whose bindings may differ
    between batched invocations, so they never amortize."""
    def scalar_has(s: Scalar) -> bool:
        if isinstance(s, Param):
            return True
        return any(scalar_has(k) for k in _embedded_scalars(s))

    if any(scalar_has(s) for s in _embedded_scalars(q)):
        return True
    return any(query_has_params(c) for c in q.children())


def query_param_cols(q: Query) -> Tuple[str, ...]:
    """Sorted names of the columns a relational tree compares against a
    ``Param`` — with the table set, the rewrite-stable identity of a
    parameterized site (:func:`~repro.core.context.param_prov_key`):
    rewrites rename parameters, but a σ's predicate column survives as the
    rewritten form's lookup key column."""
    cols = set()

    def scalar_has_param(s: Scalar) -> bool:
        if isinstance(s, Param):
            return True
        return any(scalar_has_param(k) for k in _embedded_scalars(s))

    def from_scalar(s: Scalar) -> None:
        if isinstance(s, Cmp):
            for a, b in ((s.left, s.right), (s.right, s.left)):
                if isinstance(a, Col) and scalar_has_param(b):
                    cols.add(a.name)
        for k in _embedded_scalars(s):
            from_scalar(k)

    def walk(node: Query) -> None:
        for s in _embedded_scalars(node):
            from_scalar(s)
        for c in node.children():
            walk(c)

    walk(q)
    return tuple(sorted(cols))


def query_pred_cols(q: Query) -> Tuple[str, ...]:
    """Sorted names of every column a relational tree COMPARES (either side
    of any ``Cmp``, against params, literals or other columns) — the
    columns whose histograms a targeted re-analyze rebuilds when the
    site's cardinality estimate drifts (the feedback controller's q-error
    path)."""
    cols = set()

    def from_scalar(s: Scalar) -> None:
        if isinstance(s, Cmp):
            for side in (s.left, s.right):
                if isinstance(side, Col):
                    cols.add(side.name)
        for k in _embedded_scalars(s):
            from_scalar(k)

    def walk(node: Query) -> None:
        for s in _embedded_scalars(node):
            from_scalar(s)
        for c in node.children():
            walk(c)

    walk(q)
    return tuple(sorted(cols))


@dataclasses.dataclass
class CostCatalog:
    """The tunable cost-catalog file of Sec. VIII."""

    network: NetworkProfile
    c_z: float = 30e-9          # per imperative statement (paper: 30 ns)
    c_y: float = 30e-9          # per F-IR operator evaluation
    af: float = 1.0             # amortization factor AF_Q
    loop_iters_default: float = 1000.0
    cond_prob_default: float = 0.5
    while_iters_default: float = 8.0  # K for guarded (while) loops


class CostModel:
    def __init__(self, db: DatabaseServer, catalog: CostCatalog,
                 context: Optional[ExecutionContext] = None):
        self.db = db
        self.cat = catalog
        self.context = context if context is not None else ONE_SHOT
        # the program's write set, assigned by run_search before costing:
        # sites over written tables are never served from a shared cache,
        # so no batch/diversity amortization may be claimed for them
        self.write_tables: frozenset = frozenset()

    # ------------------------------------------------------------ batching
    @property
    def batch_size(self) -> float:
        return float(max(1, self.context.batch_size))

    def amortize(self, cost: float) -> float:
        """Per-invocation share of a cost paid once per batch."""
        return cost / self.batch_size

    def tables_shareable(self, tables) -> bool:
        """False when ``tables`` intersects the program's write set: the
        runtime refetches such sites every invocation (each must observe
        earlier writes), so no cache amortization may be priced in."""
        return not (self.write_tables and self.write_tables & set(tables))

    def source_amortizable(self, source: FExpr) -> bool:
        """Can this fold source's server fetch be shared across a batch?
        Only binding-free query sites over tables the program never
        writes: identical every invocation, so the batch env's site cache
        serves all but the first from local state."""
        return (isinstance(source, FQueryE)
                and not query_has_params(source.query)
                and self.tables_shareable(scan_tables(source.query)))

    def param_site_amortization(self, q: Query) -> float:
        """Per-invocation fraction of a PARAMETERIZED query site's fetch
        cost under batching. When the context's stats carry an observed
        distinct-binding fraction d for the site's table group (published
        by the serving site cache through the feedback controller), only
        the distinct bindings in a batch pay a server fetch — the repeats
        are local cache hits — so the per-invocation share is
        ``max(d, 1/B)``. With no group-level observation, the site's
        PROVENANCE key (``qprov:`` — table set + the columns the site
        compares against parameters, an identity that survives rewrites
        renaming the parameters themselves) is consulted instead, so a
        context built with per-site fractions prices two
        differently-diverse sites over the same table separately. Without
        either observation: 1.0 (no sharing assumed, today's conservative
        behavior). Sites over tables the program WRITES never amortize —
        the runtime refetches such sites every invocation regardless of
        what diversity another (read-only) program published for the same
        table group."""
        if self.batch_size <= 1:
            return 1.0
        tables = scan_tables(q)
        if self.write_tables and self.write_tables & set(tables):
            return 1.0
        d = self.context.stats.binding_for(param_group_key(tables))
        if d is None:
            d = self.context.stats.binding_for(
                param_prov_key(tables, query_param_cols(q)))
        if d is None:
            return 1.0
        return min(1.0, max(float(d), 1.0 / self.batch_size))

    def fold_source_amortization(self, source: FExpr) -> float:
        """Binding-diversity amortization factor for a NON-binding-free fold
        source (binding-free sources take the full 1/B path via
        :meth:`source_amortizable`). Covers parameterized query sources and
        the per-key σ lookups T5-style rewrites emit."""
        if isinstance(source, FQueryE):
            return self.param_site_amortization(source.query)
        if isinstance(source, FSelLookupE):
            q = Select(Cmp("==", Col(source.key_col), Param("k")),
                       Scan(source.table))
            return self.param_site_amortization(q)
        return 1.0

    # ----------------------------------------------------- iteration counts
    def while_iters(self, pred) -> float:
        """K for a guarded loop: the observed count for this while site when
        the context carries one, else the catalog default."""
        observed = self.context.stats.iters_for(while_site_key(pred))
        return observed if observed is not None else self.cat.while_iters_default

    # ------------------------------------------------------------- queries
    def query_cost(self, q: Query) -> float:
        est = self.db.estimate(q)
        transfer = est.result_bytes / self.cat.network.bandwidth_bytes_per_s
        return (self.cat.network.c_nrt + est.first_row_s
                + max(transfer, est.last_row_s - est.first_row_s))

    def query_rows(self, q: Query) -> float:
        return self.db.estimate(q).n_rows

    def prefetch_cost(self, q: Query) -> float:
        return self.query_cost(q) / max(self.cat.af, 1e-9)

    def point_query_cost(self, table: str) -> float:
        """One indexed point lookup round trip."""
        m = self.db.model
        st = self.db.stats(table)
        transfer = st.row_bytes / self.cat.network.bandwidth_bytes_per_s
        server = m.startup_s + m.index_lookup_s
        return self.cat.network.c_nrt + server + transfer

    def ndv(self, table: str, col: str) -> float:
        return float(self.db.stats(table).ndv(col))

    def rows_per_key(self, table: str, col: str) -> float:
        """Expected rows served per key of a per-key cache lookup over
        ``table.col``. Histogram-grade when the table's stats carry one:
        the key is bound from the data's own distribution, so the expected
        group size is Σ f_v·(f_v/N) = ``param_eq_fraction() × N`` — far
        above N/NDV under skew, and degenerating to it when uniform.
        Without a histogram: the scalar N/NDV rule."""
        st = self.db.stats(table)
        hist = st.hist(col)
        if hist is not None:
            return hist.param_eq_fraction() * st.nrows
        return st.nrows / max(self.ndv(table, col), 1.0)

    # ---------------------------------------------------------------- fold
    def fold_source(self, fold: FFoldE) -> Tuple[float, float]:
        """(C_Db(Q), N_Q) for the fold's source."""
        src = fold.source
        if isinstance(src, FQueryE):
            return self.query_cost(src.query), self.query_rows(src.query)
        if isinstance(src, FSelLookupE):
            q = Select(Cmp("==", Col(src.key_col), Param("k")), Scan(src.table))
            return self.query_cost(q), self.db.estimate(q).n_rows
        if isinstance(src, FCacheLookupAllE):
            return self.cat.c_y, self.rows_per_key(src.table, src.key_col)
        raise TypeError(f"fold source {src!r}")

    def slot_row_cost(self, expr: FExpr, n_rows: float) -> float:
        """Per-row cost C_f of one tuple slot's update expression.

        Dependent aggregations were inlined at construction, so each slot is
        self-contained."""
        c = self.cat
        if isinstance(expr, FCondE):
            # ?(pred, g): pred evaluated every row; g on p fraction
            p = c.cond_prob_default
            return (self._ops_cost(expr.pred, n_rows)
                    + p * self.slot_row_cost(expr.then, n_rows) + c.c_y)
        return self._ops_cost(expr, n_rows)

    def _ops_cost(self, e: FExpr, n_rows: float) -> float:
        c = self.cat
        if isinstance(e, FPointLookup):
            # ORM id-cache: distinct keys pay a round trip once; rest are
            # hits. In a batch the id-cache (and the bulk navigation fetch)
            # is shared across invocations, so the round trips amortize.
            ndv = min(n_rows, self.ndv(e.table, e.key_col))
            per_row = (ndv * self.amortize(self.point_query_cost(e.table))
                       + (n_rows - ndv) * c.c_z) / max(n_rows, 1.0)
            return per_row + self._ops_cost(e.keyexpr, n_rows)
        if isinstance(e, FCacheLookupE):
            return c.c_y + self._ops_cost(e.keyexpr, n_rows)
        if isinstance(e, FFoldE):
            # nested fold: per-OUTER-row cost of running the inner loop
            src = e.source
            if isinstance(src, FQueryE):
                inner_q_cost = self.query_cost_correlated(src.query)
                inner_rows = self.query_rows_correlated(src.query)
            elif isinstance(src, FSelLookupE):
                q = Select(Cmp("==", Col(src.key_col), Param("k")), Scan(src.table))
                inner_q_cost = self.query_cost(q)
                inner_rows = self.db.estimate(q).n_rows
            elif isinstance(src, FCacheLookupAllE):
                inner_q_cost = c.c_y
                inner_rows = self.rows_per_key(src.table, src.key_col)
            else:
                inner_q_cost = c.c_y
                inner_rows = self.cat.loop_iters_default
            assert isinstance(e.func, FTupleE)
            per_inner = sum(self.slot_row_cost(i, inner_rows) for i in e.func.items)
            return inner_q_cost + inner_rows * (per_inner + c.c_z)
        if isinstance(e, FQueryE):
            return self.query_cost(e.query)
        base = c.c_y
        for k in fir_children(e):
            base += self._ops_cost(k, n_rows)
        return base

    # correlated query (σ with Param): selectivity from stats
    def query_cost_correlated(self, q: Query) -> float:
        return self.query_cost(q)

    def query_rows_correlated(self, q: Query) -> float:
        return self.db.estimate(q).n_rows

    # --------------------------------------------------------- region costs
    def block_cost(self, stmt) -> float:
        """Imperative statement cost: C_Z + any embedded query costs."""
        from .regions import (CacheByColumn, ILoadAll, INav, IQuery, Prefetch,
                              UpdateRow)
        c = self.cat.c_z
        if isinstance(stmt, Prefetch):
            return self.prefetch_cost(stmt.query)
        if isinstance(stmt, CacheByColumn):
            return c  # hash-index build charged per-row at runtime; est. small
        if isinstance(stmt, UpdateRow):
            return self.cat.network.c_nrt + self.db.model.index_lookup_s
        expr = getattr(stmt, "expr", None)
        if expr is not None:
            c += self._iexpr_cost(expr)
        for attr in ("keyexpr", "valexpr"):
            e2 = getattr(stmt, attr, None)
            if e2 is not None:
                c += self._iexpr_cost(e2)
        return c

    def _iexpr_cost(self, e) -> float:
        from .regions import ICacheLookup, ILoadAll, INav, IQuery
        if isinstance(e, IQuery):
            return self.query_cost(e.query)
        if isinstance(e, ILoadAll):
            return self.query_cost(Scan(e.table))
        if isinstance(e, INav):
            return self.point_query_cost(e.target)
        if isinstance(e, ICacheLookup):
            return self.cat.c_y
        out = 0.0
        for attr in ("left", "right", "base", "keyexpr"):
            k = getattr(e, attr, None)
            if k is not None and hasattr(k, "key"):
                out += self._iexpr_cost(k) if not isinstance(k, str) else 0.0
        for k in getattr(e, "args", ()):
            out += self._iexpr_cost(k)
        return out

    def loop_iters(self, source, var: Optional[str] = None) -> float:
        """K for non-fold loops. Query sources are estimated from table
        statistics; collection sources (worklists, accumulated lists) have
        no statistics, so the context's observed count for this loop site —
        when the feedback loop published one — replaces the catalog
        default."""
        from .regions import ILoadAll, IQuery
        if isinstance(source, IQuery):
            return self.query_rows(source.query)
        if isinstance(source, ILoadAll):
            return float(self.db.stats(source.table).nrows)
        if var is not None:
            observed = self.context.stats.iters_for(loop_site_key(var, source))
            if observed is not None:
                return observed
        return self.cat.loop_iters_default

    def loop_source_cost(self, source) -> float:
        """Cost of evaluating a cursor loop's source once per invocation —
        amortized for binding-free query sources (fetched once per batch),
        and by the observed distinct-binding fraction for parameterized
        query sources whose bindings repeat across the batch."""
        from .regions import ILoadAll, IQuery
        full = self._iexpr_cost(source)
        if isinstance(source, ILoadAll):
            return self.amortize(full) \
                if self.tables_shareable((source.table,)) else full
        if isinstance(source, IQuery):
            if not source.bindings and not query_has_params(source.query) \
                    and self.tables_shareable(scan_tables(source.query)):
                return self.amortize(full)
            return full * self.param_site_amortization(source.query)
        return full
