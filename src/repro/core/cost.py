"""Cobra's cost model (Sec. VI, Fig. 12).

    C_Q        = C_NRT + C_Q^F + max(N_Q · S_row(Q) / BW,  C_Q^L − C_Q^F)
    C_prefetch = C_Q / AF_Q
    C_seq      = Σ children
    C_cond     = p·C_true + (1−p)·C_false + C_p
    C_fold     = N_Q · C_f + C_Db(Q)
    C_loop     = K · C_body          (non-fold loops; K estimated)
    C_block    = Σ C_Z per statement
    other F-IR operators: C_Y each

All database-dependent terms (N_Q, S_row, C_Q^F, C_Q^L) come from
``DatabaseServer.estimate`` — statistics only, never true execution (the
paper consulted the DB optimizer the same way). ORM point lookups are
costed with the Hibernate id-cache modeled: first access per distinct key
is a round trip, the rest are local hits.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from ..relational.algebra import Cmp, Col, Param, Query, Scan, Select
from ..relational.database import DatabaseServer, NetworkProfile
from .fir import (FCacheLookupAllE, FCacheLookupE, FCondE, FExpr, FFoldE,
                  FPointLookup, FQueryE, FSelLookupE, FTupleE, fir_children)

__all__ = ["CostCatalog", "CostModel"]


@dataclasses.dataclass
class CostCatalog:
    """The tunable cost-catalog file of Sec. VIII."""

    network: NetworkProfile
    c_z: float = 30e-9          # per imperative statement (paper: 30 ns)
    c_y: float = 30e-9          # per F-IR operator evaluation
    af: float = 1.0             # amortization factor AF_Q
    loop_iters_default: float = 1000.0
    cond_prob_default: float = 0.5
    while_iters_default: float = 8.0  # K for guarded (while) loops


class CostModel:
    def __init__(self, db: DatabaseServer, catalog: CostCatalog):
        self.db = db
        self.cat = catalog

    # ------------------------------------------------------------- queries
    def query_cost(self, q: Query) -> float:
        est = self.db.estimate(q)
        transfer = est.result_bytes / self.cat.network.bandwidth_bytes_per_s
        return (self.cat.network.c_nrt + est.first_row_s
                + max(transfer, est.last_row_s - est.first_row_s))

    def query_rows(self, q: Query) -> float:
        return self.db.estimate(q).n_rows

    def prefetch_cost(self, q: Query) -> float:
        return self.query_cost(q) / max(self.cat.af, 1e-9)

    def point_query_cost(self, table: str) -> float:
        """One indexed point lookup round trip."""
        m = self.db.model
        st = self.db.stats(table)
        transfer = st.row_bytes / self.cat.network.bandwidth_bytes_per_s
        server = m.startup_s + m.index_lookup_s
        return self.cat.network.c_nrt + server + transfer

    def ndv(self, table: str, col: str) -> float:
        return float(self.db.stats(table).ndv(col))

    # ---------------------------------------------------------------- fold
    def fold_source(self, fold: FFoldE) -> Tuple[float, float]:
        """(C_Db(Q), N_Q) for the fold's source."""
        src = fold.source
        if isinstance(src, FQueryE):
            return self.query_cost(src.query), self.query_rows(src.query)
        if isinstance(src, FSelLookupE):
            q = Select(Cmp("==", Col(src.key_col), Param("k")), Scan(src.table))
            return self.query_cost(q), self.db.estimate(q).n_rows
        if isinstance(src, FCacheLookupAllE):
            total = self.db.stats(src.table).nrows
            rows = total / max(self.ndv(src.table, src.key_col), 1.0)
            return self.cat.c_y, rows
        raise TypeError(f"fold source {src!r}")

    def slot_row_cost(self, expr: FExpr, n_rows: float) -> float:
        """Per-row cost C_f of one tuple slot's update expression.

        Dependent aggregations were inlined at construction, so each slot is
        self-contained."""
        c = self.cat
        if isinstance(expr, FCondE):
            # ?(pred, g): pred evaluated every row; g on p fraction
            p = c.cond_prob_default
            return (self._ops_cost(expr.pred, n_rows)
                    + p * self.slot_row_cost(expr.then, n_rows) + c.c_y)
        return self._ops_cost(expr, n_rows)

    def _ops_cost(self, e: FExpr, n_rows: float) -> float:
        c = self.cat
        if isinstance(e, FPointLookup):
            # ORM id-cache: distinct keys pay a round trip once; rest are hits
            ndv = min(n_rows, self.ndv(e.table, e.key_col))
            per_row = (ndv * self.point_query_cost(e.table)
                       + (n_rows - ndv) * c.c_z) / max(n_rows, 1.0)
            return per_row + self._ops_cost(e.keyexpr, n_rows)
        if isinstance(e, FCacheLookupE):
            return c.c_y + self._ops_cost(e.keyexpr, n_rows)
        if isinstance(e, FFoldE):
            # nested fold: per-OUTER-row cost of running the inner loop
            src = e.source
            if isinstance(src, FQueryE):
                inner_q_cost = self.query_cost_correlated(src.query)
                inner_rows = self.query_rows_correlated(src.query)
            elif isinstance(src, FSelLookupE):
                q = Select(Cmp("==", Col(src.key_col), Param("k")), Scan(src.table))
                inner_q_cost = self.query_cost(q)
                inner_rows = self.db.estimate(q).n_rows
            elif isinstance(src, FCacheLookupAllE):
                inner_q_cost = c.c_y
                total = self.db.stats(src.table).nrows
                inner_rows = total / max(self.ndv(src.table, src.key_col), 1.0)
            else:
                inner_q_cost = c.c_y
                inner_rows = self.cat.loop_iters_default
            assert isinstance(e.func, FTupleE)
            per_inner = sum(self.slot_row_cost(i, inner_rows) for i in e.func.items)
            return inner_q_cost + inner_rows * (per_inner + c.c_z)
        if isinstance(e, FQueryE):
            return self.query_cost(e.query)
        base = c.c_y
        for k in fir_children(e):
            base += self._ops_cost(k, n_rows)
        return base

    # correlated query (σ with Param): selectivity from stats
    def query_cost_correlated(self, q: Query) -> float:
        return self.query_cost(q)

    def query_rows_correlated(self, q: Query) -> float:
        return self.db.estimate(q).n_rows

    # --------------------------------------------------------- region costs
    def block_cost(self, stmt) -> float:
        """Imperative statement cost: C_Z + any embedded query costs."""
        from .regions import (CacheByColumn, ILoadAll, INav, IQuery, Prefetch,
                              UpdateRow)
        c = self.cat.c_z
        if isinstance(stmt, Prefetch):
            return self.prefetch_cost(stmt.query)
        if isinstance(stmt, CacheByColumn):
            return c  # hash-index build charged per-row at runtime; est. small
        if isinstance(stmt, UpdateRow):
            return self.cat.network.c_nrt + self.db.model.index_lookup_s
        expr = getattr(stmt, "expr", None)
        if expr is not None:
            c += self._iexpr_cost(expr)
        for attr in ("keyexpr", "valexpr"):
            e2 = getattr(stmt, attr, None)
            if e2 is not None:
                c += self._iexpr_cost(e2)
        return c

    def _iexpr_cost(self, e) -> float:
        from .regions import ICacheLookup, ILoadAll, INav, IQuery
        if isinstance(e, IQuery):
            return self.query_cost(e.query)
        if isinstance(e, ILoadAll):
            return self.query_cost(Scan(e.table))
        if isinstance(e, INav):
            return self.point_query_cost(e.target)
        if isinstance(e, ICacheLookup):
            return self.cat.c_y
        out = 0.0
        for attr in ("left", "right", "base", "keyexpr"):
            k = getattr(e, attr, None)
            if k is not None and hasattr(k, "key"):
                out += self._iexpr_cost(k) if not isinstance(k, str) else 0.0
        for k in getattr(e, "args", ()):
            out += self._iexpr_cost(k)
        return out

    def loop_iters(self, source) -> float:
        """K for non-fold loops."""
        from .regions import ILoadAll, IQuery
        if isinstance(source, IQuery):
            return self.query_rows(source.query)
        if isinstance(source, ILoadAll):
            return float(self.db.stats(source.table).nrows)
        return self.cat.loop_iters_default
