"""Volcano/Cascades search over the Region DAG + code generation.

Cost of an OR-node = min over members; cost of an AND-node = operator cost +
children (Sec. III-A). Two Cobra-specific extensions:

  * **shared resources** — a fold (its source query + loop shell) chosen by
    several ``slot-project`` alternatives, and a prefetched cache used by
    several loops, are counted ONCE per plan. Plans carry a resource set;
    combination points (seq, assemble) merge resource sets by key. This is
    the DAG-costing idea Cobra inherits from the PyroJ/MQO optimizer [14].
  * **top-K plan lists per group** — local minima are not globally optimal
    under sharing, so each group exposes its K best plans and combination
    points enumerate the cross product (bounded); exact at our program sizes.

``optimize`` = build memo → saturate rules → search → generate the program.
``heuristic_choice`` reproduces the [4]-style comparator: push as much into
SQL as possible, never prefetch (Fig. 15's "Heuristic" bars).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

from ..relational.algebra import Query, Scan, scan_tables
from .cost import CostCatalog, CostModel, query_has_params
from .dag import AndNode, Budget, Memo, expand, expand_exhaustive
from .fir import FExpr, FPrefetchE, NameGen, fold_to_loop
from .regions import (Assign, BasicBlock, CondRegion, IBin, IQuery,
                      IQueryValues, IScalarQuery, IVar, LoopRegion, Program,
                      Region, SeqRegion, WhileRegion)
from .rules import RuleContext, _get_parts, build_memo, default_rules

__all__ = ["optimize", "run_search", "OptimizationResult", "Plan",
           "best_plans", "plan_cost"]

_TOPK = 4
_MAX_COMBOS = 4096


@dataclasses.dataclass(frozen=True)
class Plan:
    and_id: int
    op: str
    payload: object
    children: Tuple["Plan", ...]
    base: float                          # own cost excluding shared resources
    resources: Tuple[Tuple[object, float], ...]  # (key, cost), deduped by key

    @property
    def total(self) -> float:
        return self.base + sum(c for _, c in self.resources)


def _merge_resources(*resource_sets) -> Tuple[Tuple[object, float], ...]:
    seen: Dict[object, float] = {}
    for rs in resource_sets:
        for k, c in rs:
            seen.setdefault(k, c)
    return tuple(sorted(seen.items(), key=lambda kv: repr(kv[0])))


def _combine(children_lists: Sequence[List[Plan]],
             max_combos: int = _MAX_COMBOS) -> List[Tuple[Plan, ...]]:
    combos = 1
    for cl in children_lists:
        combos *= max(1, len(cl))
    if combos > max_combos:
        # greedy: take each child's best only
        return [tuple(cl[0] for cl in children_lists)]
    return list(itertools.product(*children_lists))


class Searcher:
    def __init__(self, memo: Memo, cm: CostModel, ctx: RuleContext,
                 choice: str = "cost", topk: int = _TOPK,
                 max_combos: int = _MAX_COMBOS):
        self.memo = memo
        self.cm = cm
        self.ctx = ctx
        self.choice = choice  # "cost" | "heuristic"
        self.topk = topk
        self.max_combos = max_combos
        self._cache: Dict[int, List[Plan]] = {}
        self._in_progress: set = set()

    # ------------------------------------------------------------- search
    def group_plans(self, g: int) -> List[Plan]:
        g = self.memo.find(g)
        if g in self._cache:
            return self._cache[g]
        if g in self._in_progress:
            return []  # cycle through merged groups: prune
        self._in_progress.add(g)
        plans: List[Plan] = []
        for a in self.memo.members(g):
            plans.extend(self.and_plans(a))
        self._in_progress.discard(g)
        plans = self._rank(plans)[:self.topk]
        self._cache[g] = plans
        return plans

    def _rank(self, plans: List[Plan]) -> List[Plan]:
        if self.choice == "heuristic":
            return sorted(plans, key=lambda p: (-_sql_push_score(p), p.total))
        return sorted(plans, key=lambda p: p.total)

    def and_plans(self, a: int) -> List[Plan]:
        node = self.memo.node(a)
        kids = [self.group_plans(c) for c in self.memo.canonical_children(a)]
        if any(len(k) == 0 for k in kids):
            return []
        out: List[Plan] = []
        for combo in _combine(kids, self.max_combos):
            base, res = self._compose(node, combo)
            out.append(Plan(a, node.op, node.payload, combo, base, res))
        return out

    # ------------------------------------------------------------ costing
    def _amortized_once(self, key) -> bool:
        """True when a body resource is fetched once per BATCH rather than
        once per loop iteration: its site is binding-free (flagged at
        creation) and the context batches invocations, so the shared
        site cache serves every re-execution after the first."""
        return self.cm.batch_size > 1 and key[-1] is True

    def _compose(self, node: AndNode, children: Tuple[Plan, ...]
                 ) -> Tuple[float, Tuple[Tuple[object, float], ...]]:
        """Full cost composition for one AND-node given chosen child plans.

        Resource kinds: ("fold", ·, amortizable) = per-execution loop shell
        (source query + header), multiplied when nested under an imperative
        loop; ("prefetch", ·, amortizable) = one-time hoistable cache fill —
        NEVER multiplied (the [13] heuristic hoists it to the earliest
        program point). The trailing flag marks binding-free server fetches,
        whose cost is stored already amortized by the context's batch size
        (one fetch per batch, shared via the batch env's site cache)."""
        cm = self.cm
        cat = cm.cat
        if node.op == "block":
            stmt = node.payload
            from .regions import Prefetch
            if isinstance(stmt, Prefetch):
                amortizable = (not query_has_params(stmt.query)
                               and cm.tables_shareable(
                                   scan_tables(stmt.query)))
                key = ("prefetch", _query_table(stmt.query), stmt.col,
                       amortizable)
                cost = cm.prefetch_cost(stmt.query)
                cost = cm.amortize(cost) if amortizable else \
                    cost * cm.param_site_amortization(stmt.query)
                return 0.0, ((key, cost),)
            return cm.block_cost(stmt), ()
        if node.op == "seq":
            base = sum(p.base for p in children)
            return base, _merge_resources(*[p.resources for p in children])
        if node.op == "cond":
            p = cat.cond_prob_default
            if len(children) == 1:
                base = cat.c_z + p * children[0].base
            else:
                base = cat.c_z + p * children[0].base + (1 - p) * children[1].base
            return base, _merge_resources(*[c.resources for c in children])
        if node.op == "loop":
            var, source = node.payload
            k = cm.loop_iters(source, var)
            body = children[0]
            # binding-free fold sources under a batched context are fetched
            # once per batch (site cache), not once per iteration
            per_iter = sum(c for key, c in body.resources
                           if key[0] == "fold" and not self._amortized_once(key))
            once = sum(c for key, c in body.resources
                       if key[0] == "fold" and self._amortized_once(key))
            prefetch_res = tuple((key, c) for key, c in body.resources
                                 if key[0] != "fold")
            base = (k * (body.base + per_iter + cat.c_z) + once
                    + cm.loop_source_cost(source))
            return base, prefetch_res
        if node.op == "while":
            # guarded loop: iteration count is data dependent, so charge the
            # context's observed count for this site (catalog default when
            # none). EVERY body resource is multiplied (a prefetch inside a
            # while body re-executes each iteration and is never hoisted
            # across the guard) — EXCEPT binding-free fetches under a
            # batched context, which the shared site cache turns into one
            # fetch per batch. Nothing escapes upward as a shared resource —
            # conservative by construction.
            k = cm.while_iters(node.payload)
            body = children[0]
            per_iter = sum(c for key, c in body.resources
                           if not self._amortized_once(key))
            once = sum(c for key, c in body.resources
                       if self._amortized_once(key))
            base = k * (body.base + per_iter + cat.c_z) + cat.c_z + once
            return base, ()
        if node.op == "assemble":
            base = sum(p.base for p in children)
            return base, _merge_resources(*[p.resources for p in children])
        if node.op == "slot-project":
            _, var, i, payload = node.payload
            pre, fold = _get_parts(payload)
            src_cost, n = cm.fold_source(fold)
            slot = cm.slot_row_cost(fold.func.items[i], n)
            res: List[Tuple[object, float]] = []
            if cm.source_amortizable(fold.source):
                # only the server fetch is shared across a batch; the local
                # loop shell (n · C_Z) runs every execution — under a
                # while/loop it must still multiply by K, so it rides as a
                # separate never-amortized fold resource (same dedup)
                res.append((("fold", fold.key(), True), cm.amortize(src_cost)))
                res.append((("fold", fold.key(), "shell", False),
                            n * cat.c_z))
            else:
                # parameterized source: the serving site cache still serves
                # repeated bindings, so the fetch amortizes by the OBSERVED
                # distinct-binding fraction (1.0 when never observed)
                f = cm.fold_source_amortization(fold.source)
                res.append((("fold", fold.key(), False),
                            src_cost * f + n * cat.c_z))
            for p in pre:
                if isinstance(p, FPrefetchE):
                    p_am = (not query_has_params(p.query)
                            and cm.tables_shareable(scan_tables(p.query)))
                    p_cost = cm.prefetch_cost(p.query)
                    res.append((("prefetch", _query_table(p.query), p.col,
                                 p_am),
                                cm.amortize(p_cost) if p_am else
                                p_cost * cm.param_site_amortization(p.query)))
            return n * slot, tuple(res)
        if node.op == "slot-query":
            _, var, q, op, col, binding = node.payload
            qc = cm.query_cost(q)
            if binding is None and not query_has_params(q) \
                    and cm.tables_shareable(scan_tables(q)):
                qc = cm.amortize(qc)
            else:
                qc = qc * cm.param_site_amortization(q)
            return qc + cat.c_z, ()
        if node.op == "slot-query-rows":
            _, var, q, col = node.payload
            qc = cm.query_cost(q)
            if not query_has_params(q) \
                    and cm.tables_shareable(scan_tables(q)):
                qc = cm.amortize(qc)
            else:
                qc = qc * cm.param_site_amortization(q)
            return qc + cat.c_z, ()
        raise TypeError(f"unknown op {node.op}")


def _query_table(q: Query) -> str:
    while True:
        kids = q.children()
        if isinstance(q, Scan):
            return q.table
        if not kids:
            return q.sql()
        q = kids[0]


def _sql_push_score(p: Plan) -> int:
    """Heuristic comparator [4]: more computation pushed into SQL = better;
    prefetching is never chosen (it was proposed for other goals [13])."""
    score = 0
    if p.op == "slot-query-rows":
        score += 100
    if p.op == "slot-query":
        score += 80
    if p.op == "slot-project":
        _, _, _, payload = p.payload
        pre, fold = _get_parts(payload)
        if pre:  # prefetch-based plan: heuristic refuses
            score -= 1000
        from .fir import FSelLookupE, fir_contains, FCacheLookupAllE, FCacheLookupE

        def has(t):
            return fir_contains(fold, lambda x: isinstance(x, t))

        if has(FSelLookupE):
            score += 40  # σ pushed to the database
        if has(FCacheLookupAllE) or has(FCacheLookupE):
            score -= 1000
    if p.op == "assemble":
        score += 1  # prefer F-IR over raw imperative loop
    for c in p.children:
        score += _sql_push_score(c)
    return score


# --------------------------------------------------------------------------
# Code generation from a chosen plan
# --------------------------------------------------------------------------

def plan_to_region(plan: Plan, emitted_prefetch: Optional[set] = None,
                   names: Optional[NameGen] = None) -> Region:
    if emitted_prefetch is None:
        emitted_prefetch = set()
    if names is None:
        # one alpha-normalized name source per codegen run: identical plans
        # emit byte-identical IR (see fir.NameGen)
        names = NameGen()
    if plan.op == "block":
        return BasicBlock(plan.payload)
    if plan.op == "seq":
        return SeqRegion(tuple(plan_to_region(c, emitted_prefetch, names)
                               for c in plan.children))
    if plan.op == "cond":
        pred = plan.payload
        then = plan_to_region(plan.children[0], emitted_prefetch, names)
        els = plan_to_region(plan.children[1], emitted_prefetch, names) \
            if len(plan.children) > 1 else None
        return CondRegion(pred, then, els)
    if plan.op == "loop":
        var, source = plan.payload
        return LoopRegion(var, source, plan_to_region(plan.children[0],
                                                      emitted_prefetch, names))
    if plan.op == "while":
        # a prefetch chosen inside the body must also be emitted there (the
        # guard may skip every iteration), so the body codegens with a FRESH
        # dedup set — nothing is considered already-emitted across the guard
        body = plan_to_region(plan.children[0], set(), names)
        return WhileRegion(plan.payload, body)
    if plan.op == "assemble":
        return _assemble_to_region(plan, emitted_prefetch, names)
    raise TypeError(f"cannot codegen {plan.op}")


def _assemble_to_region(plan: Plan, emitted_prefetch: set,
                        names: NameGen) -> Region:
    from .regions import Prefetch

    parts: List[Region] = []
    # group slot-projects by their payload expression (one loop per fold)
    fold_slots: Dict[object, Tuple[FExpr, List[int]]] = {}
    queries: List[Tuple[str, object]] = []
    for c in plan.children:
        if c.op == "slot-project":
            _, var, i, payload = c.payload
            k = payload.key()
            fold_slots.setdefault(k, (payload, []))[1].append(i)
        elif c.op == "slot-query":
            _, var, q, op, col, binding = c.payload
            queries.append((var, ("agg", q, op, col, binding)))
        elif c.op == "slot-query-rows":
            _, var, q, col = c.payload
            queries.append((var, ("rows", q, col)))
        else:
            raise TypeError(c.op)

    # which vars end up covered by a loop (incl. dependency closure)?
    covered: set = set()
    loops: List[Region] = []
    for payload, slots in fold_slots.values():
        pre, fold = _get_parts(payload)
        for p in pre:
            if isinstance(p, FPrefetchE):
                key = (_query_table(p.query), p.col)
                if key not in emitted_prefetch:
                    emitted_prefetch.add(key)
                    parts.append(BasicBlock(Prefetch(p.query, p.col)))
        region = fold_to_loop(fold, slots=slots, names=names)
        loops.append(region)
        covered.update(_loop_assigned_vars(region))

    for var, spec in queries:
        if var in covered:
            continue  # dependency closure already computes it in a loop
        if spec[0] == "agg":
            _, q, op, col, binding = spec
            bindings = ()
            if binding is not None:
                from .fir import _val_to_iexpr
                bindings = (("k", _val_to_iexpr(binding, {}, [], names)),)
            parts.append(BasicBlock(Assign(
                var, IBin(op, IVar(var), IScalarQuery(q, col, bindings)))))
        else:
            _, q, col = spec
            if col is None:
                parts.append(BasicBlock(Assign(var, IQuery(q))))
            else:
                parts.append(BasicBlock(Assign(var, IQueryValues(q, col))))
    parts.extend(loops)
    return SeqRegion(tuple(parts)) if len(parts) != 1 else parts[0]


def _loop_assigned_vars(r: Region) -> set:
    out = set()

    def walk(x: Region):
        if isinstance(x, BasicBlock):
            out.update(x.stmt.defs())
        for c in x.children():
            walk(c)

    walk(r)
    return {v for v in out if not v.startswith("__")}


# --------------------------------------------------------------------------
# Prefetch hoisting ("prefetch at the earliest program point", [13])
# --------------------------------------------------------------------------

def hoist_prefetches(region: Region) -> Region:
    """Move whole-relation Prefetch statements to the program start, deduped.
    Tables that the program updates are NOT hoisted (stale-cache safety,
    Sec. VIII 'threats to validity')."""
    from .regions import NoOp, Prefetch, UpdateRow

    updated: set = set()

    def find_updates(r: Region):
        if isinstance(r, BasicBlock) and isinstance(r.stmt, UpdateRow):
            updated.add(r.stmt.table)
        for c in r.children():
            find_updates(c)

    find_updates(region)
    hoisted: List = []
    seen: set = set()

    def strip(r: Region) -> Optional[Region]:
        if isinstance(r, BasicBlock):
            if isinstance(r.stmt, Prefetch):
                tbl = _query_table(r.stmt.query)
                if tbl not in updated:
                    key = (tbl, r.stmt.col)
                    if key not in seen:
                        seen.add(key)
                        hoisted.append(r)
                    return None
            return r
        if isinstance(r, SeqRegion):
            parts = tuple(p for p in (strip(x) for x in r.parts) if p is not None)
            if not parts:
                return None
            return SeqRegion(parts) if len(parts) > 1 else parts[0]
        if isinstance(r, LoopRegion):
            body = strip(r.body)
            if body is None:
                body = BasicBlock(NoOp("hoisted"))
            return LoopRegion(r.var, r.source, body, r.label)
        if isinstance(r, (CondRegion, WhileRegion)):
            # prefetch under a condition/guard is not unconditionally
            # hoistable (the branch or while body may never execute)
            return r
        return r

    core = strip(region)
    if not hoisted:
        return region
    parts = tuple(hoisted) + ((core,) if core is not None else ())
    return SeqRegion(parts) if len(parts) > 1 else parts[0]


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------

@dataclasses.dataclass
class OptimizationResult:
    program: Program
    plan: Plan
    est_cost: float
    memo_stats: Dict[str, int]
    opt_time_s: float
    alternatives: int
    # per-phase optimizer wall time (build/saturate/search/codegen) and
    # rewrite provenance: total alternatives per rule across the whole memo,
    # plus the ordered rule chain that derived the WINNING plan's nodes.
    # Defaults keep plans pickled by older PlanStores loadable.
    phase_times: Dict[str, float] = dataclasses.field(default_factory=dict)
    rule_hits: Dict[str, int] = dataclasses.field(default_factory=dict)
    rules_fired: Tuple[str, ...] = ()
    # saturation budget outcome: True when a node/wall budget tripped and
    # the plan came from the greedy best-first fallback over a partial memo
    budget_exhausted: bool = False
    # per-phase per-rule saturation accounting:
    # phase -> rule -> {"matched", "fired", "missed"}
    rule_stats: Dict[str, Dict[str, Dict[str, int]]] = \
        dataclasses.field(default_factory=dict)


def _plan_rules(plan: Plan, memo: Memo) -> Tuple[str, ...]:
    """The rules that derived the winning plan's AND-nodes, ancestors first
    (via the provenance chain), deduped preserving order."""
    out: List[str] = []
    seen_rules = set()

    def chase(and_id: int) -> None:
        seen_ids = set()
        chain: List[str] = []
        a = and_id
        while a in memo.provenance and a not in seen_ids:
            seen_ids.add(a)
            rule, src = memo.provenance[a]
            chain.append(rule)
            a = src
        for rule in reversed(chain):  # ancestors (earliest rewrites) first
            if rule not in seen_rules:
                seen_rules.add(rule)
                out.append(rule)

    def walk(p: Plan) -> None:
        chase(p.and_id)
        for c in p.children:
            walk(c)

    walk(plan)
    return tuple(out)


def run_search(program: Program, db, catalog: CostCatalog, *,
               choice: str = "cost", rules: Optional[Sequence] = None,
               topk: int = _TOPK, max_combos: int = _MAX_COMBOS,
               max_rounds: int = 64, context=None,
               cost_model=None, tracer=None,
               budget: Optional[Budget] = None, memo_pool=None,
               exhaustive: bool = False) -> OptimizationResult:
    """One full memo pass: build → saturate rules → search → codegen.

    ``context`` is an :class:`~repro.core.context.ExecutionContext` (batch
    size + observed iteration stats) the plan is costed for; ``cost_model``
    is a pluggable :class:`~repro.core.cost.CostModel`-protocol class,
    constructed as ``cost_model(db, catalog, context)``. ``tracer`` (an
    :class:`repro.obs.trace.Tracer`) records one span per phase and per
    saturation round.

    ``budget`` (a :class:`~repro.core.dag.Budget`) bounds saturation: when
    it trips, the search degrades to GREEDY best-first (top-1 per group,
    best-child-only combination) over the partial memo and the result
    reports ``budget_exhausted`` — never an error. ``memo_pool`` (a
    :class:`~repro.core.memopool.MemoPool`) replays saturated groups
    shared with earlier compiles and harvests new ones. ``exhaustive``
    switches to the reference rescan-everything saturation loop
    (:func:`~repro.core.dag.expand_exhaustive`) — used by the parity tests
    and ``make bench-compile``; the winning plan must be identical.

    This is the uncached engine; callers wanting compile-once/execute-many
    semantics should go through ``repro.api.CobraSession``, which fronts
    this with a stats-versioned plan cache."""
    import contextlib

    def _span(name):
        if tracer is not None and tracer.enabled:
            return tracer.span(name)
        return contextlib.nullcontext()

    phases: Dict[str, float] = {}
    t0 = time.perf_counter()
    ctx = RuleContext(db=db)
    with _span("build-memo"):
        memo, root = build_memo(program, ctx)
    t1 = time.perf_counter()
    phases["build_memo"] = t1 - t0
    rule_list = list(rules) if rules is not None else default_rules()
    prefired: set = set()
    replayed = 0
    if memo_pool is not None and not exhaustive:
        with _span("memo-pool-seed"):
            replayed, prefired = memo_pool.seed(memo, ctx, rule_list)
    with _span("saturate"):
        if exhaustive:
            stats = expand_exhaustive(memo, rule_list, ctx,
                                      max_rounds=max_rounds, tracer=tracer)
        else:
            stats = expand(memo, rule_list, ctx, max_rounds=max_rounds,
                           tracer=tracer, budget=budget, prefired=prefired)
    exhausted = bool(stats.get("budget_exhausted"))
    if memo_pool is not None and not exhaustive and not exhausted:
        # a partial (budgeted) memo must never be harvested — later
        # compiles would replay it as if saturated
        memo_pool.harvest(memo, ctx, rule_list, prefired)
    if replayed:
        # pooled alternatives are part of the searched space: report them
        # like a cold compile would so plan reports stay comparable
        stats["alternatives_added"] = \
            stats.get("alternatives_added", 0) + replayed
        stats["pool_replayed"] = replayed
    t2 = time.perf_counter()
    phases["saturate"] = t2 - t1
    cm = (cost_model or CostModel)(db, catalog, context)
    # sites over tables the program writes are refetched every invocation
    # (the serving cache refuses them), so the model must not amortize them
    from .regions import write_tables
    cm.write_tables = frozenset(write_tables(program))
    if exhausted:
        # greedy best-first fallback: keep only the best plan per group and
        # never enumerate combination cross-products
        topk, max_combos = 1, 1
    searcher = Searcher(memo, cm, ctx, choice=choice, topk=topk,
                        max_combos=max_combos)
    with _span("search"):
        plans = searcher.group_plans(root)
    t3 = time.perf_counter()
    phases["search"] = t3 - t2
    if not plans:
        raise RuntimeError("no plan found")
    best = plans[0]
    with _span("codegen"):
        region = hoist_prefetches(plan_to_region(best))
    out = Program(f"{program.name}_{choice}", region, program.outputs,
                  program.inputs)
    t4 = time.perf_counter()
    phases["codegen"] = t4 - t3
    dt = t4 - t0
    return OptimizationResult(out, best, best.total, stats, dt,
                              stats.get("alternatives_added", 0),
                              phase_times=phases,
                              rule_hits=dict(memo.rule_hits),
                              rules_fired=_plan_rules(best, memo),
                              budget_exhausted=exhausted,
                              rule_stats={p: {r: dict(c) for r, c in rs.items()}
                                          for p, rs in memo.rule_stats.items()})


def optimize(program: Program, db, catalog: CostCatalog,
             choice: str = "cost", rules: Optional[Sequence] = None
             ) -> OptimizationResult:
    """Back-compat shim over :class:`repro.api.CobraSession`.

    rules=None uses the full Fig. 11 rule set; pass a restricted list
    (e.g. without T3) to reproduce the paper's Experiment-1/2/3 alternative
    space {P0, P1, P2} exactly. New code should hold a session and use
    ``session.compile(program)`` so repeated optimizations hit the plan
    cache instead of re-running memo expansion."""
    from ..api import CobraSession, OptimizerConfig
    session = CobraSession(db, catalog, config=OptimizerConfig(choice=choice))
    return session.compile(program, rules=rules).result
