"""Program transformation rules (Fig. 11) over the Region DAG.

Memo layout produced by ``build_memo`` + the F-IR conversion rule:

  loop group ──┬── AND("loop", [body])            (original imperative loop)
               └── AND("assemble", [g_v1 .. g_vk]) (F-IR form, Fig. 10)
  g_vi        ──┬── AND("slot-project", payload=(var, i, fold-or-seq expr))
               ├── AND("slot-query",       ...)    from T5  (γ aggregate)
               └── AND("slot-query-rows",  ...)    from T1/T4 (collection query)

Fold-rewriting rules (T2/N2 correlated+plain, N1, N1a) fire on
``slot-project`` nodes and add new ``slot-project`` alternatives whose
payload embeds the rewritten fold (possibly wrapped in seq(prefetch, ...)).
Slot-extraction rules (T1, T4, T5) fire on ``slot-project`` nodes and add
``slot-query[-rows]`` alternatives. Duplicate detection in the memo makes
the cyclic pairs (T2 ↔ N2) terminate.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from ..relational.algebra import (AggSpec, Aggregate, Arith, Cmp, Col, Func,
                                  Join, Lit, Param, Project, Query, Scalar,
                                  Scan, Select)
from .dag import AndNode, Memo, Rule
from .fir import (FAcc, FBin, FCacheLookupAllE, FCacheLookupE, FCall, FCondE,
                  FConst, FExpr, FField, FFoldE, FInsert, FPointLookup,
                  FProjectE, FQueryE, FRow, FSelLookupE, FSeqE, FTupleE,
                  FVarRef, FIRConversionError, FPrefetchE, fir_children,
                  fir_contains, fir_map, loop_to_fir)
from .regions import (Assign, BasicBlock, CondRegion, IConst, IEmptyList,
                      IEmptyMap, LoopRegion, Program, Region, SeqRegion,
                      WhileRegion)

__all__ = ["RuleContext", "build_memo", "default_rules"]

_AGG_OF_OP = {"+": "sum", "min": "min", "max": "max"}


@dataclasses.dataclass
class RuleContext:
    db: object                      # DatabaseServer (for schemas/stats)
    loop_regions: Dict[int, LoopRegion] = dataclasses.field(default_factory=dict)
    empty_vars: Dict[Tuple, frozenset] = dataclasses.field(default_factory=dict)
    # loop AND-id -> vars known empty/zero at loop entry
    empty_at_loop: Dict[int, frozenset] = dataclasses.field(default_factory=dict)


# --------------------------------------------------------------------------
# Memo construction (Step 1+2 of Sec. IV-B: region tree → initial Region DAG)
# --------------------------------------------------------------------------

def build_memo(program: Program, ctx: RuleContext) -> Tuple[Memo, int]:
    memo = Memo()
    root = _insert_region(memo, program.body, ctx, known_empty=frozenset())
    return memo, root


def _insert_region(memo: Memo, r: Region, ctx: RuleContext,
                   known_empty: frozenset) -> int:
    if isinstance(r, BasicBlock):
        g, _ = memo.insert(AndNode("block", (), r.stmt))
        return g
    if isinstance(r, SeqRegion):
        children = []
        empty = set(known_empty)
        for p in r.parts:
            g = _insert_region(memo, p, ctx, frozenset(empty))
            children.append(g)
            _track_empties(p, empty)
        g, _ = memo.insert(AndNode("seq", tuple(children)))
        return g
    if isinstance(r, CondRegion):
        tg = _insert_region(memo, r.then_r, ctx, known_empty)
        kids = (tg,) if r.else_r is None else (
            tg, _insert_region(memo, r.else_r, ctx, known_empty))
        g, _ = memo.insert(AndNode("cond", kids, r.pred))
        return g
    if isinstance(r, LoopRegion):
        bg = _insert_region(memo, r.body, ctx, frozenset())
        g, a = memo.insert(AndNode("loop", (bg,), (r.var, r.source)))
        ctx.loop_regions[a] = r
        ctx.empty_at_loop[a] = known_empty
        return g
    if isinstance(r, WhileRegion):
        # the while itself has no F-IR form (iteration count is data
        # dependent), but its body is inserted like any region: cursor loops
        # nested inside still grow their own alternatives (T1/T3/T5, ...).
        # known_empty resets — the body re-executes, so nothing stays fresh.
        bg = _insert_region(memo, r.body, ctx, frozenset())
        g, _ = memo.insert(AndNode("while", (bg,), r.pred))
        return g
    raise TypeError(f"cannot insert region {r!r}")


def _track_empties(r: Region, empty: set) -> None:
    """Maintain which vars hold a fresh empty collection / zero scalar."""
    if isinstance(r, BasicBlock) and isinstance(r.stmt, Assign):
        e = r.stmt.expr
        if isinstance(e, (IEmptyList, IEmptyMap)) or (
                isinstance(e, IConst) and e.value in (0, 0.0)):
            empty.add(r.stmt.target)
        else:
            empty.discard(r.stmt.target)
    elif isinstance(r, (SeqRegion, CondRegion, LoopRegion, WhileRegion)):
        # conservative: any nested def invalidates
        for p in r.children():
            _track_empties(p, empty)
        if isinstance(r, (LoopRegion, WhileRegion)):
            empty.clear()


# --------------------------------------------------------------------------
# F-IR ⇄ relational scalar translation
# --------------------------------------------------------------------------

class _NotScalar(Exception):
    pass


def _fexpr_to_scalar(e: FExpr, colmap: Dict[Tuple[str, str], str]) -> Scalar:
    """F-IR value expr → relational Scalar over (joined) query columns.

    colmap: (row_name, field) → output column name."""
    if isinstance(e, FConst):
        return Lit(e.value)
    if isinstance(e, FField) and isinstance(e.base, FRow):
        out = colmap.get((e.base.name, e.col))
        if out is None:
            raise _NotScalar(f"unmapped column {e!r}")
        return Col(out)
    if isinstance(e, FBin):
        l = _fexpr_to_scalar(e.left, colmap)
        r = _fexpr_to_scalar(e.right, colmap)
        if e.op in ("+", "-", "*", "/", "min", "max"):
            return Arith(e.op, l, r)
        if e.op in ("==", "!=", "<", "<=", ">", ">="):
            return Cmp(e.op, l, r)
        raise _NotScalar(e.op)
    if isinstance(e, FCall):
        return Func(e.func, tuple(_fexpr_to_scalar(a, colmap) for a in e.args))
    raise _NotScalar(f"not scalar-translatable: {e!r}")


def _row_fields(e: FExpr, row: str) -> List[str]:
    out = []

    def walk(x: FExpr):
        if isinstance(x, FField) and isinstance(x.base, FRow) and x.base.name == row:
            out.append(x.col)
        for k in fir_children(x):
            walk(k)

    walk(e)
    return out


def _only_over_rows(e: FExpr, rows: frozenset) -> bool:
    """True iff e references only given row vars + constants (no accs/lookups)."""
    if isinstance(e, (FAcc, FVarRef, FPointLookup, FSelLookupE, FCacheLookupE,
                      FCacheLookupAllE, FFoldE, FQueryE)):
        return False
    if isinstance(e, FRow):
        return e.name in rows
    return all(_only_over_rows(k, rows) for k in fir_children(e))


def _get_parts(payload: FExpr) -> Tuple[Tuple[FExpr, ...], FFoldE]:
    """(prefetch parts, fold) from a slot payload expr."""
    if isinstance(payload, FSeqE):
        return payload.parts[:-1], payload.parts[-1]  # type: ignore
    return (), payload  # type: ignore


def _mk_payload(prefetches: Sequence[FExpr], fold: FFoldE) -> FExpr:
    if prefetches:
        return FSeqE(tuple(prefetches) + (fold,))
    return fold


# --------------------------------------------------------------------------
# Rule: cursor loop → F-IR (Fig. 9, modeled as a transformation, Sec. V-C)
# --------------------------------------------------------------------------

def rule_fir_convert(memo: Memo, and_id: int, ctx: RuleContext) -> int:
    node = memo.node(and_id)
    loop = ctx.loop_regions.get(and_id)
    if loop is None:
        return 0
    try:
        fold, index = loop_to_fir(loop)
    except FIRConversionError:
        return 0
    group = memo.owner(and_id)
    var_groups = []
    for var, i in sorted(index.items(), key=lambda kv: kv[1]):
        g, _ = memo.insert(AndNode("slot-project", (), ("slot", var, i, fold)))
        var_groups.append(g)
    memo.insert(AndNode("assemble", tuple(var_groups), ("assemble", fold.acc_names)),
                group=group)
    # propagate emptiness info to slot rules via ctx keyed by (fold key, var)
    for var in fold.acc_names:
        if var in ctx.empty_at_loop.get(and_id, frozenset()):
            ctx.empty_vars[(fold.key(), var)] = frozenset([var])
    return 1


# --------------------------------------------------------------------------
# Slot-extraction rules: T1, T5, T4
# --------------------------------------------------------------------------

def _slot(memo: Memo, and_id: int):
    node = memo.node(and_id)
    if node.op != "slot-project":
        return None
    _, var, i, payload = node.payload
    pre, fold = _get_parts(payload)
    return node, var, i, pre, fold


def rule_T1(memo: Memo, and_id: int, ctx: RuleContext) -> int:
    """fold(insert, {}, Q) ≡ Q — the collection is the query result itself."""
    s = _slot(memo, and_id)
    if s is None:
        return 0
    node, var, i, pre, fold = s
    if pre or not isinstance(fold.source, FQueryE):
        return 0
    upd = fold.func.items[i]
    if not (isinstance(upd, FInsert) and isinstance(upd.coll, FAcc)
            and upd.coll.name == var and isinstance(upd.val, FRow)
            and upd.val.name == fold.row_name):
        return 0
    if (fold.key(), var) not in ctx.empty_vars:
        return 0  # init not provably empty
    memo.insert(AndNode("slot-query-rows", (), ("rows", var, fold.source.query, None)),
                group=memo.owner(and_id))
    return 1


def rule_T5(memo: Memo, and_id: int, ctx: RuleContext) -> int:
    """fold(op, id, π_A(Q)) ≡ γ_op_agg(A)(Q) — scalar aggregation extraction.

    Handles the guarded form by first conceptually applying T2 (σ push)."""
    s = _slot(memo, and_id)
    if s is None:
        return 0
    node, var, i, pre, fold = s
    if pre:
        return 0
    binding: Optional[FExpr] = None
    if isinstance(fold.source, FQueryE):
        base_q = fold.source.query
    elif isinstance(fold.source, FSelLookupE):
        src = fold.source
        # correlated aggregate: σ_{A=:k}(R) — the key expr must be evaluable
        # at the region entry (no reference to this fold's row)
        if fir_contains(src.keyexpr, lambda x: isinstance(x, FRow)):
            return 0
        base_q = Select(Cmp("==", Col(src.key_col), Param("k")), Scan(src.table))
        binding = src.keyexpr
    else:
        return 0
    upd = fold.func.items[i]
    if isinstance(upd, FCondE):
        try:
            pred = _fexpr_to_scalar(upd.pred, _self_colmap(upd.pred, fold.row_name))
        except _NotScalar:
            return 0
        if not _only_over_rows(upd.pred, frozenset([fold.row_name])):
            return 0
        base_q = Select(pred, base_q)
        upd = upd.then
    if not (isinstance(upd, FBin) and upd.op in _AGG_OF_OP):
        return 0
    l_acc = isinstance(upd.left, FAcc) and upd.left.name == var
    r_acc = isinstance(upd.right, FAcc) and upd.right.name == var
    if l_acc == r_acc:
        return 0
    h = upd.right if l_acc else upd.left
    if not _only_over_rows(h, frozenset([fold.row_name])):
        return 0
    # build γ query
    if isinstance(h, FConst) and h.value == 1 and upd.op == "+":
        agg_q: Query = Aggregate((), (AggSpec("count", None, "agg_out"),), base_q)
    else:
        fields = _row_fields(h, fold.row_name)
        colmap = {(fold.row_name, c): c for c in fields}
        try:
            hs = _fexpr_to_scalar(h, colmap)
        except _NotScalar:
            return 0
        if isinstance(hs, Col):
            agg_q = Aggregate((), (AggSpec(_AGG_OF_OP[upd.op], hs.name, "agg_out"),),
                              base_q)
        else:
            proj = Project((), base_q, computed=(("h_val", hs),))
            agg_q = Aggregate((), (AggSpec(_AGG_OF_OP[upd.op], "h_val", "agg_out"),),
                              proj)
    memo.insert(AndNode("slot-query", (),
                        ("agg", var, agg_q, upd.op, "agg_out", binding)),
                group=memo.owner(and_id))
    return 1


def _self_colmap(e: FExpr, row: str) -> Dict[Tuple[str, str], str]:
    return {(row, c): c for c in _row_fields(e, row)}


def rule_T4(memo: Memo, and_id: int, ctx: RuleContext) -> int:
    """fold(fold(insert, id, σ_pred(Q2)), {}, Q1) ≡ Q1 ⋈_pred Q2 — nested
    cursor loops become a relational join evaluated at the database."""
    s = _slot(memo, and_id)
    if s is None:
        return 0
    node, var, i, pre, fold = s
    if pre or not isinstance(fold.source, FQueryE):
        return 0
    upd = fold.func.items[i]
    if isinstance(upd, FProjectE):
        upd = upd.base
    if not isinstance(upd, FFoldE) or upd.acc_names != (var,):
        return 0
    inner = upd
    in_upd = inner.func.items[0]
    # inner source must be a correlated σ on the outer row
    if not isinstance(inner.source, FSelLookupE):
        return 0
    keyexpr = inner.source.keyexpr
    if not (isinstance(keyexpr, FField) and isinstance(keyexpr.base, FRow)
            and keyexpr.base.name == fold.row_name):
        return 0
    if not (isinstance(in_upd, FInsert) and isinstance(in_upd.coll, FAcc)
            and in_upd.coll.name == var):
        return 0
    if (fold.key(), var) not in ctx.empty_vars:
        return 0
    val = in_upd.val
    rows = frozenset([fold.row_name, inner.row_name])
    if not _only_over_rows(val, rows):
        return 0
    # join: Q1 ⋈_{B = A} R   (B on outer, A on inner table)
    q1 = fold.source.query
    r_name = inner.source.table
    join = Join(q1, Scan(r_name), keyexpr.col, inner.source.key_col)
    # column mapping after the join (right duplicates get prefixed)
    try:
        left_names = set(q1.output_schema(ctx.db).names)
        right_names = ctx.db.table(r_name).schema.names
    except Exception:
        return 0
    colmap: Dict[Tuple[str, str], str] = {}
    for c in _row_fields(val, fold.row_name):
        colmap[(fold.row_name, c)] = c
    for c in _row_fields(val, inner.row_name):
        colmap[(inner.row_name, c)] = f"{r_name}_{c}" if c in left_names else c
    try:
        vs = _fexpr_to_scalar(val, colmap)
    except _NotScalar:
        return 0
    if isinstance(vs, Col):
        out_q: Query = Project((vs.name,), join)
        out_col = vs.name
    else:
        out_q = Project((), join, computed=(("join_val", vs),))
        out_col = "join_val"
    memo.insert(AndNode("slot-query-rows", (), ("rows", var, out_q, out_col)),
                group=memo.owner(and_id))
    return 1


def rule_point_to_join(memo: Memo, and_id: int, ctx: RuleContext) -> int:
    """SQL translation of iterative point lookups [4]: a fold whose function
    navigates σ1_{R.A = t.B}(R) becomes a fold over Q ⋈_{B=A} R (program P1
    of Fig. 3). The fold's row set is preserved by FK integrity (the lookup
    is an ORM relationship navigation)."""
    s = _slot(memo, and_id)
    if s is None:
        return 0
    node, var, i, pre, fold = s
    if pre or not isinstance(fold.source, FQueryE):
        return 0
    # find point lookups keyed by own-row fields; all uses must be FField
    lookups: Dict[Tuple[str, str, str], FPointLookup] = {}
    bad = []

    def scan(e: FExpr, parent_is_field: bool = False):
        if isinstance(e, FPointLookup):
            k = e.keyexpr
            if (isinstance(k, FField) and isinstance(k.base, FRow)
                    and k.base.name == fold.row_name):
                if not parent_is_field:
                    bad.append(e)
                lookups[(e.table, e.key_col, k.col)] = e
            else:
                bad.append(e)
            return
        for c in fir_children(e):
            scan(c, parent_is_field=isinstance(e, FField))

    scan(fold.func)
    if not lookups or bad:
        return 0
    try:
        left_names = set(fold.source.query.output_schema(ctx.db).names)
    except Exception:
        return 0
    q = fold.source.query
    renames: Dict[Tuple[str, str], str] = {}
    for (table, key_col, bcol) in sorted(lookups):
        rnames = ctx.db.table(table).schema.names
        for c in rnames:
            renames[(table, c)] = f"{table}_{c}" if c in left_names else c
        q = Join(q, Scan(table), bcol, key_col)
        left_names |= {renames[(table, c)] for c in rnames}

    def rewrite(e: FExpr) -> FExpr:
        if isinstance(e, FField) and isinstance(e.base, FPointLookup):
            pl = e.base
            return FField(FRow(fold.row_name), renames[(pl.table, e.col)])
        return e

    new_func = fir_map(fold.func, rewrite)
    new_fold = FFoldE(new_func, fold.init, FQueryE(q), fold.acc_names,
                      fold.row_name)
    return _add_slot_variant(memo, and_id, var, i, new_fold, ctx, fold)


# --------------------------------------------------------------------------
# Fold-rewriting rules: T2/N2 (plain + correlated), N1, N1a
# --------------------------------------------------------------------------

def _add_slot_variant(memo: Memo, and_id: int, var: str, i: int,
                      payload: FExpr, ctx: RuleContext = None,
                      old_fold: FFoldE = None) -> int:
    if ctx is not None and old_fold is not None:
        _, new_fold = _get_parts(payload)
        for v in old_fold.acc_names:
            if (old_fold.key(), v) in ctx.empty_vars:
                ctx.empty_vars[(new_fold.key(), v)] = frozenset([v])
    memo.insert(AndNode("slot-project", (), ("slot", var, i, payload)),
                group=memo.owner(and_id))
    return 1


def rule_T2_correlated(memo: Memo, and_id: int, ctx: RuleContext) -> int:
    """?(t2.A == k, g) over Scan(R) ≡ g over σ_{A=k}(R): push an equality
    guard into the (possibly correlated) source of a nested fold."""
    s = _slot(memo, and_id)
    if s is None:
        return 0
    node, var, i, pre, fold = s
    new = 0

    def rewrite(e: FExpr) -> FExpr:
        nonlocal new
        if isinstance(e, FFoldE) and isinstance(e.source, FQueryE) \
                and isinstance(e.source.query, Scan) and len(e.acc_names) == 1:
            u = e.func.items[0]
            if isinstance(u, FCondE) and isinstance(u.pred, FBin) and u.pred.op == "==":
                for a, b in ((u.pred.left, u.pred.right),
                             (u.pred.right, u.pred.left)):
                    if (isinstance(a, FField) and isinstance(a.base, FRow)
                            and a.base.name == e.row_name
                            and not fir_contains(
                                b, lambda x: isinstance(x, FRow)
                                and x.name == e.row_name)):
                        new += 1
                        return FFoldE(FTupleE((u.then,)), e.init,
                                      FSelLookupE(e.source.query.table, a.col, b),
                                      e.acc_names, e.row_name)
        return e

    new_fold = fir_map(fold, rewrite)
    if new == 0 or new_fold == fold:
        return 0
    return _add_slot_variant(memo, and_id, var, i, _mk_payload(pre, new_fold), ctx, fold)


def rule_N2_correlated(memo: Memo, and_id: int, ctx: RuleContext) -> int:
    """Reverse of T2-correlated: σ_{A=k}(R) source → Scan(R) + guard (N2)."""
    s = _slot(memo, and_id)
    if s is None:
        return 0
    node, var, i, pre, fold = s
    new = 0

    def rewrite(e: FExpr) -> FExpr:
        nonlocal new
        if isinstance(e, FFoldE) and isinstance(e.source, FSelLookupE) \
                and len(e.acc_names) == 1:
            u = e.func.items[0]
            pred = FBin("==", FField(FRow(e.row_name), e.source.key_col),
                        e.source.keyexpr)
            new += 1
            return FFoldE(FTupleE((FCondE(pred, u),)), e.init,
                          FQueryE(Scan(e.source.table)), e.acc_names, e.row_name)
        return e

    new_fold = fir_map(fold, rewrite)
    if new == 0 or new_fold == fold:
        return 0
    return _add_slot_variant(memo, and_id, var, i, _mk_payload(pre, new_fold), ctx, fold)


def rule_T2_plain(memo: Memo, and_id: int, ctx: RuleContext) -> int:
    """fold(?(pred, g), id, Q) ≡ fold(g, id, σ_pred(Q)) — uncorrelated form."""
    s = _slot(memo, and_id)
    if s is None:
        return 0
    node, var, i, pre, fold = s
    if not isinstance(fold.source, FQueryE):
        return 0
    upd = fold.func.items[i]
    if not isinstance(upd, FCondE):
        return 0
    if not _only_over_rows(upd.pred, frozenset([fold.row_name])):
        return 0
    try:
        pred = _fexpr_to_scalar(upd.pred, _self_colmap(upd.pred, fold.row_name))
    except _NotScalar:
        return 0
    if len(fold.acc_names) != 1:
        return 0  # σ push must preserve the other slots' row set
    new_fold = FFoldE(FTupleE((upd.then,)), fold.init,
                      FQueryE(Select(pred, fold.source.query)),
                      fold.acc_names, fold.row_name)
    return _add_slot_variant(memo, and_id, var, i, _mk_payload(pre, new_fold), ctx, fold)


def rule_N2_plain(memo: Memo, and_id: int, ctx: RuleContext) -> int:
    """fold(g, id, σ_pred(Q)) ≡ fold(?(pred, g), id, Q) — rule N2."""
    s = _slot(memo, and_id)
    if s is None:
        return 0
    node, var, i, pre, fold = s
    if not (isinstance(fold.source, FQueryE)
            and isinstance(fold.source.query, Select)
            and len(fold.acc_names) == 1):
        return 0
    sel = fold.source.query
    pred_f = _scalar_to_fexpr(sel.pred, fold.row_name)
    if pred_f is None:
        return 0
    new_fold = FFoldE(FTupleE((FCondE(pred_f, fold.func.items[i]),)), fold.init,
                      FQueryE(sel.child), fold.acc_names, fold.row_name)
    return _add_slot_variant(memo, and_id, var, i, _mk_payload(pre, new_fold), ctx, fold)


def _scalar_to_fexpr(s: Scalar, row: str) -> Optional[FExpr]:
    from ..relational.algebra import BoolOp
    if isinstance(s, Col):
        return FField(FRow(row), s.name)
    if isinstance(s, Lit):
        return FConst(s.value)
    if isinstance(s, (Cmp, Arith)):
        l = _scalar_to_fexpr(s.left, row)
        r = _scalar_to_fexpr(s.right, row)
        if l is None or r is None:
            return None
        return FBin(s.op, l, r)
    if isinstance(s, BoolOp):
        l = _scalar_to_fexpr(s.left, row)
        r = _scalar_to_fexpr(s.right, row)
        if l is None or r is None:
            return None
        return FBin(s.op, l, r)
    if isinstance(s, Func):
        args = tuple(_scalar_to_fexpr(a, row) for a in s.args)
        if any(a is None for a in args):
            return None
        return FCall(s.name, args)
    return None


def rule_N1(memo: Memo, and_id: int, ctx: RuleContext) -> int:
    """N1: iterative point lookups → prefetch(R, A) + local cache lookups."""
    s = _slot(memo, and_id)
    if s is None:
        return 0
    node, var, i, pre, fold = s
    targets = set()

    def collect(e: FExpr):
        if isinstance(e, FPointLookup):
            targets.add((e.table, e.key_col))
        for k in fir_children(e):
            collect(k)

    collect(fold)
    if not targets:
        return 0

    def rewrite(e: FExpr) -> FExpr:
        if isinstance(e, FPointLookup):
            return FCacheLookupE(e.table, e.key_col, e.keyexpr)
        return e

    new_fold = fir_map(fold, rewrite)
    prefetches = tuple(FPrefetchE(Scan(t), c) for t, c in sorted(targets))
    existing = tuple(p for p in pre
                     if not (isinstance(p, FPrefetchE)
                             and any(isinstance(q, FPrefetchE)
                                     and q.key() == p.key() for q in prefetches)))
    return _add_slot_variant(memo, and_id, var, i,
                             _mk_payload(existing + prefetches, new_fold), ctx, fold)


def rule_N1_all(memo: Memo, and_id: int, ctx: RuleContext) -> int:
    """N1 (set form): an inner fold over a correlated σ source → prefetch the
    whole relation + iterate the local multi-row cache lookup."""
    s = _slot(memo, and_id)
    if s is None:
        return 0
    node, var, i, pre, fold = s
    targets = set()

    def rewrite(e: FExpr) -> FExpr:
        if isinstance(e, FFoldE) and isinstance(e.source, FSelLookupE):
            src = e.source
            targets.add((src.table, src.key_col))
            return FFoldE(e.func, e.init,
                          FCacheLookupAllE(src.table, src.key_col, src.keyexpr),
                          e.acc_names, e.row_name)
        return e

    new_fold = fir_map(fold, rewrite)
    if not targets:
        return 0
    prefetches = tuple(FPrefetchE(Scan(t), c) for t, c in sorted(targets))
    return _add_slot_variant(memo, and_id, var, i,
                             _mk_payload(tuple(pre) + prefetches, new_fold), ctx, fold)


def rule_T3(memo: Memo, and_id: int, ctx: RuleContext) -> int:
    """T3: push a scalar function h(Q.A) into the query as a computed
    projection — fold(g(v, h(Q.A)), id, Q) ≡ fold(g, id, π_h(A)(Q))."""
    s = _slot(memo, and_id)
    if s is None:
        return 0
    node, var, i, pre, fold = s
    if not isinstance(fold.source, FQueryE):
        return 0
    upd = fold.func.items[i]
    # find a call h(t.A...) over own-row fields only
    found: List[FCall] = []

    def scan_calls(e: FExpr):
        if isinstance(e, FCall) and _only_over_rows(e, frozenset([fold.row_name])) \
                and _row_fields(e, fold.row_name):
            found.append(e)
            return
        for k in fir_children(e):
            scan_calls(k)

    scan_calls(upd)
    if not found:
        return 0
    target = found[0]
    fields = _row_fields(target, fold.row_name)
    colmap = {(fold.row_name, c): c for c in fields}
    try:
        hs = _fexpr_to_scalar(target, colmap)
    except _NotScalar:
        return 0
    # other slots must not need dropped columns — keep all original columns
    keep_cols = tuple(dict.fromkeys(
        c for j in range(len(fold.acc_names))
        for c in _row_fields(fold.func.items[j], fold.row_name)))
    new_q = Project(keep_cols, fold.source.query, computed=(("h_val", hs),))

    def rewrite(e: FExpr) -> FExpr:
        if e == target:
            return FField(FRow(fold.row_name), "h_val")
        return e

    new_items = tuple(fir_map(it, rewrite) for it in fold.func.items)
    new_fold = FFoldE(FTupleE(new_items), fold.init, FQueryE(new_q),
                      fold.acc_names, fold.row_name)
    return _add_slot_variant(memo, and_id, var, i, _mk_payload(pre, new_fold), ctx, fold)


# --------------------------------------------------------------------------
# Rule registry
# --------------------------------------------------------------------------

def default_rules() -> List[Rule]:
    return [
        # toFIR is a NORMALIZATION: it rewrites imperative loops into the
        # F-IR form every other rule matches on, so it saturates first —
        # the explore phase then starts from a fully-normalized frontier
        Rule("toFIR", "loop", rule_fir_convert, phase="normalize"),
        Rule("T1", "slot-project", rule_T1),
        Rule("T2", "slot-project", rule_T2_plain),
        Rule("T2c", "slot-project", rule_T2_correlated),
        Rule("N2", "slot-project", rule_N2_plain),
        Rule("N2c", "slot-project", rule_N2_correlated),
        Rule("T3", "slot-project", rule_T3),
        Rule("T4", "slot-project", rule_T4),
        Rule("T4j", "slot-project", rule_point_to_join),
        Rule("T5", "slot-project", rule_T5),
        Rule("N1", "slot-project", rule_N1),
        Rule("N1a", "slot-project", rule_N1_all),
    ]
