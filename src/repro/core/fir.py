"""F-IR: the fold intermediate representation (Sec. V).

F-IR algebraically represents cursor loops: variables at region end are
expressions over region-entry values (``FVarRef``) and the loop's source
query. The paper's extension over [4] — ``tuple`` + ``project`` — lets a
single ``fold`` return ALL accumulated variables, including *dependent*
aggregations (cumulative sum, Fig. 7/8), by removing precondition P2.

Node vocabulary beyond the paper's figures (needed to express its example
workloads): ``FPointLookup`` (single-row correlated σ — what an ORM
navigation denotes), ``FSelLookupE`` (multi-row correlated σ — an iterative
query inside a loop), ``FCacheLookupE``/``FCacheLookupAllE`` (rule N1's
``lookup``), and nested ``FFoldE`` (nested cursor loops — rule T4's LHS).

This module provides:

  * the node vocabulary (hashable dataclass trees);
  * ``loop_to_fir`` — the Fig. 9 conversion (cursor loop region → ``fold``
    over a tuple of update expressions; P2 removed; nested loops supported);
  * ``eval_fir`` — a reference evaluator against a ClientEnv (the oracle for
    rule-equivalence property tests);
  * ``fir_to_region`` — code generation from F-IR back to imperative regions.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple


from ..relational.algebra import Cmp, Col, Param, Query, Scan, Select
from ..relational.table import Table
from .regions import (Assign, BasicBlock, CollectionAdd, CondRegion, IBin,
                      ICacheLookup, ICall, IConst, IEmptyList, IEmptyMap,
                      IExpr, IField, INav, IQuery, IVar, LoopRegion, MapPut,
                      NoOp, Prefetch, Region, SeqRegion, Stmt, _BIN_OPS,
                      _FUNCTIONS)

__all__ = [
    "FExpr", "FConst", "FVarRef", "FAcc", "FRow", "FField", "FBin", "FCall",
    "FInsert", "FMapPutE", "FTupleE", "FProjectE", "FCondE", "FPointLookup",
    "FSelLookupE", "FCacheLookupE", "FCacheLookupAllE", "FQueryE", "FFoldE",
    "FSeqE", "FPrefetchE", "loop_to_fir", "FIRConversionError", "eval_fir",
    "fir_to_region", "fir_children", "fir_rebuild", "fir_map", "fold_to_loop",
    "NameGen", "fold_accumulators",
]


# --------------------------------------------------------------------------
# Node vocabulary
# --------------------------------------------------------------------------

class FExpr:
    def key(self) -> Tuple:
        raise NotImplementedError

    def __hash__(self):
        return hash(self.key())

    def __eq__(self, other):
        return isinstance(other, FExpr) and self.key() == other.key()


@dataclasses.dataclass(frozen=True, eq=False)
class FConst(FExpr):
    value: object

    def key(self):
        return ("fconst", self.value)

    def __repr__(self):
        return repr(self.value)


@dataclasses.dataclass(frozen=True, eq=False)
class FVarRef(FExpr):
    """Value of a program variable at region entry (the input state X0)."""

    name: str

    def key(self):
        return ("fvar", self.name)

    def __repr__(self):
        return f"@{self.name}"


@dataclasses.dataclass(frozen=True, eq=False)
class FAcc(FExpr):
    """Parametric accumulator reference — ``<v>`` in the paper's notation."""

    name: str

    def key(self):
        return ("facc", self.name)

    def __repr__(self):
        return f"<{self.name}>"


@dataclasses.dataclass(frozen=True, eq=False)
class FRow(FExpr):
    """A fold's tuple variable (one row of that fold's source)."""

    name: str = "t"

    def key(self):
        return ("frow", self.name)

    def __repr__(self):
        return self.name


@dataclasses.dataclass(frozen=True, eq=False)
class FField(FExpr):
    base: FExpr
    col: str

    def key(self):
        return ("ffield", self.base.key(), self.col)

    def __repr__(self):
        return f"{self.base!r}.{self.col}"


@dataclasses.dataclass(frozen=True, eq=False)
class FBin(FExpr):
    op: str
    left: FExpr
    right: FExpr

    def key(self):
        return ("fbin", self.op, self.left.key(), self.right.key())

    def __repr__(self):
        return f"({self.left!r} {self.op} {self.right!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class FCall(FExpr):
    func: str
    args: Tuple[FExpr, ...]

    def key(self):
        return ("fcall", self.func, tuple(a.key() for a in self.args))

    def __repr__(self):
        return f"{self.func}({', '.join(map(repr, self.args))})"


@dataclasses.dataclass(frozen=True, eq=False)
class FInsert(FExpr):
    """Collection insertion function (``insert`` in T1/T4)."""

    coll: FExpr
    val: FExpr

    def key(self):
        return ("finsert", self.coll.key(), self.val.key())

    def __repr__(self):
        return f"insert({self.coll!r}, {self.val!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class FMapPutE(FExpr):
    map: FExpr
    mkey: FExpr
    val: FExpr

    def key(self):
        return ("fmapput", self.map.key(), self.mkey.key(), self.val.key())

    def __repr__(self):
        return f"mapput({self.map!r}, {self.mkey!r}, {self.val!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class FTupleE(FExpr):
    """The paper's new ``tuple`` operator (Sec. V-B)."""

    items: Tuple[FExpr, ...]

    def key(self):
        return ("ftuple", tuple(i.key() for i in self.items))

    def __repr__(self):
        return f"tuple({', '.join(map(repr, self.items))})"


@dataclasses.dataclass(frozen=True, eq=False)
class FProjectE(FExpr):
    """The paper's new ``project`` operator — inverse of ``tuple``."""

    base: FExpr
    index: int

    def key(self):
        return ("fproject", self.base.key(), self.index)

    def __repr__(self):
        return f"project{self.index}({self.base!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class FCondE(FExpr):
    """``?(pred, g)`` — conditional execution operator (T2/N2)."""

    pred: FExpr
    then: FExpr

    def key(self):
        return ("fcond", self.pred.key(), self.then.key())

    def __repr__(self):
        return f"?({self.pred!r}, {self.then!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class FPointLookup(FExpr):
    """Correlated point query σ_{key_col = key}(table) returning ONE row
    (what an ORM navigation denotes — the N+1 pattern)."""

    table: str
    key_col: str
    keyexpr: FExpr

    def key(self):
        return ("fpoint", self.table, self.key_col, self.keyexpr.key())

    def __repr__(self):
        return f"σ1[{self.table}.{self.key_col}={self.keyexpr!r}]"


@dataclasses.dataclass(frozen=True, eq=False)
class FSelLookupE(FExpr):
    """Correlated σ_{key_col = key}(table) returning a SET of rows — an
    iterative query executed at the database per outer row."""

    table: str
    key_col: str
    keyexpr: FExpr

    def key(self):
        return ("fsel", self.table, self.key_col, self.keyexpr.key())

    def __repr__(self):
        return f"σ[{self.table}.{self.key_col}={self.keyexpr!r}]"


@dataclasses.dataclass(frozen=True, eq=False)
class FCacheLookupE(FExpr):
    """Local single-row cache lookup (``lookup`` of rule N1)."""

    table: str
    key_col: str
    keyexpr: FExpr

    def key(self):
        return ("fcachelkp", self.table, self.key_col, self.keyexpr.key())

    def __repr__(self):
        return f"lookup[{self.table}.{self.key_col}={self.keyexpr!r}]"


@dataclasses.dataclass(frozen=True, eq=False)
class FCacheLookupAllE(FExpr):
    """Local multi-row cache lookup (all rows matching the key)."""

    table: str
    key_col: str
    keyexpr: FExpr

    def key(self):
        return ("fcachelkpall", self.table, self.key_col, self.keyexpr.key())

    def __repr__(self):
        return f"lookupAll[{self.table}.{self.key_col}={self.keyexpr!r}]"


@dataclasses.dataclass(frozen=True, eq=False)
class FQueryE(FExpr):
    """A relational query leaf (executed at the database)."""

    query: Query

    def key(self):
        return ("fquery", self.query.key())

    def __repr__(self):
        return f"Q[{self.query.sql()}]"


@dataclasses.dataclass(frozen=True, eq=False)
class FFoldE(FExpr):
    """fold(func, init, source) — func over (<accs>, row_name)."""

    func: FExpr   # FTupleE of per-accumulator update expressions
    init: FExpr   # FTupleE of entry values
    source: FExpr  # FQueryE | FSelLookupE | FCacheLookupAllE
    acc_names: Tuple[str, ...]
    row_name: str = "t"

    def key(self):
        return ("ffold", self.func.key(), self.init.key(), self.source.key(),
                self.acc_names, self.row_name)

    def __repr__(self):
        return f"fold({self.func!r}, {self.init!r}, {self.source!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class FPrefetchE(FExpr):
    """prefetch(R, A): side-effecting cache fill (rule N1's seq head)."""

    query: Query
    col: str

    def key(self):
        return ("fprefetch", self.query.key(), self.col)

    def __repr__(self):
        return f"prefetch({self.query.sql()!r}, by={self.col})"


@dataclasses.dataclass(frozen=True, eq=False)
class FSeqE(FExpr):
    """Sequential combination inside F-IR (N1 produces seq(prefetch, fold))."""

    parts: Tuple[FExpr, ...]

    def key(self):
        return ("fseq", tuple(p.key() for p in self.parts))

    def __repr__(self):
        return f"seq({', '.join(map(repr, self.parts))})"


# --------------------------------------------------------------------------
# Generic traversal
# --------------------------------------------------------------------------

def fir_children(e: FExpr) -> Tuple[FExpr, ...]:
    if isinstance(e, (FConst, FVarRef, FAcc, FRow, FQueryE, FPrefetchE)):
        return ()
    if isinstance(e, FField):
        return (e.base,)
    if isinstance(e, FBin):
        return (e.left, e.right)
    if isinstance(e, FCall):
        return e.args
    if isinstance(e, FInsert):
        return (e.coll, e.val)
    if isinstance(e, FMapPutE):
        return (e.map, e.mkey, e.val)
    if isinstance(e, FTupleE):
        return e.items
    if isinstance(e, FProjectE):
        return (e.base,)
    if isinstance(e, FCondE):
        return (e.pred, e.then)
    if isinstance(e, (FPointLookup, FSelLookupE, FCacheLookupE, FCacheLookupAllE)):
        return (e.keyexpr,)
    if isinstance(e, FFoldE):
        return (e.func, e.init, e.source)
    if isinstance(e, FSeqE):
        return e.parts
    raise TypeError(type(e))


def fir_rebuild(e: FExpr, new_children: Sequence[FExpr]) -> FExpr:
    c = tuple(new_children)
    if isinstance(e, (FConst, FVarRef, FAcc, FRow, FQueryE, FPrefetchE)):
        return e
    if isinstance(e, FField):
        return FField(c[0], e.col)
    if isinstance(e, FBin):
        return FBin(e.op, c[0], c[1])
    if isinstance(e, FCall):
        return FCall(e.func, c)
    if isinstance(e, FInsert):
        return FInsert(c[0], c[1])
    if isinstance(e, FMapPutE):
        return FMapPutE(c[0], c[1], c[2])
    if isinstance(e, FTupleE):
        return FTupleE(c)
    if isinstance(e, FProjectE):
        return FProjectE(c[0], e.index)
    if isinstance(e, FCondE):
        return FCondE(c[0], c[1])
    if isinstance(e, FPointLookup):
        return FPointLookup(e.table, e.key_col, c[0])
    if isinstance(e, FSelLookupE):
        return FSelLookupE(e.table, e.key_col, c[0])
    if isinstance(e, FCacheLookupE):
        return FCacheLookupE(e.table, e.key_col, c[0])
    if isinstance(e, FCacheLookupAllE):
        return FCacheLookupAllE(e.table, e.key_col, c[0])
    if isinstance(e, FFoldE):
        return FFoldE(c[0], c[1], c[2], e.acc_names, e.row_name)
    if isinstance(e, FSeqE):
        return FSeqE(c)
    raise TypeError(type(e))


def fir_map(e: FExpr, fn) -> FExpr:
    """Bottom-up rewrite."""
    kids = tuple(fir_map(k, fn) for k in fir_children(e))
    return fn(fir_rebuild(e, kids))


def fir_contains(e: FExpr, pred) -> bool:
    if pred(e):
        return True
    return any(fir_contains(k, pred) for k in fir_children(e))


# --------------------------------------------------------------------------
# Loop → F-IR conversion (Fig. 9, precondition P2 removed)
# --------------------------------------------------------------------------

class FIRConversionError(Exception):
    pass


def _row_name_for(loop_var: str) -> str:
    """Deterministic F-IR row name for a cursor loop.

    Derived from the loop variable (unique within a lexical scope) instead of
    a global counter, so converting the same program twice — in one process
    or across sessions — yields byte-identical F-IR. Content-stable names are
    what lets the disk-backed plan store dedupe compiled programs."""
    return f"t_{loop_var}"


def _iexpr_to_fir(e: IExpr, subst: Dict[str, FExpr], row_names: Dict[str, str]) -> FExpr:
    """Translate an imperative expression. `subst` resolves intermediate
    assignments (variables expressed over region-entry values — Sec. V-A);
    `row_names` maps loop variables to F-IR row names."""
    if isinstance(e, IConst):
        return FConst(e.value)
    if isinstance(e, IVar):
        if e.name in row_names:
            return FRow(row_names[e.name])
        if e.name in subst:
            return subst[e.name]
        return FVarRef(e.name)
    if isinstance(e, IField):
        return FField(_iexpr_to_fir(e.base, subst, row_names), e.field)
    if isinstance(e, IBin):
        return FBin(e.op, _iexpr_to_fir(e.left, subst, row_names),
                    _iexpr_to_fir(e.right, subst, row_names))
    if isinstance(e, ICall):
        return FCall(e.func, tuple(_iexpr_to_fir(a, subst, row_names) for a in e.args))
    if isinstance(e, INav):
        base = _iexpr_to_fir(e.base, subst, row_names)
        if isinstance(base, (FPointLookup, FCacheLookupE)):
            keyexpr: FExpr = FField(base, e.fk_field)
        elif isinstance(base, FRow):
            keyexpr = FField(base, e.fk_field)
        else:
            raise FIRConversionError(f"nav base too complex: {e!r}")
        return FPointLookup(e.target, e.target_key, keyexpr)
    if isinstance(e, ICacheLookup):
        k = _iexpr_to_fir(e.keyexpr, subst, row_names)
        if e.all_matches:
            return FCacheLookupAllE(e.table, e.col, k)
        return FCacheLookupE(e.table, e.col, k)
    if isinstance(e, IQuery):
        q = e.query
        if (len(e.bindings) == 1 and isinstance(q, Select)
                and isinstance(q.child, Scan) and isinstance(q.pred, Cmp)
                and q.pred.op == "=="):
            pname, bexpr = e.bindings[0]
            lhs, rhs = q.pred.left, q.pred.right
            if isinstance(rhs, Col) and isinstance(lhs, Param):
                lhs, rhs = rhs, lhs
            if isinstance(lhs, Col) and isinstance(rhs, Param) and rhs.name == pname:
                return FSelLookupE(q.child.table, lhs.name,
                                   _iexpr_to_fir(bexpr, subst, row_names))
        if e.bindings:
            raise FIRConversionError(f"correlated query too complex: {e!r}")
        return FQueryE(e.query)
    if isinstance(e, IEmptyList):
        return FConst(())
    if isinstance(e, IEmptyMap):
        return FConst(())
    if hasattr(e, "table") and type(e).__name__ == "ILoadAll":
        return FQueryE(Scan(e.table))
    raise FIRConversionError(f"cannot represent {e!r} in F-IR")


def loop_to_fir(loop: LoopRegion) -> Tuple[FFoldE, Dict[str, int]]:
    """Fig. 9 ``loopToFold``: returns (fold expr, var → tuple index).

    Handles straight-line bodies with optional guards, nested cursor loops
    (nested folds — rule T4's LHS), and dependent aggregations (P2 removed)."""
    fold = _convert_loop(loop, subst={}, row_names={})
    return fold, {a: i for i, a in enumerate(fold.acc_names)}


def _source_to_fir(src: IExpr, subst, row_names) -> FExpr:
    out = _iexpr_to_fir(src, subst, row_names)
    if isinstance(out, (FQueryE, FSelLookupE, FCacheLookupAllE)):
        return out
    raise FIRConversionError(f"loop source not a query/lookup: {src!r}")


def _convert_loop(loop: LoopRegion, subst: Dict[str, FExpr],
                  row_names: Dict[str, str]) -> FFoldE:
    source = _source_to_fir(loop.source, subst, row_names)
    row_name = _row_name_for(loop.var)
    row_names = {**row_names, loop.var: row_name}

    parts = _body_parts(loop.body)
    subst = dict(subst)
    acc_update: Dict[str, FExpr] = {}
    acc_order: List[str] = []

    def acc_ref(name: str) -> FExpr:
        return acc_update.get(name, FAcc(name))

    def ctx() -> Dict[str, FExpr]:
        return {**subst, **{a: acc_ref(a) for a in acc_order}}

    def record(name: str, upd: FExpr) -> None:
        if name not in acc_order:
            acc_order.append(name)
        acc_update[name] = upd

    def handle_stmt(stmt: Stmt, guard: Optional[IExpr]) -> None:
        if isinstance(stmt, Assign):
            e = stmt.expr
            if isinstance(e, IBin) and any(
                    isinstance(s, IVar) and s.name == stmt.target
                    for s in (e.left, e.right)):
                l_is = isinstance(e.left, IVar) and e.left.name == stmt.target
                other = e.right if l_is else e.left
                other_f = _iexpr_to_fir(other, ctx(), row_names)
                cur = acc_ref(stmt.target)
                upd = FBin(e.op, cur, other_f) if l_is else FBin(e.op, other_f, cur)
                if guard is not None:
                    upd = FCondE(_iexpr_to_fir(guard, ctx(), row_names), upd)
                record(stmt.target, upd)
                return
            if guard is not None:
                raise FIRConversionError("guarded temp assignment")
            subst[stmt.target] = _iexpr_to_fir(e, ctx(), row_names)
            return
        if isinstance(stmt, CollectionAdd):
            val = _iexpr_to_fir(stmt.expr, ctx(), row_names)
            upd: FExpr = FInsert(acc_ref(stmt.target), val)
            if guard is not None:
                upd = FCondE(_iexpr_to_fir(guard, ctx(), row_names), upd)
            record(stmt.target, upd)
            return
        if isinstance(stmt, MapPut):
            c = ctx()
            upd = FMapPutE(acc_ref(stmt.target),
                           _iexpr_to_fir(stmt.keyexpr, c, row_names),
                           _iexpr_to_fir(stmt.valexpr, c, row_names))
            if guard is not None:
                upd = FCondE(_iexpr_to_fir(guard, c, row_names), upd)
            record(stmt.target, upd)
            return
        if isinstance(stmt, NoOp):
            return
        raise FIRConversionError(f"statement not representable: {stmt!r}")

    for part, guard in parts:
        if isinstance(part, LoopRegion):
            if guard is not None:
                raise FIRConversionError("guarded nested loop")
            inner = _convert_loop(part, ctx(), row_names)
            if len(inner.acc_names) != 1:
                raise FIRConversionError("nested loop with multiple accumulators")
            name = inner.acc_names[0]
            # inner fold starts from the CURRENT value: the accumulator's
            # update-so-far, a resolved temp (e.g. s = 0 just before), or the
            # region-entry value.
            start = acc_update.get(name, subst.get(name, FAcc(name)))
            inner = FFoldE(inner.func, FTupleE((start,)), inner.source,
                           inner.acc_names, inner.row_name)
            subst.pop(name, None)
            record(name, FProjectE(inner, 0))
        else:
            handle_stmt(part, guard)

    if not acc_order:
        raise FIRConversionError("loop has no accumulated variables")

    # unwrap project0(fold) single-slot markers for nested folds
    def unwrap(e: FExpr) -> FExpr:
        if isinstance(e, FProjectE) and isinstance(e.base, FFoldE) \
                and len(e.base.acc_names) == 1 and e.index == 0:
            return e.base
        return e

    func = FTupleE(tuple(unwrap(acc_update[a]) for a in acc_order))
    init = FTupleE(tuple(FVarRef(a) for a in acc_order))
    return FFoldE(func, init, source, tuple(acc_order), row_name)


def _body_parts(region: Region) -> List[Tuple[object, Optional[IExpr]]]:
    """Flatten a loop body to [(Stmt-or-LoopRegion, guard)]."""
    out: List[Tuple[object, Optional[IExpr]]] = []

    def walk(r: Region, guard: Optional[IExpr]) -> None:
        if isinstance(r, BasicBlock):
            out.append((r.stmt, guard))
        elif isinstance(r, SeqRegion):
            for p in r.parts:
                walk(p, guard)
        elif isinstance(r, CondRegion):
            if guard is not None or r.else_r is not None:
                raise FIRConversionError("nested/else conditions")
            walk(r.then_r, r.pred)
        elif isinstance(r, LoopRegion):
            out.append((r, guard))
        else:
            raise FIRConversionError(f"region not representable: {r!r}")

    walk(region, None)
    return out


def fold_accumulators(loop: LoopRegion) -> Optional[Dict[str, str]]:
    """Scalar-accumulator reduction ops of a cursor loop as F-IR sees them.

    Converts the loop to its fold form and pattern-matches each slot's
    update expression: ``{acc: op}`` where ``op`` is the ``FBin`` operator
    of an ``acc = acc <op> e`` update (unwrapping one guard ``FCondE``),
    or ``"other"`` for collection/map/non-reduction slots. Returns ``None``
    when the loop has no F-IR form at all. The compiled tier's lowering
    (:mod:`repro.compiled.lower`) uses this as a semantic cross-check on
    the syntactic accumulator recognition before it folds a column with a
    reduction kernel: a slot both analyses agree is an order-insensitive
    ``+``/``min``/``max`` fold is safe to compute as one reduction."""
    try:
        fold, idx = loop_to_fir(loop)
    except FIRConversionError:
        return None
    out: Dict[str, str] = {}
    for name, i in idx.items():
        upd = fold.func.items[i]
        if isinstance(upd, FCondE):
            upd = upd.then
        if isinstance(upd, FBin):
            l_is = isinstance(upd.left, FAcc) and upd.left.name == name
            r_is = isinstance(upd.right, FAcc) and upd.right.name == name
            if l_is != r_is:
                out[name] = upd.op
                continue
        out[name] = "other"
    return out


# --------------------------------------------------------------------------
# Reference evaluator (oracle)
# --------------------------------------------------------------------------

class _CondSkip:
    """Marker: ?(pred, g) with false pred → accumulator keeps previous value."""

    def __repr__(self):
        return "<skip>"


_COND_SKIP = _CondSkip()


def eval_fir(e: FExpr, env, state: Mapping[str, object],
             accs: Optional[Dict[str, object]] = None,
             rows: Optional[Dict[str, Mapping[str, object]]] = None):
    """Evaluate F-IR against a live ClientEnv. Side effects (queries,
    prefetches, lookups) charge simulated time on `env` — the evaluator both
    checks semantic equivalence and measures plan cost."""
    accs = accs or {}
    rows = rows or {}
    if isinstance(e, FConst):
        return [] if e.value == () else e.value
    if isinstance(e, FVarRef):
        v = state[e.name]
        return list(v) if isinstance(v, list) else (dict(v) if isinstance(v, dict) else v)
    if isinstance(e, FAcc):
        return accs[e.name]
    if isinstance(e, FRow):
        return rows[e.name]
    if isinstance(e, FField):
        return eval_fir(e.base, env, state, accs, rows)[e.col]
    if isinstance(e, FBin):
        return _BIN_OPS[e.op](eval_fir(e.left, env, state, accs, rows),
                              eval_fir(e.right, env, state, accs, rows))
    if isinstance(e, FCall):
        return _FUNCTIONS[e.func](*[eval_fir(a, env, state, accs, rows) for a in e.args])
    if isinstance(e, FInsert):
        coll = eval_fir(e.coll, env, state, accs, rows)
        val = eval_fir(e.val, env, state, accs, rows)
        return list(coll) + [val]
    if isinstance(e, FMapPutE):
        m = dict(eval_fir(e.map, env, state, accs, rows))
        m[eval_fir(e.mkey, env, state, accs, rows)] = eval_fir(e.val, env, state, accs, rows)
        return m
    if isinstance(e, FTupleE):
        return tuple(eval_fir(i, env, state, accs, rows) for i in e.items)
    if isinstance(e, FProjectE):
        return eval_fir(e.base, env, state, accs, rows)[e.index]
    if isinstance(e, FCondE):
        if bool(eval_fir(e.pred, env, state, accs, rows)):
            return eval_fir(e.then, env, state, accs, rows)
        return _COND_SKIP
    if isinstance(e, FPointLookup):
        k = eval_fir(e.keyexpr, env, state, accs, rows)
        return env.point_lookup(e.table, e.key_col, k)
    if isinstance(e, FSelLookupE):
        k = eval_fir(e.keyexpr, env, state, accs, rows)
        q = Select(Cmp("==", Col(e.key_col), Param("k")), Scan(e.table))
        return env.execute_query(q, {"k": k})
    if isinstance(e, FCacheLookupE):
        k = eval_fir(e.keyexpr, env, state, accs, rows)
        return env.lookup_cache(e.table, e.key_col, k)
    if isinstance(e, FCacheLookupAllE):
        k = eval_fir(e.keyexpr, env, state, accs, rows)
        return env.lookup_cache_all(e.table, e.key_col, k)
    if isinstance(e, FQueryE):
        return env.execute_query(e.query)
    if isinstance(e, FPrefetchE):
        t = env.execute_query(e.query)
        env.cache_by_column(t, e.col)
        return None
    if isinstance(e, FSeqE):
        out = None
        for p in e.parts:
            out = eval_fir(p, env, state, accs, rows)
        return out
    if isinstance(e, FFoldE):
        src = eval_fir(e.source, env, state, accs, rows)
        src_rows = src.to_rows() if isinstance(src, Table) else list(src)
        init = eval_fir(e.init, env, state, accs, rows)
        cur = {n: init[i] for i, n in enumerate(e.acc_names)}
        assert isinstance(e.func, FTupleE)
        # Each tuple item is expressed over iteration-START accumulator
        # values (<v>) and the row — dependent aggregations were inlined at
        # construction time (Fig. 8: the cSum item embeds <sum>+Q.sale_amt).
        for rr in src_rows:
            rbind = {**rows, e.row_name: rr}
            new = {}
            for i, n in enumerate(e.acc_names):
                v = eval_fir(e.func.items[i], env, state, {**accs, **cur}, rbind)
                new[n] = cur[n] if v is _COND_SKIP else v
            cur = new
        return tuple(cur[n] for n in e.acc_names)
    raise TypeError(f"cannot eval {e!r}")


# --------------------------------------------------------------------------
# Code generation: F-IR → imperative regions
# --------------------------------------------------------------------------

class NameGen:
    """Alpha-normalized codegen names.

    One instance is created per code-generation run (``plan_to_region`` /
    ``fir_to_region`` entry), numbering each prefix from 1 in tree-walk
    order. Because the walk over a chosen plan is deterministic, two
    searches of the same program — even in different processes — emit
    byte-identical imperative IR, which lets the cross-session plan store
    dedupe compiled programs (previously a global counter made every run's
    gensyms unique and alpha-equivalence had to be normalized away in
    tests)."""

    def __init__(self):
        self._n: Dict[str, int] = {}

    def fresh(self, prefix: str = "tmp") -> str:
        n = self._n.get(prefix, 0) + 1
        self._n[prefix] = n
        return f"__{prefix}{n}"


def _val_to_iexpr(e: FExpr, row_vars: Dict[str, str], pre: List[Region],
                  names: Optional[NameGen] = None) -> IExpr:
    """Translate a value-producing F-IR expr to an imperative expr. `pre`
    collects statements (cache/nav lookups into temporaries)."""
    if names is None:
        names = NameGen()
    if isinstance(e, FConst):
        return IEmptyList() if e.value == () else IConst(e.value)
    if isinstance(e, FVarRef):
        return IVar(e.name)
    if isinstance(e, FAcc):
        return IVar(e.name)
    if isinstance(e, FRow):
        return IVar(row_vars[e.name])
    if isinstance(e, FField):
        return IField(_val_to_iexpr(e.base, row_vars, pre, names), e.col)
    if isinstance(e, FBin):
        return IBin(e.op, _val_to_iexpr(e.left, row_vars, pre, names),
                    _val_to_iexpr(e.right, row_vars, pre, names))
    if isinstance(e, FCall):
        return ICall(e.func, tuple(_val_to_iexpr(a, row_vars, pre, names)
                                   for a in e.args))
    if isinstance(e, FPointLookup):
        tmp = names.fresh("nav")
        base_key = _val_to_iexpr(e.keyexpr, row_vars, pre, names)
        if isinstance(base_key, IField) and isinstance(base_key.base, IVar):
            pre.append(BasicBlock(Assign(tmp, INav(base_key.base, base_key.field,
                                                   e.table, e.key_col))))
        else:
            pre.append(BasicBlock(Assign(tmp, IQuery(
                Select(Cmp("==", Col(e.key_col), Param("k")), Scan(e.table)),
                (("k", base_key),)))))
        return IVar(tmp)
    if isinstance(e, FCacheLookupE):
        tmp = names.fresh("lkp")
        pre.append(BasicBlock(Assign(tmp, ICacheLookup(
            e.table, e.key_col, _val_to_iexpr(e.keyexpr, row_vars, pre, names)))))
        return IVar(tmp)
    if isinstance(e, FQueryE):
        return IQuery(e.query)
    raise TypeError(f"cannot codegen value {e!r}")


def _source_to_iexpr(src: FExpr, row_vars: Dict[str, str], pre: List[Region],
                     names: NameGen) -> IExpr:
    if isinstance(src, FQueryE):
        return IQuery(src.query)
    if isinstance(src, FSelLookupE):
        key = _val_to_iexpr(src.keyexpr, row_vars, pre, names)
        return IQuery(Select(Cmp("==", Col(src.key_col), Param("k")), Scan(src.table)),
                      (("k", key),))
    if isinstance(src, FCacheLookupAllE):
        key = _val_to_iexpr(src.keyexpr, row_vars, pre, names)
        return ICacheLookup(src.table, src.key_col, key, all_matches=True)
    raise TypeError(f"cannot codegen source {src!r}")


def fold_to_loop(fold: FFoldE, slots: Optional[Sequence[int]] = None,
                 row_vars: Optional[Dict[str, str]] = None,
                 names: Optional[NameGen] = None) -> Region:
    """Generate a loop for (a subset of slots of) a fold.

    ``slots=None`` keeps all slots. A kept slot that references another
    accumulator transitively forces that slot to stay (dependency closure)."""
    assert isinstance(fold.func, FTupleE)
    if names is None:
        names = NameGen()
    row_vars = dict(row_vars or {})
    loop_var = names.fresh("r")
    row_vars[fold.row_name] = loop_var

    keep = set(range(len(fold.acc_names))) if slots is None else set(slots)
    # dependency closure over FAcc references
    changed = True
    while changed:
        changed = False
        for i in sorted(keep):
            expr = fold.func.items[i]
            for j, nm in enumerate(fold.acc_names):
                if j not in keep and fir_contains(expr, lambda x: isinstance(x, FAcc)
                                                  and x.name == nm):
                    keep.add(j)
                    changed = True

    pre_src: List[Region] = []
    src_expr = _source_to_iexpr(fold.source, row_vars, pre_src, names)

    body: List[Region] = []
    for i in sorted(keep):
        body.extend(_update_to_parts(fold.func.items[i], fold.acc_names[i],
                                     row_vars, names))
    inner: Region = SeqRegion(tuple(body)) if len(body) != 1 else body[0]
    loop = LoopRegion(loop_var, src_expr, inner)
    if pre_src:
        return SeqRegion(tuple(pre_src) + (loop,))
    return loop


def _update_to_parts(upd: FExpr, name: str, row_vars: Dict[str, str],
                     names: NameGen) -> List[Region]:
    pre: List[Region] = []
    if isinstance(upd, FCondE):
        pred = _val_to_iexpr(upd.pred, row_vars, pre, names)
        inner = _update_to_parts(upd.then, name, row_vars, names)
        body: Region = SeqRegion(tuple(inner)) if len(inner) != 1 else inner[0]
        return pre + [CondRegion(pred, body)]
    if isinstance(upd, FFoldE):
        # nested fold accumulating into `name`
        assert upd.acc_names == (name,)
        return pre + [fold_to_loop(upd, row_vars=row_vars, names=names)]
    if isinstance(upd, FProjectE) and isinstance(upd.base, FFoldE):
        return _update_to_parts(upd.base, name, row_vars, names)
    if isinstance(upd, FInsert):
        val = _val_to_iexpr(upd.val, row_vars, pre, names)
        return pre + [BasicBlock(CollectionAdd(name, val))]
    if isinstance(upd, FMapPutE):
        k = _val_to_iexpr(upd.mkey, row_vars, pre, names)
        v = _val_to_iexpr(upd.val, row_vars, pre, names)
        return pre + [BasicBlock(MapPut(name, k, v))]
    val = _val_to_iexpr(upd, row_vars, pre, names)
    return pre + [BasicBlock(Assign(name, val))]


def fir_to_region(e: FExpr, slots: Optional[Sequence[int]] = None,
                  names: Optional[NameGen] = None) -> Region:
    """Generate an imperative region computing `e` (a fold/seq alternative)."""
    if names is None:
        names = NameGen()
    if isinstance(e, FSeqE):
        parts: List[Region] = []
        for p in e.parts[:-1]:
            parts.append(fir_to_region(p, names=names))
        parts.append(fir_to_region(e.parts[-1], slots, names=names))
        return SeqRegion(tuple(parts))
    if isinstance(e, FPrefetchE):
        return BasicBlock(Prefetch(e.query, e.col))
    if isinstance(e, FFoldE):
        return fold_to_loop(e, slots, names=names)
    raise TypeError(f"cannot codegen region for {e!r}")
