"""Cobra as a distributed-execution planner (the beyond-paper integration).

The paper's insight — enumerate equivalent program implementations in an
AND-OR DAG over regions and choose by a cost model — applied to the
train/serve step program on a TPU mesh. The SAME ``Memo``/``Rule``/search
machinery from ``core.dag`` is reused; what changes is the domain:

  region          → step-program region (embed / layer stack / head / update)
  transformation  → layout rule (DP/FSDP/TP), remat rule (T2/N2 analogue:
                    recompute vs. store), microbatch rule, weight-prefetch
                    rule (N1 analogue: gather-once-and-cache = replicated
                    weights vs. per-layer re-gather = FSDP), MoE dispatch
                    rule (T4 analogue: batch per-token expert lookups into
                    one all_to_all vs. replicate-and-select)
  cost model      → three-term roofline (compute / HBM / ICI) with an HBM
                    feasibility constraint (16 GB v5e)

``plan()`` returns the least-cost ``PlanChoice`` with predicted terms; the
launcher materializes it as a ``MeshPolicy``. ``benchmarks/bench_planner``
validates predictions against the compiled dry-run numbers.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Tuple

from ..analysis.roofline import HW
from ..models.arch import ArchConfig
from .dag import AndNode, Memo

__all__ = ["PlanChoice", "TPUCostModel", "plan", "enumerate_plans"]


@dataclasses.dataclass(frozen=True)
class PlanChoice:
    strategy: str          # dp | fsdp | tp | fsdp_tp
    remat: str             # none | dots | full
    microbatch: int
    seq_shard: bool
    moe_mode: str          # none | ep_all_to_all | replicated

    def key(self):
        return dataclasses.astuple(self)


@dataclasses.dataclass(frozen=True)
class MeshShape:
    pod: int
    data: int
    model: int

    @property
    def n(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp(self) -> int:
        return self.pod * self.data


class TPUCostModel:
    """Analytic three-term roofline for one step of (cfg × shape × plan).

    The napkin math the hypothesis→measure loop (EXPERIMENTS.md §Perf)
    starts from; deliberately simple and fully inspectable."""

    def __init__(self, cfg: ArchConfig, seq_len: int, global_batch: int,
                 kind: str, mesh: MeshShape):
        self.cfg = cfg
        self.T = seq_len
        self.B = global_batch
        self.kind = kind
        self.mesh = mesh

    # ------------------------------------------------------------ components
    def _param_bytes(self) -> float:
        return self.cfg.n_params() * 2.0  # bf16

    def _expert_bytes(self) -> float:
        c = self.cfg
        if not c.moe:
            return 0.0
        mff = c.moe_d_ff or c.d_ff
        return 3.0 * c.d_model * mff * c.n_experts * 2.0 * \
            (c.n_layers - c.n_dense_layers)

    def _opt_bytes(self) -> float:
        if self.kind != "train":
            return 0.0
        per = 8.0 if self.cfg.n_params() <= 5e11 else 0.5  # adamw vs adafactor
        return self.cfg.n_params() * per

    def _tokens(self) -> float:
        if self.kind == "decode":
            return float(self.B)
        return float(self.B * self.T)

    def _flops_total(self, plan: PlanChoice) -> float:
        c = self.cfg
        mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[self.kind]
        f = mult * c.n_active_params() * self._tokens()
        # attention context term
        if c.attn_kind != "none":
            eff_ctx = self.T
            if c.window:
                eff_ctx = min(self.T, c.window)
            if c.chunk_size:
                eff_ctx = min(eff_ctx, c.chunk_size)
            if self.kind == "decode":
                per_tok_ctx = eff_ctx
            else:
                per_tok_ctx = eff_ctx / 2.0
            n_attn = c.n_layers if not c.shared_attn else \
                max(1, c.n_layers // max(1, c.hybrid_every))
            f += (2.0 if self.kind != "train" else 6.0) * 2.0 * \
                self._tokens() * per_tok_ctx * c.n_heads * c.hd * n_attn
        if plan.remat == "full" and self.kind == "train":
            f *= 4.0 / 3.0   # one extra forward
        elif plan.remat == "dots" and self.kind == "train":
            f *= 7.0 / 6.0
        return f

    def _act_bytes_per_device(self, plan: PlanChoice) -> float:
        c = self.cfg
        tok_dev = self._tokens() / (self.mesh.dp if not plan.seq_shard
                                    else self.mesh.n / self.mesh.model)
        per_layer = tok_dev * c.d_model * 2.0 * 4.0   # a few live tensors
        if self.kind != "train":
            # inference: no backward, nothing saved; prefill can chunk the
            # batch (chunked prefill) — microbatch models that
            return per_layer * 2.0 / max(1, plan.microbatch)
        live_layers = 2 if plan.remat == "full" else c.n_layers
        mb = max(1, plan.microbatch)
        # both live activations AND remat-saved layer carries are per
        # microbatch (each microbatch's backward completes before the next)
        return (per_layer * live_layers
                + tok_dev * c.d_model * 2.0 * c.n_layers * 0.25) / mb

    # -------------------------------------------------------------- terms
    def terms(self, plan: PlanChoice) -> Dict[str, float]:
        m = self.mesh
        c = self.cfg
        n = m.n
        P = self._param_bytes()
        tok = self._tokens()
        tok_dev = tok / m.dp

        # ---- compute
        t_compute = self._flops_total(plan) / (n * HW["peak_flops"])

        # ---- memory residency (feasibility) + traffic
        if plan.strategy in ("fsdp", "fsdp_tp", "fsdp_tp_ep"):
            resident = (P + self._opt_bytes()) / n
        elif plan.strategy == "tp":
            resident = (P + self._opt_bytes()) / m.model
        else:  # dp: replicated weights ("prefetched once")
            resident = P + self._opt_bytes()
        if plan.moe_mode == "replicated" and c.moe:
            mff = c.moe_d_ff or c.d_ff
            expert_bytes = 3 * c.d_model * mff * c.n_experts * 2.0 * \
                (c.n_layers - c.n_dense_layers)
            resident += expert_bytes * (1.0 - 1.0 / m.model)
        resident += self._act_bytes_per_device(plan)
        if self.kind == "decode":
            resident += self._kv_bytes_per_device(plan)

        traffic = (P / n) * (3.0 if self.kind == "train" else 1.0) \
            + self._act_bytes_per_device(plan) * 2.0
        if self.kind == "decode":
            traffic += self._kv_bytes_per_device(plan)  # full KV read/step
        t_memory = traffic / HW["hbm_bw"]

        # ---- collectives (per device bytes / ICI bw)
        coll = 0.0
        d_bytes = c.d_model * 2.0
        if "tp" in plan.strategy:
            # 2 all-reduces per layer fwd (+2 bwd): B_loc×T×d each
            n_ar = 2 * (2 if self.kind == "train" else 1)
            coll += n_ar * c.n_layers * tok_dev * d_bytes * \
                2.0 * (m.model - 1) / m.model
        if plan.strategy in ("fsdp", "fsdp_tp", "fsdp_tp_ep") \
                and self.kind == "train":
            regather = 2.0   # fwd + bwd weight all-gather
            P_regather = P
            if plan.strategy == "fsdp_tp_ep":
                # expert weights are fully OWNED (E on model × ffn on data):
                # never regathered — instead the (E_loc, C, d) activation
                # buffer reduces over data (≈ tok·topk·d·cf bytes per layer)
                P_regather = P - self._expert_bytes()
                n_moe = c.n_layers - c.n_dense_layers
                # per-device reduce of the (E/model, C, d) buffer over data
                buf = tok_dev * c.top_k * d_bytes * c.capacity_factor \
                    * n_moe / max(1, m.model)
                coll += buf * (3.0 if self.kind == "train" else 1.0)
            coll += regather * P_regather / max(
                1, m.model if "tp" in plan.strategy else 1)
        if self.kind == "train":
            # gradient reduce-scatter + param all-gather over data axis
            coll += 2.0 * P / max(1, m.model if "tp" in plan.strategy else 1) \
                * (m.dp - 1) / m.dp
        if c.moe and plan.moe_mode == "ep_all_to_all":
            n_moe = c.n_layers - c.n_dense_layers
            a2a = tok_dev * c.top_k * d_bytes * 2.0 * n_moe  # there and back
            coll += a2a * (3.0 if self.kind == "train" else 1.0)
        if plan.seq_shard and c.attn_kind != "none":
            # ring attention: KV blocks permute around the data axis
            coll += tok_dev * c.n_kv_heads * c.hd * 2.0 * 2.0 * c.n_layers
        t_coll = coll / HW["ici_bw"]

        feasible = resident <= HW["hbm_bytes"] * 0.9
        return {"compute_s": t_compute, "memory_s": t_memory,
                "collective_s": t_coll, "resident_bytes": resident,
                "feasible": feasible,
                "step_s": max(t_compute, t_memory, t_coll)}

    def _kv_bytes_per_device(self, plan: PlanChoice) -> float:
        c = self.cfg
        B, T = self.B, self.T
        if c.ssm_kind == "rwkv6":
            per = c.n_layers * c.n_heads * (c.d_model // c.n_heads) ** 2 * 4.0
            return B * per / self.mesh.dp
        if c.ssm_kind == "mamba2":
            per = c.n_layers * c.n_heads * c.ssm_state * \
                (2 * c.d_model // c.n_heads) * 4.0
            kv = B * per
            if c.shared_attn:
                sites = max(1, c.n_layers // max(1, c.hybrid_every))
                kv += sites * B * T * c.n_kv_heads * c.hd * 2 * 2.0
            return kv / self.mesh.dp
        # attention KV: batch over data AND sequence over model (the launch
        # cache_specs sharding) → divides by the full device count
        if c.attn_kind == "mla":
            per_tok = c.n_layers * (c.kv_lora_rank + c.qk_rope_dim) * 2.0
            return B * T * per_tok / self.mesh.n
        eff = min(T, c.window) if c.window else T
        per_tok = c.n_layers * c.n_kv_heads * c.hd * 2 * 2.0
        return B * eff * per_tok / self.mesh.n


# --------------------------------------------------------------------------
# Plan enumeration through the Region DAG
# --------------------------------------------------------------------------

def _dimension_rules(cfg: ArchConfig, kind: str) -> Dict[str, List]:
    dims = {
        "layout": (["fsdp_tp_ep", "fsdp_tp", "tp", "fsdp", "dp"]
                   if cfg.moe else ["fsdp_tp", "tp", "fsdp", "dp"]),
        "remat": (["none", "dots", "full"] if kind == "train" else ["none"]),
        "microbatch": ([1, 4, 8, 16] if kind == "train"
                       else ([1, 4] if kind == "prefill" else [1])),
        "seq_shard": [False, True] if kind == "decode" else [False],
        "moe_mode": (["ep_all_to_all", "replicated"] if cfg.moe else ["none"]),
    }
    return dims


def enumerate_plans(cfg: ArchConfig, kind: str) -> List[PlanChoice]:
    dims = _dimension_rules(cfg, kind)
    out = []
    for combo in itertools.product(dims["layout"], dims["remat"],
                                   dims["microbatch"], dims["seq_shard"],
                                   dims["moe_mode"]):
        out.append(PlanChoice(*combo))
    return out


def plan(cfg: ArchConfig, seq_len: int, global_batch: int, kind: str,
         mesh: Tuple[int, ...] = (1, 16, 16), top_k: int = 1):
    """Cost-based plan selection through the Region DAG.

    The step program's regions become memo groups; each planning dimension's
    alternatives are AND-nodes added by a rule (one rule per dimension —
    exactly the Fig. 11 pattern); the root 'assemble' enumerates child
    combinations and the cost model prices each complete plan. Volcano
    duplicate detection collapses re-derived combinations."""
    ms = MeshShape(*((1,) * (3 - len(mesh)) + tuple(mesh)))
    cm = TPUCostModel(cfg, seq_len, global_batch, kind, ms)

    memo = Memo()
    dims = _dimension_rules(cfg, kind)
    dim_groups = {}
    for dim, options in dims.items():
        g = None
        for opt in options:
            g, _ = memo.insert(AndNode(f"dim:{dim}", (), (dim, opt)), group=g)
        dim_groups[dim] = g
    root, _ = memo.insert(AndNode(
        "plan-assemble", tuple(dim_groups[d] for d in dims), "step"))

    # exhaustive cost over the AND-OR combination space (small; memoized)
    best: List[Tuple[float, PlanChoice, Dict]] = []
    for combo in itertools.product(*[
            [memo.node(a).payload[1] for a in memo.members(dim_groups[d])]
            for d in dims]):
        choice = PlanChoice(*combo)
        t = cm.terms(choice)
        cost = t["step_s"] if t["feasible"] else float("inf")
        best.append((cost, choice, t))
    best.sort(key=lambda x: x[0])
    if top_k == 1:
        cost, choice, t = best[0]
        return {"choice": choice, "terms": t, "cost_s": cost,
                "n_alternatives": len(best),
                "memo": memo.stats()}
    return [{"choice": c, "terms": t, "cost_s": s} for s, c, t in best[:top_k]]
